"""Deterministic synthetic token pipeline.

batch_at(step) is a PURE function of (seed, step) — no iterator state to
checkpoint, restarts and elastic re-sharding are trivially consistent, and
every host computes exactly the (shard of the) batch it owns.

The synthetic language is learnable: with probability ~7/8 the next token
is an affine function of the current one, else it re-seeds — so training
loss decreases measurably within a few hundred steps (used by the e2e
example and the convergence test).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    d_model: int = 0        # >0 => also emit stub embeddings (vlm/audio)


def _tokens_for(cfg: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng((cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
    b, s, v = cfg.batch, cfg.seq, cfg.vocab
    start = rng.integers(0, v, size=(b, 1))
    noise = rng.random((b, s)) < 0.125
    fresh = rng.integers(0, v, size=(b, s))
    toks = np.empty((b, s), np.int64)
    toks[:, 0] = start[:, 0]
    a, c = 31, 7
    for i in range(1, s):
        nxt = (toks[:, i - 1] * a + c) % v
        toks[:, i] = np.where(noise[:, i], fresh[:, i], nxt)
    return toks.astype(np.int32)


def batch_at(cfg: DataConfig, step: int,
             sharding=None) -> Dict[str, jax.Array]:
    """Batch for `step`: tokens + next-token labels (+ stub embeds)."""
    toks = _tokens_for(cfg, step)
    labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
    out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    if cfg.d_model:
        rng = np.random.default_rng(cfg.seed * 7 + step)
        out["embeds"] = jnp.asarray(
            rng.standard_normal((cfg.batch, cfg.seq, cfg.d_model),
                                np.float32) * 0.02, jnp.bfloat16)
    if sharding is not None:
        out = {k: jax.device_put(v, sharding[k]) for k, v in out.items()
               if k in sharding}
    return out

"""Workload compression: advise on weighted representatives (ROADMAP item 3).

Every structure in the advisor pipeline is linear in the statement count, and
the `CostEngine` matrices are `(statements x candidates)` dense — at the
paper's §7 "large workload" regime (tens of thousands of statements, DTA-style
traces) both wall time and memory grow without bound.  This module clusters
statements by *signature* and hands the pipeline a budget-bounded compressed
workload of weighted representatives, together with a per-cluster cost-error
bound so a recommendation on the compressed workload carries a certificate of
how far its cost can be from the full-workload cost.

Two cluster tiers, chosen per statement budget:

* **Fine (certified) clusters** — signature = (statement kind, table,
  sorted (filter column, selectivity bucket) pairs, projected-column set)
  for queries and (kind, table, log2 row-count bucket) for bulk inserts.
  Within a fine cluster every member shares the *structure* the cost model
  sees (table, filter-column set, covering set, ncols) and differs only in
  per-column selectivity (queries) or rows written (inserts).  The cost
  model is monotone in both (`seek_cost`/`rid_lookup_cost` nondecreasing in
  selectivity, `update_cost` nondecreasing in rows), and the selectivity
  buckets pin each column to one side of the covering `sel >= 1` branch, so
  for ANY predicate-free configuration each member's cost is sandwiched
  between the costs of two *bounding statements* built from the member
  extremes.  The reported per-cluster error term
  ``W * (max(c_hi, c_rep) - min(c_lo, c_rep))`` is therefore a theorem of
  the cost model, not a heuristic.
* **Coarse (envelope) clusters** — the budget tail.  Statements whose fine
  cluster did not earn a representative slot fall back to ONE envelope
  cluster per (statement kind, table), so the representative count is
  genuinely bounded by the budget (down to the ~2x#tables structural
  floor).  A coarse query cluster's error term uses the universal envelope
  ``0 <= cost(q, cfg) <= scan(clustered layout)`` (a query's cost is a min
  over paths that always includes the clustered scan) — sound for any
  configuration, looser than the certificate; `scan_cost` is linear in
  `ncols_used`, so the per-cluster envelope aggregates in O(1) per
  configuration.  Coarse insert clusters keep the monotone certificate
  (it never needed structural sharing).

Budget allocation is a pure function of the cluster statistics: fine
clusters are ranked by total weight (ties by signature) and the heaviest
keep representative slots, the rest spill into the coarse tier; a fixpoint
loop balances slots between the tiers.  Representative *content* is a pure
function of the cluster signature and table statistics (canonical
predicates at the bucket midpoint, content-addressed names), so membership
churn only changes representative *weights* — the property the online
`AdvisorSession` fast path relies on.  All weight sums run in
member-name-sorted order, so a `ClusterIndex` maintained incrementally
across `WorkloadDelta`s derives the bit-identical compressed workload a
fresh `compress_workload` call produces on the resulting full workload.

With the budget disabled (`None`, or >= the statement count)
`compress_workload` returns None and the advisor runs the uncompressed
pipeline unchanged — the repo's exact-parity contract.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Tuple

from . import cost_model as cm
from .relation import Predicate, Table
from .whatif import (Configuration, SizeProvider, query_cost,
                     update_statement_cost)
from .workload import BulkInsert, Query, Statement, Workload

# selectivity bucket b covers (2^-(b+1), 2^-b]; MAX_BUCKET absorbs the tail
MAX_BUCKET = 24
# canonical representative selectivity inside bucket b: 0.75 * 2^-b
_BUCKET_MID = 0.75


def _sel_bucket(sel: float) -> str:
    """Selectivity bucket key.  "E" (exactly-one) is its own bucket: the
    covering-path formula switches from seek to scan at sel == 1, and the
    certificate needs every member of a cluster on the same side."""
    if sel >= 1.0:
        return "E"
    if sel <= 2.0 ** -MAX_BUCKET:
        return f"{MAX_BUCKET:02d}"
    return f"{min(MAX_BUCKET, int(math.floor(-math.log2(sel)))):02d}"


@dataclasses.dataclass
class _Member:
    """Per-statement facts the bound and the weights need."""
    weight: float
    # queries: {col: (selectivity, predicate)} over the canonical filter
    # dict (last predicate per column wins — the cost model's semantics)
    sels: Optional[Dict[str, Tuple[float, Predicate]]] = None
    ncols: int = 0
    # inserts
    nrows: int = 0


def _canonical_filters(q: Query, table: Table) -> Dict[str,
                                                       Tuple[float, Predicate]]:
    out: Dict[str, Tuple[float, Predicate]] = {}
    for p in q.filters:
        out[p.col] = (p.selectivity(table), p)
    return out


def _statement_facts(s: Statement,
                     table: Table) -> Tuple[Tuple, Tuple, _Member]:
    """(fine sig, coarse sig, member facts) in one pass — the canonical
    filter dict and the column set feed both the signature and the member,
    and computing them once halves per-statement clustering cost."""
    if isinstance(s, Query):
        filt = _canonical_filters(s, table)
        fsig = tuple(sorted((c, _sel_bucket(sel))
                            for c, (sel, _) in filt.items()))
        cols = set(s.all_cols())
        fine = ("q", s.table, fsig, tuple(sorted(cols)))
        member = _Member(weight=float(s.weight), sels=filt,
                         ncols=len(cols))
        return fine, ("q~", s.table), member
    fine = ("u", s.table, f"{max(0, int(s.nrows).bit_length() - 1):02d}")
    member = _Member(weight=float(s.weight), nrows=int(s.nrows))
    return fine, ("u~", s.table), member


def statement_signatures(s: Statement, table: Table) -> Tuple[Tuple, Tuple]:
    """(fine, coarse) cluster signatures of one statement — pure in the
    statement and the table's min/max statistics, so clustering is
    deterministic and independent of statement order."""
    fine, coarse, _ = _statement_facts(s, table)
    return fine, coarse


def _rep_name(key: Tuple) -> str:
    return "wc" + hashlib.sha1(repr(key).encode()).hexdigest()[:12]


def _canonical_pred(table: Table, col: str, bucket: str) -> Predicate:
    mn, mx = table.minmax(col)
    if bucket == "E":
        return Predicate(col, mn, mx)
    domain = mx - mn + 1
    target = _BUCKET_MID * 2.0 ** (-int(bucket))
    width = max(1, int(round(target * domain)))
    return Predicate(col, mn, mn + width - 1)


@dataclasses.dataclass
class Cluster:
    """One representative's cluster: identity, members, and bound data."""
    tier: str                      # "fine" | "coarse"
    sig: Tuple
    rep: Statement                 # weight = total member weight
    members: Dict[str, _Member]    # statement name -> facts
    weight: float

    @property
    def certified(self) -> bool:
        """True when the error term is the monotone-sandwich certificate
        (fine clusters and all insert clusters); False for the coarse
        query tier's scan envelope."""
        return self.tier == "fine" or self.sig[0] == "u~"

    # -- error term ------------------------------------------------------
    def _bounding_queries(self, table: Table) -> Tuple[Query, Query]:
        """Member-extreme bounding queries: per filter column take the
        member predicate with min (resp. max) selectivity.  Componentwise
        monotonicity of `query_cost` makes cost(lo) <= cost(member) <=
        cost(hi) for every member under any predicate-free config."""
        assert self.tier == "fine" and self.sig[0] == "q"
        proj = self.sig[3]
        lo_p, hi_p = [], []
        for col, _bucket in self.sig[2]:
            pairs = [m.sels[col] for m in self.members.values()]
            lo_p.append(min(pairs, key=lambda t: (t[0], t[1].lo, t[1].hi))[1])
            hi_p.append(max(pairs, key=lambda t: (t[0], t[1].lo, t[1].hi))[1])
        mk = lambda tag, preds: Query(f"{self.rep.name}:{tag}",
                                      self.rep.table, tuple(preds), proj,
                                      weight=self.weight)
        return mk("lo", lo_p), mk("hi", hi_p)

    def error_term(self, config: Configuration, sizes: SizeProvider,
                   table: Table) -> float:
        """Sound upper bound on |sum_s w_s cost(s, cfg) - W * cost(rep,
        cfg)| for this cluster under `config` (predicate-free indexes)."""
        W = self.weight
        if isinstance(self.rep, BulkInsert):
            rows = [m.nrows for m in self.members.values()]
            c_lo = update_statement_cost(
                dataclasses.replace(self.rep, nrows=min(rows)), config, sizes)
            c_hi = update_statement_cost(
                dataclasses.replace(self.rep, nrows=max(rows)), config, sizes)
            c_rep = update_statement_cost(self.rep, config, sizes)
            return W * (max(c_hi, c_rep) - min(c_lo, c_rep))
        if self.tier == "fine":
            q_lo, q_hi = self._bounding_queries(table)
            c_lo = query_cost(q_lo, config, sizes)
            c_hi = query_cost(q_hi, config, sizes)
            c_rep = query_cost(self.rep, config, sizes)
            return W * (max(c_hi, c_rep) - min(c_lo, c_rep))
        # coarse query envelope: 0 <= cost(s) <= scan(clustered layout),
        # and scan_cost is linear in ncols_used, so the weighted member
        # envelope collapses to one scan_cost call at the weighted mean
        clustered = config.clustered(self.rep.table)
        assert clustered is not None
        w_ncols = sum(m.weight * m.ncols
                      for _, m in sorted(self.members.items()))
        env = W * cm.scan_cost(sizes.size(clustered), table.nrows,
                               w_ncols / W, clustered.compression)
        c_rep = W * query_cost(self.rep, config, sizes)
        return max(c_rep, env - c_rep)


@dataclasses.dataclass
class CompressedWorkload:
    """A budget-bounded weighted-representative workload + its certificate."""
    workload: Workload             # representative statements, sig-sorted
    clusters: List[Cluster]        # aligned with workload.statements
    n_full: int
    budget: int

    @property
    def n_representatives(self) -> int:
        return len(self.clusters)

    @property
    def compression_ratio(self) -> float:
        return self.n_full / max(1, len(self.clusters))

    def cluster_of(self) -> Dict[str, str]:
        """statement name -> representative name (membership map)."""
        out: Dict[str, str] = {}
        for c in self.clusters:
            for name in c.members:
                out[name] = c.rep.name
        return out

    def error_bound(self, config: Configuration,
                    sizes: SizeProvider) -> float:
        """Sound bound on |C_full(config) - C_compressed(config)| in cost
        units, summed over per-cluster terms (see `Cluster.error_term`).
        Valid for any configuration of predicate-free indexes — the only
        kind the advisor pipeline generates."""
        tables = sizes.schema.tables
        return sum(c.error_term(config, sizes, tables[c.rep.table])
                   for c in self.clusters)


class ClusterIndex:
    """Incremental cluster membership over a (possibly huge) workload.

    `add`/`remove`/`reweight` are O(1) per statement; `derive(budget)`
    recomputes the budgeted representative set as a pure function of the
    current membership statistics, so an index maintained across
    `WorkloadDelta`s and a fresh `ClusterIndex.from_workload` on the
    resulting workload derive identical compressed workloads.
    """

    def __init__(self, schema):
        self.schema = schema
        # fine sig -> {name: _Member}; per-name reverse map for removal
        self._fine: Dict[Tuple, Dict[str, _Member]] = {}
        self._by_name: Dict[str, Tuple[Tuple, Tuple]] = {}

    @classmethod
    def from_workload(cls, workload: Workload) -> "ClusterIndex":
        ix = cls(workload.schema)
        for s in workload.statements:
            ix.add(s)
        return ix

    def __len__(self) -> int:
        return len(self._by_name)

    # -- membership maintenance (O(delta)) ------------------------------
    def add(self, s: Statement) -> None:
        table = self.schema.tables[s.table]
        fine, coarse, member = _statement_facts(s, table)
        if s.name in self._by_name:
            raise ValueError(f"duplicate statement name {s.name!r}")
        self._by_name[s.name] = (fine, coarse)
        self._fine.setdefault(fine, {})[s.name] = member

    def remove(self, name: str) -> None:
        fine, _ = self._by_name.pop(name)
        members = self._fine[fine]
        del members[name]
        if not members:
            del self._fine[fine]

    def reweight(self, name: str, weight: float) -> None:
        fine, _ = self._by_name[name]
        self._fine[fine][name].weight = float(weight)

    def apply_delta(self, delta) -> None:
        """Mirror a validated `workload.WorkloadDelta`."""
        for name in delta.removed:
            self.remove(name)
        for name, w in delta.reweighted:
            self.reweight(name, w)
        for s in delta.added:
            self.add(s)

    # -- derivation ------------------------------------------------------
    def _fine_weight(self, members: Dict[str, _Member]) -> float:
        # name-sorted summation: bit-identical between an incrementally
        # maintained index and a fresh one on the same workload
        return sum(members[n].weight for n in sorted(members))

    def _rep(self, tier: str, sig: Tuple, weight: float) -> Statement:
        name = _rep_name((tier, sig))
        if sig[0] == "q":
            table = self.schema.tables[sig[1]]
            preds = tuple(_canonical_pred(table, c, b) for c, b in sig[2])
            return Query(name, sig[1], preds, sig[3], weight=weight)
        if sig[0] == "q~":
            table = self.schema.tables[sig[1]]
            cols = tuple(c.name for c in table.columns)
            return Query(name, sig[1], (), cols, weight=weight)
        if sig[0] == "u":
            b = int(sig[2])
            return BulkInsert(name, sig[1], max(1, int(1.5 * 2 ** b)),
                              weight=weight)
        assert sig[0] == "u~"
        table = self.schema.tables[sig[1]]
        return BulkInsert(name, sig[1], max(table.nrows // 50, 1),
                          weight=weight)

    def derive(self, budget: Optional[int]) -> Optional[CompressedWorkload]:
        """The budgeted compressed workload of the current membership, or
        None when the budget is disabled or >= the statement count (the
        exact-parity bypass)."""
        n_full = len(self._by_name)
        if budget is None or n_full <= budget:
            return None
        fine_stats = [(self._fine_weight(m), sig, m)
                      for sig, m in self._fine.items()]
        order = sorted(fine_stats, key=lambda t: (-t[0], repr(t[1])))
        # fixpoint: fine representative slots vs coarse tail clusters.
        # Shrinking the kept set only grows the tail, so k is monotone
        # nonincreasing and the loop terminates.
        k = min(len(order), budget)
        while True:
            coarse_sigs = {self._by_name[name][1]
                           for _, _, members in order[k:]
                           for name in members}
            k_new = min(len(order), max(0, budget - len(coarse_sigs)))
            if k_new >= k:
                break
            k = k_new
        clusters: List[Cluster] = []
        for w, sig, members in order[:k]:
            clusters.append(Cluster("fine", sig,
                                    self._rep("fine", sig, w),
                                    dict(members), w))
        coarse: Dict[Tuple, Dict[str, _Member]] = {}
        for _, _sig, members in order[k:]:
            for name, m in members.items():
                coarse.setdefault(self._by_name[name][1], {})[name] = m
        for csig, members in coarse.items():
            w = self._fine_weight(members)
            clusters.append(Cluster("coarse", csig,
                                    self._rep("coarse", csig, w),
                                    members, w))
        clusters.sort(key=lambda c: (c.tier, repr(c.sig)))
        wl = Workload(schema=self.schema,
                      statements=[c.rep for c in clusters])
        return CompressedWorkload(workload=wl, clusters=clusters,
                                  n_full=n_full, budget=budget)


def compress_workload(workload: Workload,
                      budget: Optional[int]) -> Optional[CompressedWorkload]:
    """Cluster `workload` into <= `budget` weighted representatives (None
    disables; budget >= statement count returns None — the exact-parity
    bypass the advisor relies on).  The spilled tail can push the
    representative count above `budget` only when the budget is below the
    number of distinct coarse signatures (the structural floor)."""
    if budget is None or len(workload.statements) <= budget:
        return None
    return ClusterIndex.from_workload(workload).derive(budget)

"""Unified accelerator-backend resolution for the advisor stack.

One backend knob — ``AdvisorOptions(backend=...)`` — threads through every
engine (CostEngine, compression codec kernels, EstimationEngine,
PlannerEngine) and the fleet service.  This module is the single place
that decides whether a requested backend can actually run:

* ``"numpy"`` — the float64 parity reference.  Always available.
* ``"jax"``  — Pallas kernels (repro.kernels.codec_bytes /
  planner_score) plus jax.jit scoring kernels.  Requires jax; runs in
  interpret mode on CPU and compiled on TPU.  The old int64/x64 gate is
  gone: codec kernels do exact int32-safe math through uint32 planes.

Fallback semantics: when ``"jax"`` is requested but jax is unavailable,
`resolve` downgrades to ``"numpy"`` — but never silently.  Each resolving
engine gets a one-time `BackendFallbackWarning` (once per call site per
process) and counts the event in its ``stats()["backend_fallbacks"]``.
Unknown backend names always raise ValueError.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

try:  # repro.kernels idiom: gate, don't require
    import jax  # noqa: F401
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    HAVE_JAX = False

BACKENDS = ("numpy", "jax")


class BackendFallbackWarning(UserWarning):
    """A requested accelerator backend was unavailable; numpy ran instead."""


_warned_sites = set()


def available(backend: str) -> bool:
    """True when `backend` can actually run in this process."""
    return backend == "numpy" or (backend == "jax" and HAVE_JAX)


def resolve(backend: str, site: Optional[str] = None) -> Tuple[str, bool]:
    """Validate `backend` and downgrade to numpy if it cannot run.

    Returns (resolved_backend, fell_back).  With `site` set, an
    unavailable backend emits a one-time BackendFallbackWarning per site;
    site=None resolves quietly (for callers that only need the answer,
    e.g. WhatIfOptimizer deciding whether a rebuild is needed).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r} (expected one of "
                         f"{BACKENDS})")
    if available(backend):
        return backend, False
    if site is not None and site not in _warned_sites:
        _warned_sites.add(site)
        warnings.warn(
            f"{site}: backend={backend!r} requested but unavailable "
            f"(jax import failed); falling back to numpy. This warning "
            f"is emitted once per site.", BackendFallbackWarning,
            stacklevel=3)
    return "numpy", True

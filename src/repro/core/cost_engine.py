"""Batched what-if cost engine: the advisor hot path as array code.

The scalar what-if path (repro.core.whatif) evaluates one (statement,
configuration) pair per Python call; `greedy_enumerate` multiplies that by
O(pool × statements) per greedy step, which is intractable for large
workloads (paper §5-§6 argue the tuning loop must scale).  This module
precomputes, per table, the full (statement × access-path) cost matrix so a
greedy step scores the *entire* candidate pool with a handful of vectorized
ops, and so adding an index on table T only re-evaluates statements on T
(incremental delta evaluation).

Decomposition used (mirrors `whatif.query_cost` exactly):

* A query's cost under configuration (c, S) — clustered layout `c` plus
  secondary set `S` — is

      min( SCANC[q, c],  min_{i in S} PATH[q, i, c] )
      PATH[q, i, c] = min( COV[q, i],  SEEK[q, i] + RID[q, i, c] )

  where COV (covering seek/scan) and SEEK (non-covering seek part) depend
  only on the candidate index, and RID (base-table RID lookups) couples the
  candidate with the *current clustered layout* through its page count and
  decompression coefficient.  All terms are evaluated with the ufunc-safe
  functions of repro.core.cost_model, so scalar and batched paths are
  formula-identical.

* A bulk insert's cost is additive over the table's indexes: UPD[u, i].

Registering an index computes its whole per-statement column in one
vectorized pass; columns live in capacity-doubling arrays so registration is
amortized O(statements) per index with no re-stacking.

Backends: plain NumPy (default, float64, bit-compatible with the scalar
reference) or the unified "jax" backend resolved through `core.backend`
(one knob for the whole advisor: AdvisorOptions(backend=...)).  Under jax
the greedy-step scoring kernels — add-secondary, replace-clustered, and
per-query candidate costing — run as jax.jit array kernels (same idioms
as repro.kernels.ops).  An unavailable jax never downgrades silently:
`core.backend.resolve` warns once per site and the engine counts the
event in ``stats()["backend_fallbacks"]``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import cost_model as cm
from .backend import resolve as _resolve_backend
from .relation import IndexDef, Predicate, Table
from .whatif import Configuration, SizeProvider, _partial_applicable
from .workload import BulkInsert, Query, Workload

try:  # optional accelerator backend (repro.kernels idiom: gate, don't require)
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    jax = None
    jnp = None
    HAVE_JAX = False

_INF = float("inf")


@dataclasses.dataclass
class TableEval:
    """Evaluated state of one table under a (clustered, secondaries) pair."""
    q_cost: np.ndarray      # per-query cost vector (nq,)
    q_total: float          # weighted query cost
    u_total: float          # weighted update-maintenance cost
    sec_upd: float          # update part contributed by secondaries only

    @property
    def total(self) -> float:
        return self.q_total + self.u_total


class _TableBlock:
    """Cost matrices for all registered access paths of one table.

    Columns (one per registered IndexDef) are stored in capacity-doubling
    arrays; evaluation always addresses them by explicit id lists, so no
    final assembly step is needed.
    """

    def __init__(self, table: Table, queries: Sequence[Query],
                 updates: Sequence[BulkInsert]):
        self.table = table
        self.queries = list(queries)
        self.updates = list(updates)
        nq, nu = len(self.queries), len(self.updates)
        self.q_w = np.array([q.weight for q in self.queries], dtype=np.float64)
        self.u_w = np.array([u.weight for u in self.updates], dtype=np.float64)
        self.u_rows = np.array([float(u.nrows) for u in self.updates])
        self.ncols_used = np.array([len(q.all_cols()) for q in self.queries],
                                   dtype=np.float64)
        # structural per-query caches (mirror whatif._covers /
        # whatif._prefix_selectivity without re-deriving per registration)
        self._q_cols_set = [frozenset(q.all_cols()) for q in self.queries]
        self._q_filt = [{p.col: p for p in q.filters} for q in self.queries]
        self._q_row = {q.name: qi for qi, q in enumerate(self.queries)}
        self._sel_cache: Dict[Predicate, float] = {}
        # dense structural matrices over the table's column universe: the
        # registration-time structural pass (applicability / covering /
        # prefix selectivity) runs as array ops instead of a per-query
        # Python loop, which dominates registration on large workloads
        self._col_pos = {c.name: k for k, c in enumerate(table.columns)}
        ncols_t = len(table.columns)
        self._q_has = np.zeros((nq, ncols_t), dtype=bool)
        self._q_hasf = np.zeros((nq, ncols_t), dtype=bool)
        self._q_selm = np.ones((nq, ncols_t))
        for qi, q in enumerate(self.queries):
            self._fill_struct_row(qi, q)
        self._u_row = {u.name: ui for ui, u in enumerate(self.updates)}
        self._ids: Dict[Tuple, int] = {}       # IndexDef.key -> column id
        self._defs: List[IndexDef] = []
        self._col_sets: List[Optional[frozenset]] = []  # None for clustered
        self.n = 0
        self._cap = 0
        self.cov = np.empty((nq, 0))
        self.seek = np.empty((nq, 0))
        self.ridr = np.empty((nq, 0))
        self.scanc = np.empty((nq, 0))
        self.upd = np.empty((nu, 0))
        self.size = np.empty(0)
        self.beta = np.empty(0)
        self.alpha = np.empty(0)
        self.nrows_idx = np.empty(0)
        self.col_klen = np.empty(0)

    def _grow(self, need: int) -> None:
        if need <= self._cap:
            return
        cap = max(16, 2 * self._cap, need)
        nq, nu = len(self.queries), len(self.updates)

        def g2(a: np.ndarray, rows: int) -> np.ndarray:
            out = np.empty((rows, cap))
            out[:, :a.shape[1]] = a
            return out

        def g1(a: np.ndarray) -> np.ndarray:
            out = np.empty(cap)
            out[:a.shape[0]] = a
            return out

        self.cov, self.seek = g2(self.cov, nq), g2(self.seek, nq)
        self.ridr, self.scanc = g2(self.ridr, nq), g2(self.scanc, nq)
        self.upd = g2(self.upd, nu)
        self.size, self.beta = g1(self.size), g1(self.beta)
        self.alpha, self.nrows_idx = g1(self.alpha), g1(self.nrows_idx)
        self.col_klen = g1(self.col_klen)
        self._cap = cap

    def _sel(self, p: Predicate) -> float:
        s = self._sel_cache.get(p)
        if s is None:
            s = self._sel_cache[p] = p.selectivity(self.table)
        return s

    def _fill_struct_row(self, qi: int, q: Query) -> None:
        """One query's row of the structural matrices: which columns the
        query touches, which carry a filter, and that filter's selectivity
        (last predicate per column wins, as in `whatif.query_cost`)."""
        pos = self._col_pos
        for c in q.all_cols():
            self._q_has[qi, pos[c]] = True
        for c, p in self._q_filt[qi].items():
            self._q_hasf[qi, pos[c]] = True
            self._q_selm[qi, pos[c]] = self._sel(p)

    # -- registration ----------------------------------------------------
    def has(self, idx: IndexDef) -> bool:
        return idx.key in self._ids

    def id_of(self, idx: IndexDef) -> int:
        return self._ids[idx.key]

    def query_row(self, query: Query) -> int:
        return self._q_row[query.name]

    def add(self, idx: IndexDef, sizes: SizeProvider) -> int:
        j = self._ids.get(idx.key)
        if j is not None:
            return j
        j = self.n
        self._grow(j + 1)
        self._ids[idx.key] = j
        self._defs.append(idx)
        self._col_sets.append(None if idx.clustered else frozenset(idx.cols))
        self.n += 1
        self._fill_column(j, idx, sizes)
        return j

    def _fill_column(self, j: int, idx: IndexDef,
                     sizes: SizeProvider) -> None:
        """(Re)compute column `j` from the provider's current sizes; used
        both at registration and when a re-estimation round changed the
        registered size of an already-registered access path."""
        t = self.table
        size = float(sizes.size(idx))
        nrows_idx = float(sizes.nrows(idx))
        nq = len(self.queries)
        self.size[j] = size
        self.beta[j] = cm.beta_coef_of(idx.compression)
        self.alpha[j] = cm.alpha_coef_of(idx.compression)
        self.nrows_idx[j] = nrows_idx
        self.col_klen[j] = float(len(idx.cols))

        if idx.clustered:
            # clustered layout: full scan path (whatif.query_cost's base)
            self.scanc[:, j] = cm.scan_cost(size, t.nrows, self.ncols_used,
                                            idx.compression)
            self.cov[:, j] = _INF
            self.seek[:, j] = _INF
            self.ridr[:, j] = 0.0
        else:
            self.scanc[:, j] = _INF
            # structural pass: applicability / covering / prefix selectivity
            if idx.predicate is None:
                # vectorized over the structural matrices.  The prefix
                # selectivity multiplies column-by-column in idx.cols
                # order — the same IEEE operation order as the scalar
                # loop, so the resulting values are bit-identical.
                ids = [self._col_pos[c] for c in idx.cols]
                applicable = np.ones(nq, dtype=bool)
                in_idx = np.zeros(len(self._col_pos), dtype=bool)
                in_idx[ids] = True
                covers = ~(self._q_has & ~in_idx).any(axis=1)
                prefix = np.logical_and.accumulate(self._q_hasf[:, ids],
                                                   axis=1)
                sel = np.ones(nq)
                for pos, ci in enumerate(ids):
                    m = prefix[:, pos]
                    sel[m] *= self._q_selm[m, ci]
            else:
                sel = np.ones(nq)
                applicable = np.ones(nq, dtype=bool)
                covers = np.zeros(nq, dtype=bool)
                cols_set = set(idx.cols)
                for qi, q in enumerate(self.queries):
                    if not _partial_applicable(idx, q):
                        applicable[qi] = False
                        continue
                    covers[qi] = self._q_cols_set[qi] <= cols_set
                    filt = self._q_filt[qi]
                    s, matched = 1.0, False
                    for c in idx.cols:
                        p = filt.get(c)
                        if p is None:
                            break
                        s *= self._sel(p)
                        matched = True
                    sel[qi] = s if matched else 1.0
            # vectorized cost pass over the structural masks
            cov = np.full(nq, _INF)
            seek = np.full(nq, _INF)
            ridr = np.zeros(nq)
            m = applicable & covers & (sel < 1.0)
            cov[m] = cm.seek_cost(size, nrows_idx, sel[m],
                                  self.ncols_used[m], idx.compression)
            m = applicable & covers & (sel >= 1.0)
            cov[m] = cm.scan_cost(size, nrows_idx, self.ncols_used[m],
                                  idx.compression)
            m = applicable & ~covers & (sel < 1.0)
            seek[m] = cm.seek_cost(size, nrows_idx, sel[m],
                                   float(len(idx.cols)), idx.compression)
            ridr[m] = nrows_idx * sel[m]
            self.cov[:, j] = cov
            self.seek[:, j] = seek
            self.ridr[:, j] = ridr

        if self.updates:
            rows = self.u_rows
            if idx.predicate is not None:
                rows = rows * self._sel(idx.predicate)
            self.upd[:, j] = cm.update_cost(size, nrows_idx, rows,
                                            idx.compression)

    def refresh_sizes(self, sizes: SizeProvider) -> int:
        """Refill every column whose provider size changed; returns how
        many columns were recomputed."""
        changed = 0
        for j, idx in enumerate(self._defs):
            if float(sizes.size(idx)) != self.size[j]:
                self._fill_column(j, idx, sizes)
                changed += 1
        return changed

    # -- statement mutation (online sessions) ----------------------------
    def _query_row(self, q: Query) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]:
        """(cov, seek, ridr, scanc) entries of one new query row for ALL
        registered columns — the transpose of `_fill_column`'s per-query
        pass, with identical elementwise cost-model calls so appended rows
        are bit-identical to a from-scratch block on the grown workload."""
        cap, n = self._cap, self.n
        cov = np.full(cap, _INF)
        seek = np.full(cap, _INF)
        ridr = np.zeros(cap)
        scanc = np.full(cap, _INF)
        if n == 0:
            return cov, seek, ridr, scanc
        ncq = float(len(q.all_cols()))
        qset = frozenset(q.all_cols())
        filt = {p.col: p for p in q.filters}
        sel = np.ones(n)
        applicable = np.ones(n, dtype=bool)
        covers = np.zeros(n, dtype=bool)
        cl = np.zeros(n, dtype=bool)
        for j, idx in enumerate(self._defs):
            if idx.clustered:
                cl[j] = True
                continue
            if idx.predicate is not None and not _partial_applicable(idx, q):
                applicable[j] = False
                continue
            covers[j] = qset <= self._col_sets[j]
            s, matched = 1.0, False
            for c in idx.cols:
                p = filt.get(c)
                if p is None:
                    break
                s *= self._sel(p)
                matched = True
            sel[j] = s if matched else 1.0
        t = self.table
        ids = np.nonzero(cl)[0]
        if ids.size:
            scanc[ids] = cm.scan_cost(self.size[ids], t.nrows, ncq,
                                      beta_coef=self.beta[ids])
        sec = ~cl
        ids = np.nonzero(sec & applicable & covers & (sel < 1.0))[0]
        if ids.size:
            cov[ids] = cm.seek_cost(self.size[ids], self.nrows_idx[ids],
                                    sel[ids], ncq, beta_coef=self.beta[ids])
        ids = np.nonzero(sec & applicable & covers & (sel >= 1.0))[0]
        if ids.size:
            cov[ids] = cm.scan_cost(self.size[ids], self.nrows_idx[ids],
                                    ncq, beta_coef=self.beta[ids])
        ids = np.nonzero(sec & applicable & ~covers & (sel < 1.0))[0]
        if ids.size:
            seek[ids] = cm.seek_cost(self.size[ids], self.nrows_idx[ids],
                                     sel[ids], self.col_klen[ids],
                                     beta_coef=self.beta[ids])
            ridr[ids] = self.nrows_idx[ids] * sel[ids]
        return cov, seek, ridr, scanc

    def _update_row(self, u: BulkInsert) -> np.ndarray:
        row = np.zeros(self._cap)
        n = self.n
        if n == 0:
            return row
        rows_w = np.full(n, float(u.nrows))
        for j, idx in enumerate(self._defs):
            if idx.predicate is not None:
                rows_w[j] = rows_w[j] * self._sel(idx.predicate)
        row[:n] = cm.update_cost(self.size[:n], self.nrows_idx[:n], rows_w,
                                 alpha_coef=self.alpha[:n])
        return row

    def add_statement(self, s) -> None:
        """Append one statement row across all registered columns."""
        self.add_statements([s])

    def add_statements(self, stmts: Sequence) -> None:
        """Append a batch of statement rows with ONE concatenate per
        matrix.  Each new row is a pure function of the registered columns
        (rows never read other rows), so batching the appends is
        bit-identical to sequential `add_statement` calls — it only
        removes the per-statement re-stacking that dominates large
        session deltas."""
        qs = [s for s in stmts if isinstance(s, Query)]
        us = [s for s in stmts if not isinstance(s, Query)]
        if qs:
            rows = [self._query_row(q) for q in qs]
            base = len(self.queries)
            nc = len(self._col_pos)
            for i, q in enumerate(qs):
                self.queries.append(q)
                self._q_row[q.name] = base + i
                self._q_cols_set.append(frozenset(q.all_cols()))
                self._q_filt.append({p.col: p for p in q.filters})
            self.q_w = np.append(self.q_w, [float(q.weight) for q in qs])
            self.ncols_used = np.append(
                self.ncols_used, [float(len(q.all_cols())) for q in qs])
            self._q_has = np.concatenate(
                [self._q_has, np.zeros((len(qs), nc), dtype=bool)], axis=0)
            self._q_hasf = np.concatenate(
                [self._q_hasf, np.zeros((len(qs), nc), dtype=bool)], axis=0)
            self._q_selm = np.concatenate(
                [self._q_selm, np.ones((len(qs), nc))], axis=0)
            for i, q in enumerate(qs):
                self._fill_struct_row(base + i, q)
            self.cov = np.concatenate(
                [self.cov, np.stack([r[0] for r in rows])], axis=0)
            self.seek = np.concatenate(
                [self.seek, np.stack([r[1] for r in rows])], axis=0)
            self.ridr = np.concatenate(
                [self.ridr, np.stack([r[2] for r in rows])], axis=0)
            self.scanc = np.concatenate(
                [self.scanc, np.stack([r[3] for r in rows])], axis=0)
        if us:
            rows_u = [self._update_row(u) for u in us]
            base = len(self.updates)
            for i, u in enumerate(us):
                self.updates.append(u)
                self._u_row[u.name] = base + i
            self.u_w = np.append(self.u_w, [float(u.weight) for u in us])
            self.u_rows = np.append(self.u_rows,
                                    [float(u.nrows) for u in us])
            self.upd = np.concatenate([self.upd, np.stack(rows_u)], axis=0)

    def remove_statements(self, names) -> int:
        """Drop the rows of the named statements (no recomputation; the
        surviving rows keep their values and relative order, matching a
        from-scratch block on the shrunk workload)."""
        removed = 0
        qkeep = [i for i, q in enumerate(self.queries)
                 if q.name not in names]
        if len(qkeep) != len(self.queries):
            removed += len(self.queries) - len(qkeep)
            ii = np.array(qkeep, dtype=np.int64)
            self.queries = [self.queries[i] for i in qkeep]
            self.q_w = self.q_w[ii]
            self.ncols_used = self.ncols_used[ii]
            self._q_cols_set = [self._q_cols_set[i] for i in qkeep]
            self._q_filt = [self._q_filt[i] for i in qkeep]
            self._q_row = {q.name: qi for qi, q in enumerate(self.queries)}
            self._q_has, self._q_hasf = self._q_has[ii], self._q_hasf[ii]
            self._q_selm = self._q_selm[ii]
            self.cov, self.seek = self.cov[ii], self.seek[ii]
            self.ridr, self.scanc = self.ridr[ii], self.scanc[ii]
        ukeep = [i for i, u in enumerate(self.updates)
                 if u.name not in names]
        if len(ukeep) != len(self.updates):
            removed += len(self.updates) - len(ukeep)
            ii = np.array(ukeep, dtype=np.int64)
            self.updates = [self.updates[i] for i in ukeep]
            self.u_w = self.u_w[ii]
            self.u_rows = self.u_rows[ii]
            self._u_row = {u.name: ui for ui, u in enumerate(self.updates)}
            self.upd = self.upd[ii]
        return removed

    def reweight(self, name: str, w: float) -> bool:
        qi = self._q_row.get(name)
        if qi is not None:
            self.queries[qi] = dataclasses.replace(self.queries[qi],
                                                   weight=w)
            self.q_w[qi] = w
            return True
        ui = self._u_row.get(name)
        if ui is not None:
            self.updates[ui] = dataclasses.replace(self.updates[ui],
                                                   weight=w)
            self.u_w[ui] = w
            return True
        return False

    # -- evaluation ------------------------------------------------------
    def rid(self, ids, c: int) -> np.ndarray:
        """RID-lookup matrix (nq, len(ids)) under clustered layout `c`."""
        return cm.rid_lookup_cost(self.ridr[:, ids], self.size[c],
                                  ncols_used=self.ncols_used[:, None],
                                  beta_coef=self.beta[c])

    def paths(self, ids, c: int) -> np.ndarray:
        """Best per-query path cost (nq, len(ids)) via each secondary id."""
        return np.minimum(self.cov[:, ids],
                          self.seek[:, ids] + self.rid(ids, c))

    def eval(self, c: int, sec_ids: Sequence[int]) -> TableEval:
        q = self.scanc[:, c].copy()
        if len(sec_ids) and len(self.queries):
            q = np.minimum(q, self.paths(list(sec_ids), c).min(axis=1))
        q_total = float(self.q_w @ q) if len(self.queries) else 0.0
        sec_upd = 0.0
        u_total = 0.0
        if len(self.updates):
            u_vec = self.upd[:, c].copy()
            if len(sec_ids):
                sec_vec = self.upd[:, list(sec_ids)].sum(axis=1)
                sec_upd = float(self.u_w @ sec_vec)
                u_vec = u_vec + sec_vec
            u_total = float(self.u_w @ u_vec)
        return TableEval(q_cost=q, q_total=q_total, u_total=u_total,
                         sec_upd=sec_upd)


# ---------------------------------------------------------------------------
# Optional jax.jit scoring kernel (repro.kernels.ops idiom)
# ---------------------------------------------------------------------------

if HAVE_JAX:
    @jax.jit
    def _jax_score_secondary(cur_q, cov, seek, ridr, size_c, beta_c,
                             ncols_used, q_w):
        npages = jnp.maximum(size_c, 0.0) / cm.PAGE_BYTES
        rid = (cm.T_IO_RAND * jnp.minimum(ridr, npages)
               + cm.CPU_ROW * ridr
               + beta_c * ridr * ncols_used[:, None])
        path = jnp.minimum(cov, seek + rid)
        new_q = jnp.minimum(cur_q[:, None], path)
        return q_w @ new_q

    @jax.jit
    def _jax_score_replace(scanc_c, cov, seek, ridr, size_c, beta_c,
                           ncols_used, q_w):
        """Clustered-replacement scoring: every secondary path under every
        candidate clustered layout.  scanc_c (nq, m) candidate scan costs;
        cov/seek/ridr (nq, ns) the kept-secondary rows; size_c/beta_c (m,)
        the candidate layouts' RID coupling."""
        npages = jnp.maximum(size_c, 0.0) / cm.PAGE_BYTES           # (m,)
        rid = (cm.T_IO_RAND * jnp.minimum(ridr[:, :, None], npages)
               + cm.CPU_ROW * ridr[:, :, None]
               + beta_c * ridr[:, :, None] * ncols_used[:, None, None])
        path = jnp.minimum(cov[:, :, None], seek[:, :, None] + rid)
        new_q = jnp.minimum(scanc_c, jnp.min(path, axis=1))
        return q_w @ new_q

    @jax.jit
    def _jax_cand_costs(scan_l, cov_s, seek_s, ridr_s, size_l, beta_l,
                        cov_k, seek_k, ridr_k, size_c, beta_c, ncq,
                        is_sec):
        """Per-query candidate costing (one query row, m candidates).
        Each candidate k is scored under its own layout L_k (the current
        clustered layout for secondary candidates, the candidate itself
        for clustered ones): min(scan under L_k, best base-secondary path
        under L_k, own path under the current layout when secondary)."""
        npag_l = jnp.maximum(size_l, 0.0) / cm.PAGE_BYTES           # (m,)
        rid_sl = (cm.T_IO_RAND * jnp.minimum(ridr_s[:, None], npag_l)
                  + cm.CPU_ROW * ridr_s[:, None]
                  + beta_l * ridr_s[:, None] * ncq)                 # (ns, m)
        base_path = jnp.min(
            jnp.minimum(cov_s[:, None], seek_s[:, None] + rid_sl),
            axis=0, initial=jnp.inf)                                # (m,)
        npag_c = jnp.maximum(size_c, 0.0) / cm.PAGE_BYTES
        rid_k = (cm.T_IO_RAND * jnp.minimum(ridr_k, npag_c)
                 + cm.CPU_ROW * ridr_k + beta_c * ridr_k * ncq)     # (m,)
        own = jnp.where(is_sec, jnp.minimum(cov_k, seek_k + rid_k),
                        jnp.inf)
        return jnp.minimum(jnp.minimum(scan_l, base_path), own)

    @jax.jit
    def _jax_cand_costs_stacked(scan_l, cov, seek, ridr, size_c, beta_c,
                                ncq, is_sec):
        """Cross-job stacked twin of `_jax_cand_costs` for secondary-free
        bases (the fleet COST-phase prefetch): the same per-element
        float32 op sequence, so a job scored inside a fleet batch equals
        the per-job kernel's output bitwise."""
        npag = jnp.maximum(size_c, 0.0) / cm.PAGE_BYTES             # (J,1)
        rid = (cm.T_IO_RAND * jnp.minimum(ridr, npag)
               + cm.CPU_ROW * ridr + beta_c * ridr * ncq)           # (J,m)
        own = jnp.where(is_sec, jnp.minimum(cov, seek + rid), jnp.inf)
        return jnp.minimum(scan_l, own)


class CostEngine:
    """Batched what-if engine over a workload and a SizeProvider.

    Register any IndexDef once; afterwards every cost query — single
    configurations, configuration batches, or whole-pool greedy-step scores —
    is evaluated from the precomputed per-table matrices.
    """

    def __init__(self, workload: Workload, sizes: SizeProvider,
                 backend: str = "numpy"):
        self.backend, fell_back = _resolve_backend(backend,
                                                   site="cost_engine")
        self.backend_fallbacks = int(fell_back)
        self.workload = workload
        self.sizes = sizes
        self.blocks: Dict[str, _TableBlock] = {}
        for name, table in workload.schema.tables.items():
            qs = [s for s in workload.statements
                  if isinstance(s, Query) and s.table == name]
            us = [s for s in workload.statements
                  if isinstance(s, BulkInsert) and s.table == name]
            self.blocks[name] = _TableBlock(table, qs, us)
        self.config_evals = 0     # configurations costed via this engine
        self.batch_scores = 0     # vectorized pool-scoring calls
        self.rows_added = 0       # statement rows appended incrementally
        self.rows_removed = 0     # statement rows dropped incrementally
        self.cols_refreshed = 0   # columns refilled after size changes

    def stats(self) -> Dict[str, int]:
        return {"config_evals": self.config_evals,
                "batch_scores": self.batch_scores,
                "rows_added": self.rows_added,
                "rows_removed": self.rows_removed,
                "cols_refreshed": self.cols_refreshed,
                "backend_fallbacks": self.backend_fallbacks}

    # -- registration ----------------------------------------------------
    def register(self, idxs: Iterable[IndexDef]) -> np.ndarray:
        """Register every index; returns their engine column ids aligned
        with the input (so callers can precompute id arrays once instead
        of calling `id_of` per candidate per greedy step)."""
        return np.array([self.blocks[idx.table].add(idx, self.sizes)
                         for idx in idxs], dtype=np.int64)

    def id_of(self, idx: IndexDef) -> int:
        blk = self.blocks[idx.table]
        if not blk.has(idx):
            blk.add(idx, self.sizes)
        return blk.id_of(idx)

    # -- incremental maintenance (online sessions) -----------------------
    def apply_delta(self, delta) -> None:
        """Apply a `workload.WorkloadDelta`: removed statements' rows are
        dropped, reweights touch only the weight vectors, and each added
        statement appends one fully-evaluated row per registered access
        path — no existing matrix entry is recomputed."""
        removed = set(delta.removed)
        if removed:
            for blk in self.blocks.values():
                self.rows_removed += blk.remove_statements(removed)
        for name, w in delta.reweighted:
            if not any(blk.reweight(name, float(w))
                       for blk in self.blocks.values()):
                raise KeyError(f"cannot reweight unknown statement {name!r}")
        by_table: Dict[str, list] = {}
        for s in delta.added:
            by_table.setdefault(s.table, []).append(s)
        for table, stmts in by_table.items():
            self.blocks[table].add_statements(stmts)
            self.rows_added += len(stmts)

    def sync_sizes(self) -> int:
        """Refill columns whose registered size changed since they were
        computed (a later estimation round re-registered the candidate);
        returns the number of refreshed columns."""
        refreshed = 0
        for blk in self.blocks.values():
            refreshed += blk.refresh_sizes(self.sizes)
        self.cols_refreshed += refreshed
        return refreshed

    # -- configuration costing -------------------------------------------
    def split(self, config: Configuration, table: str
              ) -> Tuple[int, List[int]]:
        blk = self.blocks[table]
        c_id = None
        sec: List[int] = []
        for idx in config.indexes:
            if idx.table != table:
                continue
            if not blk.has(idx):
                blk.add(idx, self.sizes)
            if idx.clustered:
                assert c_id is None, f"two clustered layouts for {table}"
                c_id = blk.id_of(idx)
            else:
                sec.append(blk.id_of(idx))
        assert c_id is not None, f"no clustered layout for {table}"
        return c_id, sec

    def table_eval(self, config: Configuration, table: str) -> TableEval:
        c_id, sec = self.split(config, table)
        return self.blocks[table].eval(c_id, sec)

    def config_cost(self, config: Configuration) -> float:
        """Workload cost of one configuration (parity with the scalar
        `WhatIfOptimizer.workload_cost`, modulo summation order)."""
        self.config_evals += 1
        total = 0.0
        for table, blk in self.blocks.items():
            if not blk.queries and not blk.updates:
                continue
            total += self.table_eval(config, table).total
        return total

    def config_costs(self, configs: Sequence[Configuration]) -> np.ndarray:
        return np.array([self.config_cost(c) for c in configs])

    # -- per-query candidate costing (candidate selection, §6.1) ----------
    def candidate_query_costs(self, query: Query, base: Configuration,
                              cands: Sequence[IndexDef]) -> np.ndarray:
        """Cost of `query` under base + each single candidate, batched.

        Mirrors the scalar `cost_candidates` loop: secondary candidates are
        added on top of `base`; clustered candidates replace the table's
        clustered layout.  Returns one cost per candidate, aligned with
        `cands`.
        """
        self.batch_scores += 1
        table = query.table
        blk = self.blocks[table]
        self.register(cands)
        c_id, sec_ids = self.split(base, table)
        qi = blk.query_row(query)
        ncq = blk.ncols_used[qi]

        def row_paths(ids, c):
            # single-query row of paths(): same formula, O(len(ids))
            rid = cm.rid_lookup_cost(blk.ridr[qi, ids], blk.size[c],
                                     ncols_used=ncq, beta_coef=blk.beta[c])
            return np.minimum(blk.cov[qi, ids], blk.seek[qi, ids] + rid)

        if self.backend == "jax" and len(cands):
            ids = np.array([blk.id_of(i) for i in cands], dtype=np.int64)
            is_sec = np.array([not i.clustered for i in cands])
            cl_ids = np.where(is_sec, c_id, ids)  # layout each k runs under
            sids = np.array(sec_ids, dtype=np.int64)
            return np.asarray(_jax_cand_costs(
                blk.scanc[qi, cl_ids], blk.cov[qi, sids],
                blk.seek[qi, sids], blk.ridr[qi, sids], blk.size[cl_ids],
                blk.beta[cl_ids], blk.cov[qi, ids], blk.seek[qi, ids],
                blk.ridr[qi, ids], blk.size[c_id], blk.beta[c_id],
                ncq, is_sec), dtype=np.float64)

        base_q = blk.scanc[qi, c_id]
        if sec_ids:
            base_q = min(base_q, float(row_paths(sec_ids, c_id).min()))

        out = np.empty(len(cands))
        sec_ks = [k for k, idx in enumerate(cands) if not idx.clustered]
        if sec_ks:
            ids = [blk.id_of(cands[k]) for k in sec_ks]
            out[sec_ks] = np.minimum(base_q, row_paths(ids, c_id))
        for k, idx in enumerate(cands):
            if not idx.clustered:
                continue
            cid2 = blk.id_of(idx)
            c = blk.scanc[qi, cid2]
            if sec_ids:
                c = min(c, float(row_paths(sec_ids, cid2).min()))
            out[k] = c
        return out

    def cost_job_arrays(self, query: Query, base: Configuration,
                        cands: Sequence[IndexDef]) -> Dict[str, object]:
        """Gather one (query, base, candidates) costing job as flat
        per-candidate arrays for cross-job stacking — the fleet service's
        COST-phase prefetch.  Requires a secondary-free `base` (the
        advisor's `base_configuration`), which makes the job purely
        elementwise; `batched_candidate_costs` then scores many jobs at
        once with exactly the per-job `candidate_query_costs` arithmetic."""
        table = query.table
        blk = self.blocks[table]
        self.register(cands)
        c_id, sec_ids = self.split(base, table)
        if sec_ids:
            raise ValueError("cost_job_arrays requires a secondary-free "
                             "base configuration")
        qi = blk.query_row(query)
        ids = np.array([blk.id_of(i) for i in cands], dtype=np.int64)
        is_sec = np.array([not i.clustered for i in cands])
        cl_ids = np.where(is_sec, c_id, ids)  # layout each k runs under
        return {
            "scan_l": blk.scanc[qi, cl_ids], "cov": blk.cov[qi, ids],
            "seek": blk.seek[qi, ids], "ridr": blk.ridr[qi, ids],
            "size_c": float(blk.size[c_id]),
            "beta_c": float(blk.beta[c_id]),
            "ncq": float(blk.ncols_used[qi]), "is_sec": is_sec,
        }

    # -- greedy-step scoring ---------------------------------------------
    def score_add_secondary(self, table: str, c_id: int, cur_q: np.ndarray,
                            cand_ids: Sequence[int]
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Score adding each candidate secondary id on top of the current
        state.  Returns (new weighted query totals, update-cost deltas),
        one entry per candidate, in one shot."""
        self.batch_scores += 1
        blk = self.blocks[table]
        ids = list(cand_ids)
        if blk.queries:
            if self.backend == "jax":
                q_tot = np.asarray(_jax_score_secondary(
                    cur_q, blk.cov[:, ids], blk.seek[:, ids],
                    blk.ridr[:, ids], blk.size[c_id], blk.beta[c_id],
                    blk.ncols_used, blk.q_w), dtype=np.float64)
            else:
                new_q = np.minimum(cur_q[:, None], blk.paths(ids, c_id))
                q_tot = blk.q_w @ new_q
        else:
            q_tot = np.zeros(len(ids))
        if blk.updates:
            upd_delta = blk.u_w @ blk.upd[:, ids]
        else:
            upd_delta = np.zeros(len(ids))
        return q_tot, upd_delta

    def score_replace_clustered(self, table: str, sec_ids: Sequence[int],
                                cand_ids: Sequence[int]
                                ) -> Tuple[np.ndarray, np.ndarray]:
        """Score swapping the clustered layout to each candidate id, keeping
        the current secondary set.  Returns (new weighted query totals,
        new clustered-update totals) per candidate."""
        self.batch_scores += 1
        blk = self.blocks[table]
        cids = list(cand_ids)
        sids = list(sec_ids)
        if blk.queries:
            if self.backend == "jax" and sids:
                q_tot = np.asarray(_jax_score_replace(
                    blk.scanc[:, cids], blk.cov[:, sids],
                    blk.seek[:, sids], blk.ridr[:, sids], blk.size[cids],
                    blk.beta[cids], blk.ncols_used, blk.q_w),
                    dtype=np.float64)
            else:
                new_q = blk.scanc[:, cids]                  # (nq, m)
                if sids:
                    # (nq, ns, m): every secondary path under every new
                    # layout
                    rid = cm.rid_lookup_cost(
                        blk.ridr[:, sids, None], blk.size[cids],
                        ncols_used=blk.ncols_used[:, None, None],
                        beta_coef=blk.beta[cids])
                    path = np.minimum(blk.cov[:, sids, None],
                                      blk.seek[:, sids, None] + rid)
                    new_q = np.minimum(new_q, path.min(axis=1))
                q_tot = blk.q_w @ new_q
        else:
            q_tot = np.zeros(len(cids))
        if blk.updates:
            upd_c = blk.u_w @ blk.upd[:, cids]
        else:
            upd_c = np.zeros(len(cids))
        return q_tot, upd_c


# ---------------------------------------------------------------------------
# Streamed costing for workloads too large to hold as dense matrices
# ---------------------------------------------------------------------------

def chunked_config_costs(workload: Workload, sizes: SizeProvider,
                         configs: Sequence[Configuration],
                         chunk_statements: int = 8192,
                         backend: str = "numpy") -> np.ndarray:
    """Full-workload cost of each configuration, streamed in statement
    chunks.

    Never materializes the full (statements x access-path) matrices: each
    chunk builds a short-lived engine over at most `chunk_statements`
    statements, scores every configuration against it, and accumulates the
    weighted totals — peak memory is O(chunk x registered paths) however
    large the workload.  The summation ORDER differs from a monolithic
    `CostEngine.config_cost` (per-chunk partial sums), so this is the
    memory-bounded evaluation path for huge workloads (the workload-
    compression benchmark's quality curve), not a bit-parity replacement
    for the in-core engine.
    """
    configs = list(configs)
    totals = np.zeros(len(configs))
    stmts = workload.statements
    if not stmts or not configs:
        return totals
    for lo in range(0, len(stmts), int(chunk_statements)):
        sub = Workload(schema=workload.schema,
                       statements=stmts[lo:lo + int(chunk_statements)])
        eng = CostEngine(sub, sizes, backend=backend)
        for k, cfg in enumerate(configs):
            totals[k] += eng.config_cost(cfg)
    return totals


# ---------------------------------------------------------------------------
# Cross-tenant stacked candidate costing (the fleet COST-phase prefetch)
# ---------------------------------------------------------------------------

def batched_candidate_costs(jobs: Sequence[Dict[str, object]],
                            backend: str = "numpy") -> np.ndarray:
    """Score many `CostEngine.cost_job_arrays` jobs in one stacked
    (job x candidate) pass.

    Per element this is EXACTLY the `candidate_query_costs` arithmetic
    for a secondary-free base — the same `cm.rid_lookup_cost` ufunc
    sequence on the numpy backend (bitwise), the same jit'd float32 op
    sequence on jax (`_jax_cand_costs_stacked`) — so a tenant whose
    costs were prefetched in a fleet batch recommends exactly what it
    would have recommended scoring alone.  Returns a (len(jobs), max_m)
    array; row i's first len(jobs[i]["cov"]) entries are live, the pad
    tail is meaningless.
    """
    J = len(jobs)
    m = max((len(j["cov"]) for j in jobs), default=0)
    if not J or not m:
        return np.zeros((J, m))

    def stack(key, fill):
        out = np.full((J, m), fill)
        for i, j in enumerate(jobs):
            out[i, :len(j[key])] = j[key]
        return out

    scan_l = stack("scan_l", 0.0)
    cov = stack("cov", np.inf)
    seek = stack("seek", np.inf)
    ridr = stack("ridr", 0.0)
    is_sec = stack("is_sec", False)
    size_c = np.array([j["size_c"] for j in jobs])[:, None]
    beta_c = np.array([j["beta_c"] for j in jobs])[:, None]
    ncq = np.array([j["ncq"] for j in jobs])[:, None]
    if backend == "jax" and HAVE_JAX:
        return np.asarray(_jax_cand_costs_stacked(
            scan_l, cov, seek, ridr, size_c, beta_c, ncq, is_sec),
            dtype=np.float64)
    rid = cm.rid_lookup_cost(ridr, size_c, ncols_used=ncq,
                             beta_coef=beta_c)
    own = np.where(is_sec, np.minimum(cov, seek + rid), np.inf)
    return np.minimum(scan_l, own)

"""Batched what-if cost engine: the advisor hot path as array code.

The scalar what-if path (repro.core.whatif) evaluates one (statement,
configuration) pair per Python call; `greedy_enumerate` multiplies that by
O(pool × statements) per greedy step, which is intractable for large
workloads (paper §5-§6 argue the tuning loop must scale).  This module
precomputes, per table, the full (statement × access-path) cost matrix so a
greedy step scores the *entire* candidate pool with a handful of vectorized
ops, and so adding an index on table T only re-evaluates statements on T
(incremental delta evaluation).

Decomposition used (mirrors `whatif.query_cost` exactly):

* A query's cost under configuration (c, S) — clustered layout `c` plus
  secondary set `S` — is

      min( SCANC[q, c],  min_{i in S} PATH[q, i, c] )
      PATH[q, i, c] = min( COV[q, i],  SEEK[q, i] + RID[q, i, c] )

  where COV (covering seek/scan) and SEEK (non-covering seek part) depend
  only on the candidate index, and RID (base-table RID lookups) couples the
  candidate with the *current clustered layout* through its page count and
  decompression coefficient.  All terms are evaluated with the ufunc-safe
  functions of repro.core.cost_model, so scalar and batched paths are
  formula-identical.

* A bulk insert's cost is additive over the table's indexes: UPD[u, i].

Registering an index computes its whole per-statement column in one
vectorized pass; columns live in capacity-doubling arrays so registration is
amortized O(statements) per index with no re-stacking.

Backends: plain NumPy (default, float64, bit-compatible with the scalar
reference) or an optional jax.jit backend for the per-step scoring kernel
(same idioms as repro.kernels.ops: jit + CPU fallback) — useful once pools
reach accelerator-worthy sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import cost_model as cm
from .relation import IndexDef, Predicate, Table
from .whatif import Configuration, SizeProvider, _partial_applicable
from .workload import BulkInsert, Query, Workload

try:  # optional accelerator backend (repro.kernels idiom: gate, don't require)
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    jax = None
    jnp = None
    HAVE_JAX = False

_INF = float("inf")


@dataclasses.dataclass
class TableEval:
    """Evaluated state of one table under a (clustered, secondaries) pair."""
    q_cost: np.ndarray      # per-query cost vector (nq,)
    q_total: float          # weighted query cost
    u_total: float          # weighted update-maintenance cost
    sec_upd: float          # update part contributed by secondaries only

    @property
    def total(self) -> float:
        return self.q_total + self.u_total


class _TableBlock:
    """Cost matrices for all registered access paths of one table.

    Columns (one per registered IndexDef) are stored in capacity-doubling
    arrays; evaluation always addresses them by explicit id lists, so no
    final assembly step is needed.
    """

    def __init__(self, table: Table, queries: Sequence[Query],
                 updates: Sequence[BulkInsert]):
        self.table = table
        self.queries = list(queries)
        self.updates = list(updates)
        nq, nu = len(self.queries), len(self.updates)
        self.q_w = np.array([q.weight for q in self.queries], dtype=np.float64)
        self.u_w = np.array([u.weight for u in self.updates], dtype=np.float64)
        self.u_rows = np.array([float(u.nrows) for u in self.updates])
        self.ncols_used = np.array([len(q.all_cols()) for q in self.queries],
                                   dtype=np.float64)
        # structural per-query caches (mirror whatif._covers /
        # whatif._prefix_selectivity without re-deriving per registration)
        self._q_cols_set = [frozenset(q.all_cols()) for q in self.queries]
        self._q_filt = [{p.col: p for p in q.filters} for q in self.queries]
        self._q_row = {q.name: qi for qi, q in enumerate(self.queries)}
        self._sel_cache: Dict[Predicate, float] = {}
        self._ids: Dict[Tuple, int] = {}       # IndexDef.key -> column id
        self._defs: List[IndexDef] = []
        self.n = 0
        self._cap = 0
        self.cov = np.empty((nq, 0))
        self.seek = np.empty((nq, 0))
        self.ridr = np.empty((nq, 0))
        self.scanc = np.empty((nq, 0))
        self.upd = np.empty((nu, 0))
        self.size = np.empty(0)
        self.beta = np.empty(0)

    def _grow(self, need: int) -> None:
        if need <= self._cap:
            return
        cap = max(16, 2 * self._cap, need)
        nq, nu = len(self.queries), len(self.updates)

        def g2(a: np.ndarray, rows: int) -> np.ndarray:
            out = np.empty((rows, cap))
            out[:, :a.shape[1]] = a
            return out

        def g1(a: np.ndarray) -> np.ndarray:
            out = np.empty(cap)
            out[:a.shape[0]] = a
            return out

        self.cov, self.seek = g2(self.cov, nq), g2(self.seek, nq)
        self.ridr, self.scanc = g2(self.ridr, nq), g2(self.scanc, nq)
        self.upd = g2(self.upd, nu)
        self.size, self.beta = g1(self.size), g1(self.beta)
        self._cap = cap

    def _sel(self, p: Predicate) -> float:
        s = self._sel_cache.get(p)
        if s is None:
            s = self._sel_cache[p] = p.selectivity(self.table)
        return s

    # -- registration ----------------------------------------------------
    def has(self, idx: IndexDef) -> bool:
        return idx.key in self._ids

    def id_of(self, idx: IndexDef) -> int:
        return self._ids[idx.key]

    def query_row(self, query: Query) -> int:
        return self._q_row[query.name]

    def add(self, idx: IndexDef, sizes: SizeProvider) -> int:
        j = self._ids.get(idx.key)
        if j is not None:
            return j
        t = self.table
        size = float(sizes.size(idx))
        nrows_idx = float(sizes.nrows(idx))
        nq = len(self.queries)
        j = self.n
        self._grow(j + 1)
        self._ids[idx.key] = j
        self._defs.append(idx)
        self.size[j] = size
        self.beta[j] = cm.beta_coef_of(idx.compression)
        self.n += 1

        if idx.clustered:
            # clustered layout: full scan path (whatif.query_cost's base)
            self.scanc[:, j] = cm.scan_cost(size, t.nrows, self.ncols_used,
                                            idx.compression)
            self.cov[:, j] = _INF
            self.seek[:, j] = _INF
            self.ridr[:, j] = 0.0
        else:
            self.scanc[:, j] = _INF
            # structural pass: applicability / covering / prefix selectivity
            sel = np.ones(nq)
            applicable = np.ones(nq, dtype=bool)
            covers = np.zeros(nq, dtype=bool)
            cols_set = set(idx.cols)
            for qi, q in enumerate(self.queries):
                if idx.predicate is not None \
                        and not _partial_applicable(idx, q):
                    applicable[qi] = False
                    continue
                covers[qi] = self._q_cols_set[qi] <= cols_set
                filt = self._q_filt[qi]
                s, matched = 1.0, False
                for c in idx.cols:
                    p = filt.get(c)
                    if p is None:
                        break
                    s *= self._sel(p)
                    matched = True
                sel[qi] = s if matched else 1.0
            # vectorized cost pass over the structural masks
            cov = np.full(nq, _INF)
            seek = np.full(nq, _INF)
            ridr = np.zeros(nq)
            m = applicable & covers & (sel < 1.0)
            cov[m] = cm.seek_cost(size, nrows_idx, sel[m],
                                  self.ncols_used[m], idx.compression)
            m = applicable & covers & (sel >= 1.0)
            cov[m] = cm.scan_cost(size, nrows_idx, self.ncols_used[m],
                                  idx.compression)
            m = applicable & ~covers & (sel < 1.0)
            seek[m] = cm.seek_cost(size, nrows_idx, sel[m],
                                   float(len(idx.cols)), idx.compression)
            ridr[m] = nrows_idx * sel[m]
            self.cov[:, j] = cov
            self.seek[:, j] = seek
            self.ridr[:, j] = ridr

        if self.updates:
            rows = self.u_rows
            if idx.predicate is not None:
                rows = rows * self._sel(idx.predicate)
            self.upd[:, j] = cm.update_cost(size, nrows_idx, rows,
                                            idx.compression)
        return j

    # -- evaluation ------------------------------------------------------
    def rid(self, ids, c: int) -> np.ndarray:
        """RID-lookup matrix (nq, len(ids)) under clustered layout `c`."""
        return cm.rid_lookup_cost(self.ridr[:, ids], self.size[c],
                                  ncols_used=self.ncols_used[:, None],
                                  beta_coef=self.beta[c])

    def paths(self, ids, c: int) -> np.ndarray:
        """Best per-query path cost (nq, len(ids)) via each secondary id."""
        return np.minimum(self.cov[:, ids],
                          self.seek[:, ids] + self.rid(ids, c))

    def eval(self, c: int, sec_ids: Sequence[int]) -> TableEval:
        q = self.scanc[:, c].copy()
        if len(sec_ids) and len(self.queries):
            q = np.minimum(q, self.paths(list(sec_ids), c).min(axis=1))
        q_total = float(self.q_w @ q) if len(self.queries) else 0.0
        sec_upd = 0.0
        u_total = 0.0
        if len(self.updates):
            u_vec = self.upd[:, c].copy()
            if len(sec_ids):
                sec_vec = self.upd[:, list(sec_ids)].sum(axis=1)
                sec_upd = float(self.u_w @ sec_vec)
                u_vec = u_vec + sec_vec
            u_total = float(self.u_w @ u_vec)
        return TableEval(q_cost=q, q_total=q_total, u_total=u_total,
                         sec_upd=sec_upd)


# ---------------------------------------------------------------------------
# Optional jax.jit scoring kernel (repro.kernels.ops idiom)
# ---------------------------------------------------------------------------

if HAVE_JAX:
    @jax.jit
    def _jax_score_secondary(cur_q, cov, seek, ridr, size_c, beta_c,
                             ncols_used, q_w):
        npages = jnp.maximum(size_c, 0.0) / cm.PAGE_BYTES
        rid = (cm.T_IO_RAND * jnp.minimum(ridr, npages)
               + cm.CPU_ROW * ridr
               + beta_c * ridr * ncols_used[:, None])
        path = jnp.minimum(cov, seek + rid)
        new_q = jnp.minimum(cur_q[:, None], path)
        return q_w @ new_q


class CostEngine:
    """Batched what-if engine over a workload and a SizeProvider.

    Register any IndexDef once; afterwards every cost query — single
    configurations, configuration batches, or whole-pool greedy-step scores —
    is evaluated from the precomputed per-table matrices.
    """

    def __init__(self, workload: Workload, sizes: SizeProvider,
                 backend: str = "numpy"):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "jax" and not HAVE_JAX:
            backend = "numpy"
        self.backend = backend
        self.workload = workload
        self.sizes = sizes
        self.blocks: Dict[str, _TableBlock] = {}
        for name, table in workload.schema.tables.items():
            qs = [s for s in workload.statements
                  if isinstance(s, Query) and s.table == name]
            us = [s for s in workload.statements
                  if isinstance(s, BulkInsert) and s.table == name]
            self.blocks[name] = _TableBlock(table, qs, us)
        self.config_evals = 0     # configurations costed via this engine
        self.batch_scores = 0     # vectorized pool-scoring calls

    # -- registration ----------------------------------------------------
    def register(self, idxs: Iterable[IndexDef]) -> np.ndarray:
        """Register every index; returns their engine column ids aligned
        with the input (so callers can precompute id arrays once instead
        of calling `id_of` per candidate per greedy step)."""
        return np.array([self.blocks[idx.table].add(idx, self.sizes)
                         for idx in idxs], dtype=np.int64)

    def id_of(self, idx: IndexDef) -> int:
        blk = self.blocks[idx.table]
        if not blk.has(idx):
            blk.add(idx, self.sizes)
        return blk.id_of(idx)

    # -- configuration costing -------------------------------------------
    def split(self, config: Configuration, table: str
              ) -> Tuple[int, List[int]]:
        blk = self.blocks[table]
        c_id = None
        sec: List[int] = []
        for idx in config.indexes:
            if idx.table != table:
                continue
            if not blk.has(idx):
                blk.add(idx, self.sizes)
            if idx.clustered:
                assert c_id is None, f"two clustered layouts for {table}"
                c_id = blk.id_of(idx)
            else:
                sec.append(blk.id_of(idx))
        assert c_id is not None, f"no clustered layout for {table}"
        return c_id, sec

    def table_eval(self, config: Configuration, table: str) -> TableEval:
        c_id, sec = self.split(config, table)
        return self.blocks[table].eval(c_id, sec)

    def config_cost(self, config: Configuration) -> float:
        """Workload cost of one configuration (parity with the scalar
        `WhatIfOptimizer.workload_cost`, modulo summation order)."""
        self.config_evals += 1
        total = 0.0
        for table, blk in self.blocks.items():
            if not blk.queries and not blk.updates:
                continue
            total += self.table_eval(config, table).total
        return total

    def config_costs(self, configs: Sequence[Configuration]) -> np.ndarray:
        return np.array([self.config_cost(c) for c in configs])

    # -- per-query candidate costing (candidate selection, §6.1) ----------
    def candidate_query_costs(self, query: Query, base: Configuration,
                              cands: Sequence[IndexDef]) -> np.ndarray:
        """Cost of `query` under base + each single candidate, batched.

        Mirrors the scalar `cost_candidates` loop: secondary candidates are
        added on top of `base`; clustered candidates replace the table's
        clustered layout.  Returns one cost per candidate, aligned with
        `cands`.
        """
        self.batch_scores += 1
        table = query.table
        blk = self.blocks[table]
        self.register(cands)
        c_id, sec_ids = self.split(base, table)
        qi = blk.query_row(query)
        ncq = blk.ncols_used[qi]

        def row_paths(ids, c):
            # single-query row of paths(): same formula, O(len(ids))
            rid = cm.rid_lookup_cost(blk.ridr[qi, ids], blk.size[c],
                                     ncols_used=ncq, beta_coef=blk.beta[c])
            return np.minimum(blk.cov[qi, ids], blk.seek[qi, ids] + rid)

        base_q = blk.scanc[qi, c_id]
        if sec_ids:
            base_q = min(base_q, float(row_paths(sec_ids, c_id).min()))

        out = np.empty(len(cands))
        sec_ks = [k for k, idx in enumerate(cands) if not idx.clustered]
        if sec_ks:
            ids = [blk.id_of(cands[k]) for k in sec_ks]
            out[sec_ks] = np.minimum(base_q, row_paths(ids, c_id))
        for k, idx in enumerate(cands):
            if not idx.clustered:
                continue
            cid2 = blk.id_of(idx)
            c = blk.scanc[qi, cid2]
            if sec_ids:
                c = min(c, float(row_paths(sec_ids, cid2).min()))
            out[k] = c
        return out

    # -- greedy-step scoring ---------------------------------------------
    def score_add_secondary(self, table: str, c_id: int, cur_q: np.ndarray,
                            cand_ids: Sequence[int]
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Score adding each candidate secondary id on top of the current
        state.  Returns (new weighted query totals, update-cost deltas),
        one entry per candidate, in one shot."""
        self.batch_scores += 1
        blk = self.blocks[table]
        ids = list(cand_ids)
        if blk.queries:
            if self.backend == "jax":
                q_tot = np.asarray(_jax_score_secondary(
                    cur_q, blk.cov[:, ids], blk.seek[:, ids],
                    blk.ridr[:, ids], blk.size[c_id], blk.beta[c_id],
                    blk.ncols_used, blk.q_w), dtype=np.float64)
            else:
                new_q = np.minimum(cur_q[:, None], blk.paths(ids, c_id))
                q_tot = blk.q_w @ new_q
        else:
            q_tot = np.zeros(len(ids))
        if blk.updates:
            upd_delta = blk.u_w @ blk.upd[:, ids]
        else:
            upd_delta = np.zeros(len(ids))
        return q_tot, upd_delta

    def score_replace_clustered(self, table: str, sec_ids: Sequence[int],
                                cand_ids: Sequence[int]
                                ) -> Tuple[np.ndarray, np.ndarray]:
        """Score swapping the clustered layout to each candidate id, keeping
        the current secondary set.  Returns (new weighted query totals,
        new clustered-update totals) per candidate."""
        self.batch_scores += 1
        blk = self.blocks[table]
        cids = list(cand_ids)
        sids = list(sec_ids)
        if blk.queries:
            new_q = blk.scanc[:, cids]                      # (nq, m)
            if sids:
                # (nq, ns, m): every secondary path under every new layout
                rid = cm.rid_lookup_cost(
                    blk.ridr[:, sids, None], blk.size[cids],
                    ncols_used=blk.ncols_used[:, None, None],
                    beta_coef=blk.beta[cids])
                path = np.minimum(blk.cov[:, sids, None],
                                  blk.seek[:, sids, None] + rid)
                new_q = np.minimum(new_q, path.min(axis=1))
            q_tot = blk.q_w @ new_q
        else:
            q_tot = np.zeros(len(cids))
        if blk.updates:
            upd_c = blk.u_w @ blk.upd[:, cids]
        else:
            upd_c = np.zeros(len(cids))
        return q_tot, upd_c

"""What-if API: optimizer-estimated statement cost under a hypothetical
configuration (paper §1, §3; the DTA architecture of Figure 1).

A Configuration is a set of IndexDef (one clustered layout per table plus
secondary indexes).  Sizes of compressed structures come from a SizeProvider
fed by the estimation framework (§4-§5); uncompressed sizes are analytic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, Optional, Tuple

from . import cost_model as cm
from .compression import uncompressed_payload_bytes
from .relation import IndexDef, Table
from .synopses import Schema
from .workload import BulkInsert, Query, Statement, Workload


class SizeProvider:
    """Maps IndexDef -> estimated physical bytes.

    Uncompressed indexes are sized analytically; compressed sizes must be
    registered (from the §5 estimation framework) or an analytic fallback
    CF prior is used (flagged, so the advisor always registers real ones).
    """

    DEFAULT_CF_PRIOR = 0.55

    def __init__(self, schema: Schema):
        self.schema = schema
        self._sizes: Dict[Tuple, float] = {}
        self.fallback_hits = 0

    @staticmethod
    def _key(idx: IndexDef) -> Tuple:
        return (idx.table, idx.cols, idx.compression, idx.predicate)

    def register(self, idx: IndexDef, est_bytes: float) -> None:
        self._sizes[self._key(idx)] = float(est_bytes)

    def analytic_uncompressed(self, idx: IndexDef) -> float:
        t = self.schema.tables[idx.table]
        widths = [t.col_by_name[c].width for c in idx.cols]
        nrows = t.nrows
        if idx.predicate is not None:
            nrows = int(round(nrows * idx.predicate.selectivity(t)))
        return float(uncompressed_payload_bytes(nrows, widths))

    def size(self, idx: IndexDef) -> float:
        if idx.compression is None:
            return self.analytic_uncompressed(idx)
        key = self._key(idx)
        if key in self._sizes:
            return self._sizes[key]
        self.fallback_hits += 1
        return self.analytic_uncompressed(idx) * self.DEFAULT_CF_PRIOR

    def nrows(self, idx: IndexDef) -> float:
        t = self.schema.tables[idx.table]
        if idx.predicate is not None:
            return t.nrows * idx.predicate.selectivity(t)
        return float(t.nrows)


@dataclasses.dataclass(frozen=True)
class Configuration:
    indexes: FrozenSet[IndexDef]

    @staticmethod
    def of(indexes: Iterable[IndexDef]) -> "Configuration":
        return Configuration(frozenset(indexes))

    def add(self, idx: IndexDef) -> "Configuration":
        return Configuration(self.indexes | {idx})

    def remove(self, idx: IndexDef) -> "Configuration":
        return Configuration(self.indexes - {idx})

    def replace(self, old: IndexDef, new: IndexDef) -> "Configuration":
        return Configuration((self.indexes - {old}) | {new})

    def for_table(self, table: str) -> Tuple[IndexDef, ...]:
        return tuple(sorted((i for i in self.indexes if i.table == table),
                            key=lambda i: i.label()))

    def clustered(self, table: str) -> Optional[IndexDef]:
        for i in self.indexes:
            if i.table == table and i.clustered:
                return i
        return None


def base_configuration(schema: Schema) -> Configuration:
    """Uncompressed clustered layout (heap) per table — the initial design."""
    idxs = []
    for t in schema.tables.values():
        cols = tuple(c.name for c in t.columns)
        idxs.append(IndexDef(t.name, cols, compression=None, clustered=True))
    return Configuration.of(idxs)


def storage_used(config: Configuration, base: Configuration,
                 sizes: SizeProvider) -> float:
    """Budget accounting: bytes beyond the uncompressed base layout.

    Compressing a clustered index *frees* budget (paper App. D.2: DTAc can
    produce indexes even at a 0% budget by compressing existing tables).
    """
    total = sum(sizes.size(i) for i in config.indexes)
    baseline = sum(sizes.size(i) for i in base.indexes)
    return total - baseline


# ---------------------------------------------------------------------------
# Optimizer: access-path selection (System-R-lite) with compression-aware CPU
# ---------------------------------------------------------------------------

def _prefix_selectivity(idx: IndexDef, query: Query, table: Table) -> float:
    """Selectivity of filters matching the index's leading key prefix."""
    filt = {p.col: p for p in query.filters}
    sel = 1.0
    matched = False
    for c in idx.cols:
        if c in filt:
            sel *= filt[c].selectivity(table)
            matched = True
        else:
            break
    return sel if matched else 1.0


def _covers(idx: IndexDef, query: Query) -> bool:
    return set(query.all_cols()) <= set(idx.cols)


def _partial_applicable(idx: IndexDef, query: Query) -> bool:
    if idx.predicate is None:
        return True
    for p in query.filters:
        if (p.col == idx.predicate.col and p.lo >= idx.predicate.lo
                and p.hi <= idx.predicate.hi):
            return True
    return False


def query_cost(query: Query, config: Configuration,
               sizes: SizeProvider) -> float:
    table = sizes.schema.tables[query.table]
    ncols_used = len(query.all_cols())
    clustered = config.clustered(query.table)
    assert clustered is not None, f"no clustered layout for {query.table}"

    base_size = sizes.size(clustered)
    best = cm.scan_cost(base_size, table.nrows, ncols_used,
                        clustered.compression)

    for idx in config.for_table(query.table):
        if idx.clustered or not _partial_applicable(idx, query):
            continue
        nrows_idx = sizes.nrows(idx)
        isize = sizes.size(idx)
        sel = _prefix_selectivity(idx, query, table)
        covering = _covers(idx, query)
        if covering:
            if sel < 1.0:
                cost = cm.seek_cost(isize, nrows_idx, sel, ncols_used,
                                    idx.compression)
            else:
                cost = cm.scan_cost(isize, nrows_idx, ncols_used,
                                    idx.compression)
        else:
            if sel >= 1.0:
                continue  # non-covering full scan is never chosen
            cost = cm.seek_cost(isize, nrows_idx, sel, len(idx.cols),
                                idx.compression)
            cost += cm.rid_lookup_cost(nrows_idx * sel, base_size,
                                       clustered.compression, ncols_used)
        best = min(best, cost)
    return best


def update_statement_cost(stmt: BulkInsert, config: Configuration,
                          sizes: SizeProvider) -> float:
    total = 0.0
    for idx in config.for_table(stmt.table):
        rows = stmt.nrows
        if idx.predicate is not None:
            t = sizes.schema.tables[idx.table]
            rows = rows * idx.predicate.selectivity(t)
        total += cm.update_cost(sizes.size(idx), sizes.nrows(idx), rows,
                                idx.compression)
    return total


class WhatIfOptimizer:
    """Cached what-if cost API (the Figure-1 'query optimizer extension').

    `statement_cost` / `workload_cost` are the scalar reference path;
    `workload_cost_batch` routes through the batched cost engine
    (repro.core.cost_engine) and scores many configurations at once.
    """

    def __init__(self, workload: Workload, sizes: SizeProvider):
        self.workload = workload
        self.sizes = sizes
        self._cache: Dict[Tuple, float] = {}
        self._engine = None
        self.calls = 0

    def statement_cost(self, stmt: Statement, config: Configuration) -> float:
        relevant = config.for_table(stmt.table)
        key = (stmt.name, tuple(i.key for i in relevant))
        if key not in self._cache:
            self.calls += 1
            if isinstance(stmt, Query):
                c = query_cost(stmt, config, self.sizes)
            else:
                c = update_statement_cost(stmt, config, self.sizes)
            self._cache[key] = c
        return self._cache[key]

    def workload_cost(self, config: Configuration) -> float:
        return sum(s.weight * self.statement_cost(s, config)
                   for s in self.workload.statements)

    def engine(self, backend: Optional[str] = None):
        """The batched cost engine bound to this optimizer's sizes.

        Built lazily so every size registered on the SizeProvider *before*
        the first batched call is picked up.  Sizes registered afterwards
        are not reflected (the scalar cache has the same staleness rule).

        `backend=None` (the default, and what internal callers such as
        `workload_cost_batch` pass) reuses whatever engine exists, building
        a numpy one if none does.  An explicit backend that differs from
        the current engine's resolved backend REBUILDS the engine from the
        provider's current sizes — switching is a fresh build, never an
        error (registered columns and statement deltas do not carry over).
        """
        from .cost_engine import CostEngine  # deferred: avoids cycle
        if self._engine is None:
            self._engine = CostEngine(self.workload, self.sizes,
                                      backend=backend or "numpy")
        elif backend is not None:
            from .backend import resolve as _resolve
            if self._engine.backend != _resolve(backend)[0]:
                self._engine = CostEngine(self.workload, self.sizes,
                                          backend=backend)
        return self._engine

    def workload_cost_batch(self, configs: Iterable[Configuration]):
        """Vectorized what-if: workload cost of each configuration.

        Returns a float64 array aligned with `configs`.  The scalar
        `workload_cost` remains the correctness reference; parity is
        exercised by tests/test_cost_engine.py.
        """
        return self.engine().config_costs(list(configs))

"""Batched SampleCF: size estimation for many targets as array code.

The scalar path (`repro.core.samplecf.sample_cf`) builds and compresses one
index per call; an estimation plan with hundreds of SAMPLED targets pays a
Python-level lexsort + five-odd NumPy kernel launches per target.  This
engine computes every SAMPLED target of a plan in a handful of grouped
kernel calls while staying byte-identical to the scalar reference.

Batch dimensions, in the paper's terms:

* **Group axis — (table, f):** the §4.1 amortization.  One uniform sample
  of fraction `f` per table is drawn (via `SampleManager`, so the sampling
  cost of §5.1 is paid once) and shared by every target on that table.
* **Target axis — (cols, method):** each target is one compressed index
  `I^c` whose SampleCF `CF = S^c / S` (§2.2) we estimate on the group's
  sample.  The §5.1 estimation cost charged per target is unchanged: the
  pages of the index built on the sample.
* **Job axis — (prefix, column):** the unit of batched work.  A target
  with key columns (c_0..c_k) needs, for each position j, the payload
  bytes of column c_j laid out in the target's sort order.  That sequence
  depends only on the key *prefix* (c_0..c_j) — lexicographic sort is
  refined, not reordered, by trailing key columns — so targets sharing a
  prefix share both the sort permutation and, for ORD-IND methods (which
  ignore order entirely), the per-column byte counts.

Concretely, per (table, f) group the engine:

1. collects the distinct (method, prefix, rows-per-page) jobs of all
   targets (ORD-IND jobs collapse to (method, column));
2. materializes one `np.lexsort` permutation per *maximal* prefix and
   reuses it for every shorter prefix it extends;
3. stacks the permuted columns into (ntargets, nrows) matrices grouped by
   (method, rows-per-page) and sizes them with the `*_bytes_batch` kernels
   of `repro.core.compression` (NumPy, or — under the unified
   `backend="jax"` of repro.core.backend — the bit-identical Pallas
   segment-reduce kernels in repro.kernels.codec_bytes);
4. assembles per-target compressed bytes, applies the same bias
   correction (`errors.samplecf_bias`) and full-table scaling as
   `sample_cf`, and returns `SizeEstimate`s that match the scalar path
   float-for-float.

Exactness (asserted in tests/test_estimation_engine.py and in
benchmarks/estimation_scaling.py): per-column integer byte counts equal the
scalar kernels', so `cf`, `est_bytes` and `cost_pages` are byte-identical.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import compression, distinct, errors
from .backend import resolve as _resolve
from .relation import IndexDef, Table, rows_per_page, uncompressed_pages
from .samplecf import SampleManager, SizeEstimate

# (cols, method) — method None means "uncompressed" (CF = 1.0)
TargetSpec = Tuple[Tuple[str, ...], Optional[str]]


def _resolve_backend(backend: str) -> str:
    # kept for backwards compatibility; warns + downgrades via core.backend
    return _resolve(backend, site="estimation_engine")[0]


def _prefix_permutations(sample: Table,
                         prefixes: Sequence[Tuple[str, ...]]
                         ) -> Dict[Tuple[str, ...], np.ndarray]:
    """One sort order per *maximal* prefix; shorter prefixes reuse it.

    Valid because a lexicographic sort by (c_0..c_k) orders the (c_0..c_j)
    tuples, j <= k, exactly as a sort by (c_0..c_j) does — trailing key
    columns only permute rows *within* groups of equal (c_0..c_j) values,
    where c_j is constant.

    The maximal prefixes themselves are sorted in ONE grouped call: each
    column is replaced by its dense rank (order-isomorphic, so the
    permutation is unchanged), ranks are bit-packed into a single int64
    key per prefix, and a stable row-wise argsort sorts the whole
    (nprefixes, nrows) key matrix at once.  A prefix whose packed ranks
    exceed 63 bits falls back to np.lexsort — both are stable sorts of the
    same key sequence, hence the identical permutation.
    """
    uniq = set(prefixes)
    parents = {p[:-1] for p in uniq if len(p) > 1}
    maximal = [p for p in uniq if p not in parents]

    ranks: Dict[str, np.ndarray] = {}
    bits: Dict[str, int] = {}

    def rank_of(c: str) -> np.ndarray:
        r = ranks.get(c)
        if r is None:
            u, inv = np.unique(sample.values[c], return_inverse=True)
            r = ranks[c] = inv.astype(np.int64, copy=False)
            bits[c] = max(int(u.size - 1).bit_length(), 1)
        return r

    out: Dict[Tuple[str, ...], np.ndarray] = {}
    packable: List[Tuple[str, ...]] = []
    for p in maximal:
        for c in p:
            rank_of(c)
        if sum(bits[c] for c in p) <= 63:
            packable.append(p)
        else:
            out[p] = np.lexsort([sample.values[c] for c in reversed(p)])
    if packable:
        # depth-wise batched packing: keys[i] = fold over p of (k << b) | r
        cols_used = sorted(ranks)
        cidx = {c: i for i, c in enumerate(cols_used)}
        rmat = np.stack([ranks[c] for c in cols_used])
        bvec = np.array([bits[c] for c in cols_used], dtype=np.int64)
        keys = rmat[[cidx[p[0]] for p in packable]].copy()
        maxlen = max(len(p) for p in packable)
        for d in range(1, maxlen):
            sel = np.array([i for i, p in enumerate(packable) if len(p) > d])
            if not sel.size:
                continue
            ci = np.array([cidx[packable[i][d]] for i in sel])
            keys[sel] = (keys[sel] << bvec[ci, None]) | rmat[ci]
        perms = np.argsort(keys, axis=1, kind="stable")
        for i, p in enumerate(packable):
            out[p] = perms[i]
    # every needed non-maximal prefix is an ancestor of some maximal one
    for p in maximal:
        perm = out[p]
        for j in range(len(p) - 1, 0, -1):
            anc = p[:j]
            if anc in uniq and anc not in out:
                out[anc] = perm
    return out


def batched_sample_cf(table: Table, sample: Table,
                      specs: Sequence[TargetSpec], f: float,
                      bias_correct: bool = True,
                      backend: str = "numpy") -> List[SizeEstimate]:
    """SampleCF for every (cols, method) spec on one shared sample.

    `table` provides column widths and the full-index row count used to
    scale CF back up (§2.2); `sample` is the (table, f) sample the indexes
    are built on.  Returns estimates aligned with `specs`, byte-identical
    to calling `sample_cf` per target.
    """
    n = sample.nrows
    widths_of = {c.name: table.col_by_name[c.name].width
                 for c in sample.columns}

    def rpp_key(rpp: int) -> int:
        # Any rows-per-page >= n yields a single page holding all n rows,
        # and single-page sizes are rpp-independent (padding repeats the
        # last value, which adds no distinct values, runs, or min/max
        # movement) — so such jobs collapse into one per (method, prefix).
        return rpp if 0 < rpp < n else max(n, 1)

    # ---- collect the distinct sizing jobs across all targets ----
    ordind_jobs = set()           # (method, col)
    orddep_jobs = set()           # (method, prefix, rpp_key)
    gdict_jobs = set()            # col — AE-priced at full cardinality
    for cols, method in specs:
        if method is None:
            continue
        rpp = rpp_key(rows_per_page(sum(widths_of[c] for c in cols)))
        order_dep = compression.METHODS[method].order_dependent
        for j, c in enumerate(cols):
            if method == "GDICT":
                gdict_jobs.add(c)
            elif order_dep:
                orddep_jobs.add((method, cols[:j + 1], rpp))
            else:
                ordind_jobs.add((method, c))

    # ---- closed forms for single-page order-dependent jobs ----
    # When the whole sample fits in one page, LDICT's page dictionary sees
    # the column's full multiset (ndv) and PREFIX sees its global min/max —
    # both independent of the sort order — so these jobs reduce to O(1)
    # arithmetic on per-column stats the sample Table already caches.
    col_bytes: Dict[Tuple, int] = {}
    kernel_jobs = set()
    single = max(n, 1)
    for job in orddep_jobs:
        method, prefix, rpp = job
        c = prefix[-1]
        w = widths_of[c]
        cap = n * w + compression.PAGE_META
        if rpp == single and method == "LDICT":
            ndv = sample.ndv([c])
            ptr = int(compression._ptr_bytes(ndv))
            col_bytes[job] = min(ndv * w + n * ptr + compression.PAGE_META,
                                 cap)
        elif rpp == single and method == "PREFIX":
            mn, mx = sample.minmax(c)
            # uint64 semantics, like the kernel's significant_bytes cast
            # (Table enforces non-negative values, so this is defensive)
            xor = (mn ^ mx) & 0xFFFFFFFFFFFFFFFF
            diff_bytes = (xor.bit_length() + 7) // 8  # significant_bytes
            common = max(w - diff_bytes, 0)
            col_bytes[job] = min(
                common + n * (1 + w - common) + compression.PAGE_META, cap)
        else:
            kernel_jobs.add(job)

    # ---- GDICT: App. B Adaptive-Estimator pricing (samplecf parity) ----
    # The sample's dictionary is nearly all-distinct at small f, so GDICT
    # sizes are not CF-scaled; the shared `gdict_estimated_col_bytes`
    # estimates full-table NDV per column and prices the full index
    # directly — bit-identical to the scalar sample_cf GDICT path (the
    # estimator only depends on the sample's value multiset).
    gdict_bytes: Dict[str, float] = {
        c: distinct.gdict_estimated_col_bytes(sample.values[c],
                                              widths_of[c], table.nrows)
        for c in gdict_jobs}

    perms = _prefix_permutations(
        sample, [p for (_, p, _) in kernel_jobs]) if kernel_jobs else {}

    # ---- grouped kernel calls ----
    by_method: Dict[str, List[Tuple[str, ...]]] = {}
    for method, c in ordind_jobs:
        by_method.setdefault(method, []).append(c)
    for method, jcols in by_method.items():
        # ORD-IND sizes ignore row order: use raw sample order
        mat = np.stack([sample.values[c] for c in jcols])
        w = np.array([widths_of[c] for c in jcols], dtype=np.int64)
        got = compression.batched_bytes(method, mat, w, rows_per_page(1),
                                        backend=backend)
        for c, b in zip(jcols, got):
            col_bytes[(method, c)] = int(b)

    by_group: Dict[Tuple[str, int], List[Tuple[str, ...]]] = {}
    for method, prefix, rpp in kernel_jobs:
        by_group.setdefault((method, rpp), []).append(prefix)
    for (method, rpp), prefixes in by_group.items():
        mat = np.stack([sample.values[p[-1]][perms[p]] for p in prefixes])
        w = np.array([widths_of[p[-1]] for p in prefixes], dtype=np.int64)
        got = compression.batched_bytes(method, mat, w, rpp, backend=backend)
        for p, b in zip(prefixes, got):
            col_bytes[(method, p, rpp)] = int(b)

    # ---- per-target assembly (same float ops, same order, as sample_cf) --
    colset_cache: Dict[Tuple[str, ...], Tuple] = {}

    def colset_consts(cols: Tuple[str, ...]) -> Tuple:
        got = colset_cache.get(cols)
        if got is None:
            widths = [widths_of[c] for c in cols]
            got = colset_cache[cols] = (
                rpp_key(rows_per_page(sum(widths))),
                compression.uncompressed_payload_bytes(n, widths),
                compression.uncompressed_payload_bytes(table.nrows, widths),
                float(uncompressed_pages(n, widths)))
        return got

    out: List[SizeEstimate] = []
    for cols, method in specs:
        rpp, s, full_bytes, cost = colset_consts(tuple(cols))
        if method is None or n == 0 or s == 0:
            cf = 1.0
        elif method == "GDICT":
            # full-cardinality AE pricing (same op order as sample_cf)
            sc = table.nrows * compression.ROW_OVERHEAD
            for c in cols:
                sc = sc + gdict_bytes[c]
            cf = sc / full_bytes
            if bias_correct:
                cf = min(cf / errors.samplecf_bias(method, f), 1.0)
        else:
            order_dep = compression.METHODS[method].order_dependent
            sc = n * compression.ROW_OVERHEAD
            for j, c in enumerate(cols):
                sc += col_bytes[(method, cols[:j + 1], rpp)] if order_dep \
                    else col_bytes[(method, c)]
            cf = sc / s
            if bias_correct:
                cf = min(cf / errors.samplecf_bias(method, f), 1.0)
        out.append(SizeEstimate(
            index=IndexDef(table.name, tuple(cols), method),
            est_bytes=cf * full_bytes, method="samplecf",
            cost_pages=cost, cf=cf))
    return out


class EstimationEngine:
    """Batched SampleCF over a schema and an amortized sample store.

    Accepts any target objects carrying `.table`, `.cols` and `.method`
    (`estimation_graph.NodeKey` in the advisor pipeline) and estimates all
    of them per (table, f) group in grouped kernel calls.
    """

    def __init__(self, tables: Dict[str, Table],
                 manager: Optional[SampleManager] = None,
                 backend: str = "numpy", seed: int = 0, faults=None):
        self.tables = dict(tables)
        self.manager = manager if manager is not None else \
            SampleManager(self.tables, seed=seed)
        self.backend, fell_back = _resolve(backend, site="estimation_engine")
        # optional faults.FaultInjector; site "estimation" fires a
        # transient FaultError before any sampling work happens, so a
        # faulted batch is cleanly retryable
        self.faults = faults
        self.batch_calls = 0        # per-(table, f) group batches run
        self.targets_estimated = 0  # total targets sized through the engine
        self.backend_fallbacks = int(fell_back)  # jax requested, numpy ran

    def stats(self) -> Dict[str, int]:
        return {"batch_calls": self.batch_calls,
                "targets_estimated": self.targets_estimated,
                "backend_fallbacks": self.backend_fallbacks}

    def estimate_batch(self, targets: Sequence, f: float,
                       bias_correct: bool = True) -> Dict:
        """SizeEstimate for every target, keyed by the target objects."""
        if self.faults is not None:
            self.faults.check("estimation", f"estimate_batch of "
                              f"{len(targets)} targets at f={f}")
        by_table: Dict[str, List] = {}
        for t in targets:
            by_table.setdefault(t.table, []).append(t)
        out: Dict = {}
        for tname, ts in by_table.items():
            sample = self.manager.get_sample(tname, f)
            ests = batched_sample_cf(
                self.tables[tname], sample, [(t.cols, t.method) for t in ts],
                f, bias_correct=bias_correct, backend=self.backend)
            out.update(zip(ts, ests))
            self.batch_calls += 1
            self.targets_estimated += len(ts)
        return out

# Compression Aware Physical Database Design (Kimura, Narasayya, Syamala;
# PVLDB 4(10), 2011) — faithful reproduction of the paper's algorithms:
# compression methods + SampleCF + deduction (§2, §4), the estimation-plan
# graph search (§5), skyline candidate selection + backtracking greedy
# enumeration (§6), the compression-aware what-if cost model (App. A), and
# join synopses / Adaptive-Estimator MV cardinalities (App. B).
from .advisor import AdvisorOptions, DesignAdvisor, Recommendation
from .compression import DEFAULT_ADVISOR_METHODS, METHODS
from .durability import DurableStore, LogCorrupt, RecoveredTenant
from .session import AdvisorSession, SessionSnapshot, SnapshotCorrupt
from .cost_engine import CostEngine, chunked_config_costs
from .estimation_engine import EstimationEngine, batched_sample_cf
from .estimation_graph import EstimationPlanner, NodeKey, Plan, State
from .faults import FaultError, FaultInjector, FaultSpec
from .planner_engine import PlannerEngine
from .relation import ColumnDef, IndexDef, Predicate, Table
from .samplecf import EstimateCache, SampleManager, sample_cf
from .synopses import ForeignKey, MVDef, Schema, SynopsisManager
from .whatif import Configuration, SizeProvider, WhatIfOptimizer, \
    base_configuration, storage_used
from .workload import BulkInsert, Query, Workload, WorkloadDelta, \
    make_scaled_workload, make_scaled_workload_reference, make_tpch_like, \
    make_tpch_workload
from .workload_compression import ClusterIndex, CompressedWorkload, \
    compress_workload

__all__ = [
    "AdvisorOptions", "DesignAdvisor", "Recommendation", "AdvisorSession",
    "SessionSnapshot", "SnapshotCorrupt",
    "DurableStore", "LogCorrupt", "RecoveredTenant",
    "DEFAULT_ADVISOR_METHODS", "METHODS", "CostEngine",
    "chunked_config_costs",
    "ClusterIndex", "CompressedWorkload", "compress_workload",
    "EstimationEngine", "batched_sample_cf",
    "EstimationPlanner", "NodeKey", "Plan", "State", "PlannerEngine",
    "FaultError", "FaultInjector", "FaultSpec",
    "ColumnDef", "IndexDef", "Predicate", "Table",
    "EstimateCache", "SampleManager", "sample_cf",
    "ForeignKey", "MVDef", "Schema", "SynopsisManager",
    "Configuration", "SizeProvider", "WhatIfOptimizer",
    "base_configuration", "storage_used",
    "BulkInsert", "Query", "Workload", "WorkloadDelta",
    "make_scaled_workload", "make_scaled_workload_reference",
    "make_tpch_like", "make_tpch_workload",
]

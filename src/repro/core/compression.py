"""Compression methods (paper §2.1).

Five methods: NULL suppression (NS), global dictionary (GDICT), page-local
dictionary (LDICT), prefix suppression (PREFIX), run-length encoding (RLE).

NS and GDICT are order-INdependent (ORD-IND): the compressed size depends only
on the multiset of values.  LDICT, PREFIX and RLE are order-DEPENDENT
(ORD-DEP): the size depends on how values are distributed across pages, i.e.
on the index sort order (paper Figure 2).

All sizes are *payload bytes*; the cost model converts bytes -> pages.
Everything is vectorized NumPy so SampleCF and full-index sizing are cheap.

Each scalar kernel `_<m>_bytes(col, width, rpp)` has a batched twin
`<m>_bytes_batch(cols, widths, rpp)` operating on an (ntargets, nrows)
column stack — one row per (target, column) job, all rows sharing the same
rows-per-page — returning one payload-byte count per row.  The batched
kernels are exact integer re-expressions of the scalar ones (asserted
property-by-property in tests/test_core_compression.py) so the estimation
engine built on them is byte-identical to per-target SampleCF.

Backend architecture (see repro.core.backend): `batched_bytes(...,
backend="jax")` dispatches to the Pallas segment-reduce kernels in
repro.kernels.codec_bytes, which are BIT-IDENTICAL to the NumPy batch
kernels (int32-safe uint32-plane math — the old int64/x64 gate is gone;
parity asserted in tests/test_pallas_parity.py).  When jax is unavailable
the dispatcher runs the NumPy kernels; the unified-backend engines
surface that fallback via warnings + stats counters (repro.core.backend).
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

import numpy as np

from .relation import ROW_OVERHEAD, rows_per_page

try:  # optional accelerator backend (repro.kernels idiom: gate, don't require)
    import jax  # noqa: F401
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    HAVE_JAX = False

ORD_IND = "ORD-IND"
ORD_DEP = "ORD-DEP"

# per-page dictionary/metadata overhead for page-local methods
PAGE_META = 16


def significant_bytes(v: np.ndarray) -> np.ndarray:
    """Bytes needed to represent each value (leading zero bytes stripped)."""
    v = np.asarray(v, dtype=np.uint64)
    out = np.ones(v.shape, dtype=np.int64)
    for k in range(1, 8):
        out += (v >= np.uint64(1) << np.uint64(8 * k)).astype(np.int64)
    return out


def _pages(col: np.ndarray, rpp: int) -> np.ndarray:
    """Reshape a column (in index order) into (npages, rpp), edge-padded."""
    n = col.shape[0]
    npages = -(-n // rpp)
    pad = npages * rpp - n
    if pad:
        col = np.concatenate([col, np.repeat(col[-1], pad)])
    return col.reshape(npages, rpp), n


def _ptr_bytes(ndv) -> np.ndarray:
    """Bytes for a dictionary pointer addressing `ndv` entries."""
    ndv = np.asarray(ndv, dtype=np.int64)
    return np.where(ndv <= 256, 1, np.where(ndv <= 65536, 2, 3)).astype(np.int64)


# ---------------------------------------------------------------------------
# Per-column compressed sizes. data is (nrows, ncols) in index order.
# ---------------------------------------------------------------------------

def _ns_bytes(col: np.ndarray, width: int, rpp: int) -> int:
    # 4-bit length descriptor per value (SQL Server row-compression style),
    # never exceeding the uncompressed width.
    sig = np.minimum(significant_bytes(col), width)
    half_bytes = np.minimum(2 * sig + 1, 2 * width)
    return int((int(np.sum(half_bytes)) + 1) // 2)


def _gdict_bytes(col: np.ndarray, width: int, rpp: int) -> int:
    ndv = int(np.unique(col).size)
    ptr = int(_ptr_bytes(ndv))
    return ndv * width + col.shape[0] * ptr


def _ldict_bytes(col: np.ndarray, width: int, rpp: int) -> int:
    pages, n = _pages(col, rpp)
    srt = np.sort(pages, axis=1)
    ndv_p = 1 + np.count_nonzero(np.diff(srt, axis=1), axis=1)
    ptr = _ptr_bytes(ndv_p)
    # per-page: dictionary entries + per-row pointers (+ page metadata)
    rows_in_page = np.full(pages.shape[0], rpp, dtype=np.int64)
    if n % rpp:
        rows_in_page[-1] = n % rpp
    per_page = ndv_p * width + rows_in_page * ptr + PAGE_META
    cap = rows_in_page * width  # never bigger than uncompressed
    return int(np.sum(np.minimum(per_page, cap + PAGE_META)))


def _prefix_bytes(col: np.ndarray, width: int, rpp: int) -> int:
    pages, n = _pages(col, rpp)
    mn = pages.min(axis=1).astype(np.uint64)
    mx = pages.max(axis=1).astype(np.uint64)
    xor = mn ^ mx
    diff_bytes = np.where(xor == 0, 0, significant_bytes(xor))
    common = np.maximum(width - diff_bytes, 0)
    rows_in_page = np.full(pages.shape[0], rpp, dtype=np.int64)
    if n % rpp:
        rows_in_page[-1] = n % rpp
    # page stores the prefix once; rows store 1 marker + suffix bytes
    per_page = common + rows_in_page * (1 + width - common) + PAGE_META
    cap = rows_in_page * width
    return int(np.sum(np.minimum(per_page, cap + PAGE_META)))


def _rle_bytes(col: np.ndarray, width: int, rpp: int) -> int:
    pages, n = _pages(col, rpp)
    runs = 1 + np.count_nonzero(np.diff(pages, axis=1), axis=1)
    rows_in_page = np.full(pages.shape[0], rpp, dtype=np.int64)
    if n % rpp:
        rows_in_page[-1] = n % rpp
    per_page = runs * (width + 2) + PAGE_META  # value + 2-byte run length
    cap = rows_in_page * width
    return int(np.sum(np.minimum(per_page, cap + PAGE_META)))


# ---------------------------------------------------------------------------
# Batched per-method kernels.  cols is an (ntargets, nrows) stack — one row
# per (target, column) sizing job, every row in its target's index order —
# widths is (ntargets,), rpp is shared by the whole stack (the estimation
# engine groups jobs by rows-per-page).  Returns (ntargets,) payload bytes,
# exactly equal to applying the scalar kernel row by row.
# ---------------------------------------------------------------------------

def _rows_in_pages(n: int, rpp: int) -> np.ndarray:
    """Rows actually stored in each of the ceil(n/rpp) pages."""
    npages = -(-n // rpp)
    rows = np.full(npages, rpp, dtype=np.int64)
    if n % rpp:
        rows[-1] = n % rpp
    return rows


def _pages_batch(cols: np.ndarray, rpp: int) -> np.ndarray:
    """(m, n) -> (m, npages, rpp), each row edge-padded with its last value."""
    m, n = cols.shape
    npages = -(-n // rpp)
    pad = npages * rpp - n
    if pad:
        cols = np.concatenate([cols, np.repeat(cols[:, -1:], pad, axis=1)],
                              axis=1)
    return cols.reshape(m, npages, rpp)


def _batch_io(cols, widths) -> tuple:
    cols = np.asarray(cols, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    return cols, widths


def ns_bytes_batch(cols: np.ndarray, widths: np.ndarray,
                   rpp: int) -> np.ndarray:
    cols, widths = _batch_io(cols, widths)
    if cols.shape[1] == 0:
        return np.zeros(cols.shape[0], dtype=np.int64)
    sig = np.minimum(significant_bytes(cols), widths[:, None])
    half_bytes = np.minimum(2 * sig + 1, 2 * widths[:, None])
    return (half_bytes.sum(axis=1) + 1) // 2


def gdict_bytes_batch(cols: np.ndarray, widths: np.ndarray,
                      rpp: int) -> np.ndarray:
    cols, widths = _batch_io(cols, widths)
    m, n = cols.shape
    if n == 0:
        return np.zeros(m, dtype=np.int64)
    srt = np.sort(cols, axis=1)
    ndv = 1 + np.count_nonzero(np.diff(srt, axis=1), axis=1)
    return ndv * widths + n * _ptr_bytes(ndv)


def ldict_bytes_batch(cols: np.ndarray, widths: np.ndarray,
                      rpp: int) -> np.ndarray:
    cols, widths = _batch_io(cols, widths)
    m, n = cols.shape
    if n == 0:
        return np.zeros(m, dtype=np.int64)
    pages = _pages_batch(cols, rpp)
    srt = np.sort(pages, axis=2)
    ndv_p = 1 + np.count_nonzero(np.diff(srt, axis=2), axis=2)  # (m, npages)
    rows = _rows_in_pages(n, rpp)[None, :]
    w = widths[:, None]
    per_page = ndv_p * w + rows * _ptr_bytes(ndv_p) + PAGE_META
    cap = rows * w
    return np.minimum(per_page, cap + PAGE_META).sum(axis=1)


def prefix_bytes_batch(cols: np.ndarray, widths: np.ndarray,
                       rpp: int) -> np.ndarray:
    cols, widths = _batch_io(cols, widths)
    m, n = cols.shape
    if n == 0:
        return np.zeros(m, dtype=np.int64)
    pages = _pages_batch(cols, rpp)
    mn = pages.min(axis=2).astype(np.uint64)
    mx = pages.max(axis=2).astype(np.uint64)
    xor = mn ^ mx
    diff_bytes = np.where(xor == 0, 0, significant_bytes(xor))
    rows = _rows_in_pages(n, rpp)[None, :]
    w = widths[:, None]
    common = np.maximum(w - diff_bytes, 0)
    per_page = common + rows * (1 + w - common) + PAGE_META
    cap = rows * w
    return np.minimum(per_page, cap + PAGE_META).sum(axis=1)


def rle_bytes_batch(cols: np.ndarray, widths: np.ndarray,
                    rpp: int) -> np.ndarray:
    cols, widths = _batch_io(cols, widths)
    m, n = cols.shape
    if n == 0:
        return np.zeros(m, dtype=np.int64)
    pages = _pages_batch(cols, rpp)
    runs = 1 + np.count_nonzero(np.diff(pages, axis=2), axis=2)
    rows = _rows_in_pages(n, rpp)[None, :]
    w = widths[:, None]
    per_page = runs * (w + 2) + PAGE_META
    cap = rows * w
    return np.minimum(per_page, cap + PAGE_META).sum(axis=1)


# ---------------------------------------------------------------------------
# Accelerator dispatch.  backend="jax" routes to the Pallas segment-reduce
# kernels (repro.kernels.codec_bytes): bit-identical int32-safe math via
# uint32 planes — no x64 requirement.  Inputs outside the kernels' proven
# int32 envelope are routed back to the NumPy kernels by the kernels
# module itself, so the dispatcher is exact for every input either way.
# ---------------------------------------------------------------------------

def jax_batch_ready() -> bool:
    """True when the accelerated batch kernels can run (exactly)."""
    return HAVE_JAX


BATCH_KERNELS: Dict[str, Callable[[np.ndarray, np.ndarray, int], np.ndarray]] \
    = {
    "NS": ns_bytes_batch,
    "GDICT": gdict_bytes_batch,
    "LDICT": ldict_bytes_batch,
    "PREFIX": prefix_bytes_batch,
    "RLE": rle_bytes_batch,
}


def batched_bytes(method: str, cols: np.ndarray, widths: np.ndarray,
                  rpp: int, backend: str = "numpy") -> np.ndarray:
    """Per-row payload bytes of `method` over an (ntargets, nrows) stack."""
    if backend == "jax" and jax_batch_ready():
        from ..kernels import codec_bytes as _ck
        return _ck.batched_codec_bytes(method, cols, widths, rpp)
    return BATCH_KERNELS[method](cols, widths, rpp)


class Method:
    def __init__(self, name: str, kind: str,
                 fn: Callable[[np.ndarray, int, int], int],
                 alpha: float, beta: float):
        self.name = name
        self.kind = kind          # ORD_IND or ORD_DEP
        self._fn = fn
        # cost-model constants (paper App. A): alpha = CPU to compress one
        # tuple on update; beta = CPU to decompress one column of one tuple.
        self.alpha = alpha
        self.beta = beta

    @property
    def order_dependent(self) -> bool:
        return self.kind == ORD_DEP

    def compressed_bytes(self, data: np.ndarray, widths: Sequence[int]) -> int:
        """Payload bytes of the compressed index (data in index order)."""
        rw = int(sum(widths))
        rpp = rows_per_page(rw)
        total = data.shape[0] * ROW_OVERHEAD
        for j, w in enumerate(widths):
            total += self._fn(data[:, j], int(w), rpp)
        return int(total)


# alpha/beta loosely follow the paper's ROW-vs-PAGE ordering: page-local
# methods cost more CPU than row methods (App. A; [13] microbenchmarks).
METHODS: Dict[str, Method] = {
    "NS":     Method("NS", ORD_IND, _ns_bytes, alpha=1.0, beta=0.20),
    "GDICT":  Method("GDICT", ORD_IND, _gdict_bytes, alpha=1.5, beta=0.25),
    "LDICT":  Method("LDICT", ORD_DEP, _ldict_bytes, alpha=2.5, beta=0.45),
    "PREFIX": Method("PREFIX", ORD_DEP, _prefix_bytes, alpha=2.0, beta=0.35),
    "RLE":    Method("RLE", ORD_DEP, _rle_bytes, alpha=1.8, beta=0.30),
}

# The two "packages" the advisor offers by default, mirroring SQL Server's
# ROW (null suppression) and PAGE (local dictionary) compression.
DEFAULT_ADVISOR_METHODS = ("NS", "LDICT")


def uncompressed_payload_bytes(nrows: int, widths: Sequence[int]) -> int:
    return nrows * (int(sum(widths)) + ROW_OVERHEAD)


def compressed_payload_bytes(method: str, data: np.ndarray,
                             widths: Sequence[int]) -> int:
    return METHODS[method].compressed_bytes(data, widths)

"""Batched §5.2 deduction planner: the greedy graph search as array code.

The scalar planner (`EstimationPlanner.greedy_scalar`) walks the targets
narrow-to-wide and, per target, scores every candidate deduction with
Python-level RV composition and erf calls — then the §5.2 outer loop repeats
the whole walk for every sampling fraction on F_GRID.  After PRs 1-2 batched
what-if costing and SampleCF execution, this walk is the advisor's last
scalar hot path (~0.7s of ~0.8s `estimate_sizes` at 200 statements).

This engine runs the greedy for **all fractions in one pass over a shared
deduction graph**:

* **Graph build (f-independent, built once).**  The node universe and each
  target's candidate-deduction set do not depend on f: ColSet mates can only
  be pre-existing nodes (existing indexes + targets), never nodes
  materialized mid-walk — a materialized child is strictly narrower than its
  creator, and the walk is narrow-to-wide, so it can never share a column
  set with a later target.  The build therefore records, per target in
  processing order, the candidate `Deduction`s with their children packed
  into (ncand, K) id/kind arrays (EXACT-padded), plus the deduction-error
  term of each candidate.

* **Per-(node, f) state arrays.**  Decisions differ across fractions, so
  node state / error-RV mean / error-RV std live in (nnodes, nf) arrays.
  One pass over the targets then scores lines 6-9 of the §5.2 pseudocode
  for a target's whole candidate set, for every f, in a handful of NumPy
  calls: `errors.goodman_fold` (the sequential-fold core of
  `errors.compose_batch`, continued with the deduction-error factor) and
  `errors.prob_within_batch` (vectorized erf over the mask-compressed
  eligible entries, memoized).

* **(node × f) sampling-cost matrix.**  §5.1 sampling costs are pure in
  table stats, so the lines 8-9 "enable by sampling unknown children"
  comparison is an argmin over `extra = Σ cost(unknown child)` arrays.

Parity: decisions reduce to comparisons of floats produced by the same
IEEE operations in the same order as the scalar reference (see
`errors.compose_batch` / `errors.prob_within_batch`), so the engine is
**plan-identical** to `greedy_scalar` — same per-node states, same chosen
deductions, same `total_cost`, for every f — asserted in
tests/test_core_estimation.py, tests/test_estimation_engine.py and in
benchmarks/estimation_scaling.py.

Backend architecture (see repro.core.backend): under the unified
`backend="jax"` the candidate-scoring step runs through the Pallas
kernels in repro.kernels.planner_score — `fused_score` fuses the Goodman
fold, the deduction-error continuation and the masked accuracy
probability of a whole (candidate x f) record into one float32 kernel,
and `prob_within` is the matching probability stage used by the memoized
`_prob_cached` path (feasibility, replay verification).  Both kernels
share one probability op sequence, so a probability recomputed from a
stored (mean, std) pair — buf values are float32-exact once written — is
bit-identical to the fused in-line value: replay, `_verify_changed` and
session-vs-fresh plan equality stay exact WITHIN the jax backend.  The
jax backend is NOT bit-parity with numpy (a different erf, float32
arithmetic); the NumPy backend remains the parity reference against the
scalar planner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import errors as err
from .backend import resolve as _resolve_backend
from .compression import METHODS
from .estimation_graph import (Deduction, F_GRID, Node, NodeKey, Plan, State,
                               _colext_deductions, _colset_ded,
                               memoized_sampling_cost)

# state codes (match estimation_graph.State member order)
_NONE, _DEDUCED, _SAMPLED, _EXACT = 0, 1, 2, 3
_STATE_OF = {_DEDUCED: State.DEDUCED, _SAMPLED: State.SAMPLED,
             _EXACT: State.EXACT}


def _kind_code(method: str) -> int:
    return 1 if METHODS[method].order_dependent else 0


def assert_plan_identical(ref: Plan, got: Plan, label: str = "") -> None:
    """The engine's parity contract vs `EstimationPlanner.greedy_scalar`:
    same nodes, states, chosen deductions, error RVs, exact sizes,
    total_cost and feasibility.  Shared by the parity tests and
    benchmarks/estimation_scaling.py so the asserted contract cannot
    drift between suites."""
    tag = f"{label}: " if label else ""
    assert got.f == ref.f and got.targets == ref.targets, \
        tag + "plan identity (f / targets) diverged"
    assert set(got.nodes) == set(ref.nodes), f"{tag}node sets diverged"
    for k, na in ref.nodes.items():
        nb = got.nodes[k]
        assert na.state is nb.state, f"{tag}state diverged at {k.label()}"
        assert na.chosen == nb.chosen, \
            f"{tag}chosen deduction diverged at {k.label()}"
        assert na.rv == nb.rv, f"{tag}error RV diverged at {k.label()}"
        assert na.exact_bytes == nb.exact_bytes, \
            f"{tag}exact size diverged at {k.label()}"
    assert got.total_cost == ref.total_cost, \
        f"{tag}total_cost {got.total_cost} != {ref.total_cost}"
    assert got.feasible == ref.feasible, tag + "feasibility diverged"


@dataclasses.dataclass
class _TargetRec:
    """One target's candidate-deduction set, packed for array scoring.

    Every candidate child shares the target's compression method (ColSet
    mates by definition, ColExt parts by construction), so one order-class
    code covers the whole record.

    Candidates are packed in TWO blocks, in candidate order (ColSet mates
    first, then ColExt partitions): ColSet candidates all have exactly one
    child, so they score as (ncs, nf) arrays with no K axis — the big
    clustered-layout groups (every reordering of a table's full column
    set is one ColSet group) would otherwise pad hundreds of single-child
    rows to the widest ColExt partition.  Folding a single factor equals
    folding it with EXACT padding (multiplying by exact 1.0 is the
    identity), so the split is bit-identical to one padded block.
    """
    tid: int
    key: NodeKey
    kind: int                # order-class code of target AND all children
    cands: Tuple[Deduction, ...]
    ncs: int                 # leading single-child (ColSet) candidates
    cs_ids: np.ndarray       # (ncs,) ColSet child node ids
    cx_ids: np.ndarray       # (ncx, K) ColExt child ids, -1-padded
    nchild: List[int]        # real (unpadded) child count per candidate
    cx_dm: np.ndarray        # (ncx, 1) ColExt deduction-error term (T. 3)
    cx_msq: np.ndarray       # (ncx, 1) ... mean^2    (Goodman E^2 factor)
    cx_vterm: np.ndarray     # (ncx, 1) ... std^2 + mean^2     (V factor)
    all_child_ids: np.ndarray = None  # unique child ids (replay dirty check)
    ver: int = -1            # mate-group version this record was built at
    pos: int = -1            # own position in the mate group

    def child_row(self, w: int) -> np.ndarray:
        """Child-id row of candidate `w` in candidate order."""
        if w < self.ncs:
            return self.cs_ids[w:w + 1]
        return self.cx_ids[w - self.ncs]


@dataclasses.dataclass
class _Graph:
    """One round's view of the shared node universe: `node_keys`/`node_id`
    are the engine's LIVE append-only universe (ids are stable across
    target-set deltas), `recs` the round's targets in processing order."""
    node_keys: List[NodeKey]
    node_id: Dict[NodeKey, int]
    exact: List[Tuple[int, NodeKey, float]]
    recs: List[_TargetRec]


@dataclasses.dataclass
class _RecReplay:
    """One target's recorded decision from a previous `_run` (same e, q,
    f_grid): the pre-decision view of its inputs and the writes it
    produced.  A decision is a pure function of (candidate record, input
    view, e, q, sampling costs), so when the view is bit-identical this
    round — checked cheaply via the run's dirty-node flags, with a full
    view compare as the fallback — replaying the stored writes is exactly
    what re-scoring would produce."""
    rec: _TargetRec              # identity-checked (record cache object)
    view_tid: np.ndarray         # (4, nf) buf[tid] before the decision
    view_ch: Optional[np.ndarray]  # (nc, K, 4, nf) child gather, or None
    post_tid: np.ndarray         # (3, nf) buf[tid, :3] after the decision
    written: np.ndarray          # node ids whose value this rec wrote
    child_w: Optional[tuple]     # (cids, fis, means, stds) sampled children
    used_w: Optional[tuple]      # (ids, fis) used-as-child flag writes
    chosen: dict                 # {(tid, fi): Deduction}
    totals: List[tuple]          # ordered (fi, cost) total accumulations


@dataclasses.dataclass
class _RunState:
    """Resolved per-(node, f) arrays of one `_run` pass, pre-assembly."""
    g: _Graph
    targets: Tuple[NodeKey, ...]
    f_grid: Tuple[float, ...]
    state: np.ndarray             # (nnodes+1, nf) state codes
    mean: np.ndarray              # (nnodes+1, nf) rv mean
    std: np.ndarray               # (nnodes+1, nf) rv std
    used: np.ndarray              # (nnodes+1, nf) used-as-child flags
    chosen: Dict[Tuple[int, int], Deduction]
    total: List[float]            # per-f accumulated sampling cost


class PlannerEngine:
    """Runs the §5.2 greedy for a whole f grid over one shared graph."""

    def __init__(self, tables: Dict, existing: Optional[Dict] = None,
                 backend: str = "numpy",
                 scost_memo: Optional[Dict] = None, record: bool = True,
                 max_nodes: Optional[int] = None,
                 max_replay: Optional[int] = None, faults=None):
        self.backend, fell_back = _resolve_backend(backend,
                                                   site="planner_engine")
        self.backend_fallbacks = int(fell_back)  # jax requested, numpy ran
        # record per-target decisions for cross-run replay (the online-
        # session regime).  One-shot throwaway engines pass record=False
        # and skip the bookkeeping entirely.
        self.record = record
        # durability bounds for long-lived engines: when the node
        # universe outgrows `max_nodes` the whole id space is reset (an
        # EPOCH eviction — cached records/replays/cost columns reference
        # node ids, so they are dropped together and rebuilt on demand,
        # bit-identically); when the replay store holds more than
        # `max_replay` per-target decision records it is cleared.  Both
        # discard only recomputable state.  `faults` is an optional
        # faults.FaultInjector; site "planner_replay" models replay-store
        # loss (drop + recompute, never wrong results).
        self.max_nodes = max_nodes
        self.max_replay = max_replay
        self.faults = faults
        self.tables = tables
        self.existing = dict(existing or {})
        self._graphs: Dict[Tuple[NodeKey, ...], _Graph] = {}
        # (table, cols, f) -> §5.1 sampling cost; an owning
        # EstimationPlanner shares its memo so scalar reference and engine
        # price from one cache
        self._scost: Dict[Tuple[str, Tuple[str, ...], float], float] = \
            scost_memo if scost_memo is not None else {}
        self._pcache: Dict[Tuple[float, float, float], float] = {}
        # --- persistent incremental state (online sessions) -------------
        # append-only node universe: ids are stable across target-set
        # deltas, so cached target records and replay views stay valid
        self._node_keys: List[NodeKey] = []
        self._node_id: Dict[NodeKey, int] = {}
        self._exact: List[Tuple[int, NodeKey, float]] = [
            (self._add_node(k), k, size) for k, size in self.existing.items()]
        # (target, mate-group version) -> packed _TargetRec: a target's
        # candidate record only changes when its mate group does, so a
        # delta round rebuilds O(delta) records, not O(targets)
        self._recs: Dict[Tuple[NodeKey, int], _TargetRec] = {}
        # (table, column set, method) -> [mates tuple, ids, pos map,
        # shared Deduction list, version]; version bumps when membership
        # changes, invalidating members' cached records
        self._groups: Dict[Tuple[str, frozenset, str], list] = {}
        # target -> packed ColExt block (pure in the target; never stale)
        self._colext: Dict[NodeKey, tuple] = {}
        rv = err.colset_error()
        self._cs_fac = (rv.mean, rv.mean * rv.mean,
                        rv.std * rv.std + rv.mean * rv.mean)
        # per-f-grid (node x f) §5.1 cost columns, grown with the universe
        self._scost_cols: Dict[Tuple[float, ...], list] = {}
        # (e, q, f_grid) -> per-target _RecReplay decision records
        self._replay: Dict[Tuple[float, float, Tuple[float, ...]],
                           Dict[NodeKey, _RecReplay]] = {}
        self.graph_builds = 0   # distinct target sets built
        self.batch_runs = 0     # greedy_batch invocations
        self.rec_builds = 0       # target records packed from scratch
        self.rec_hits = 0         # target records reused from the cache
        self.replay_hits = 0      # per-(target) decisions replayed in _run
        self.replay_verified = 0  # ... replayed after appended-mate checks
        self.replay_misses = 0    # ... recomputed (inputs really changed)
        self.universe_evictions = 0  # epoch resets of the node universe
        self.replay_evictions = 0    # replay stores dropped at max_replay
        self.replay_faults = 0       # ... dropped by injected faults
        self.peak_nodes = 0          # high-water mark of the universe

    # ------------------------------------------------------------------
    # Graph construction (f-independent; incremental over a shared
    # node universe, with per-(target, mates) record caching)
    # ------------------------------------------------------------------
    def _add_node(self, k: NodeKey) -> int:
        nid = self._node_id.get(k)
        if nid is None:
            nid = self._node_id[k] = len(self._node_keys)
            self._node_keys.append(k)
        return nid

    def _colext_block(self, t: NodeKey) -> tuple:
        """Packed ColExt candidates of `t` — pure in the target (partition
        shapes and error fits don't depend on the round), cached forever.
        Pad id is -1: it always indexes the LAST buf row, which every
        `_run` allocates as the virtual EXACT node (neutral under compose,
        zero cost) — stable however much the universe grows."""
        got = self._colext.get(t)
        if got is not None:
            return got
        cands = _colext_deductions(t)
        for d in cands:
            for c in d.children:
                self._add_node(c)
        ncx = len(cands)
        nchild = [len(d.children) for d in cands]
        kmax = max(nchild, default=1)
        cx_ids = np.full((ncx, kmax), -1, dtype=np.int64)
        dm = np.empty((ncx, 1))
        ds = np.empty((ncx, 1))
        for i, d in enumerate(cands):
            row = cx_ids[i]
            for j, c in enumerate(d.children):
                row[j] = self._node_id[c]
            drv = err.colext_error(t.method, nchild[i])
            dm[i, 0] = drv.mean
            ds[i, 0] = drv.std
        msq = dm * dm
        got = (cands, cx_ids, nchild, dm, msq, ds * ds + msq)
        self._colext[t] = got
        return got

    def _build_rec(self, t: NodeKey, group: Optional[list]) -> _TargetRec:
        cx_cands, cx_ids, cx_nchild, cx_dm, cx_msq, cx_vt = \
            self._colext_block(t)
        if group is None:
            cs_cands: List[Deduction] = []
            cs_ids = np.empty(0, dtype=np.int64)
            ver = pos = -1
        else:
            mates, ids, pos_map, ded_list, ver, _ = group
            pos = pos_map[t]
            cs_cands = ded_list[:pos] + ded_list[pos + 1:]
            cs_ids = np.delete(ids, pos)
        cands = tuple(cs_cands) + tuple(cx_cands)
        nchild = [1] * len(cs_cands) + list(cx_nchild)
        all_ids = np.unique(np.concatenate([cs_ids, cx_ids.ravel()])) \
            if cands else np.empty(0, dtype=np.int64)
        return _TargetRec(self._node_id[t], t, _kind_code(t.method), cands,
                          len(cs_cands), cs_ids, cx_ids, nchild,
                          cx_dm, cx_msq, cx_vt, all_ids, ver, pos)

    def _build_graph(self, targets: Sequence[NodeKey]) -> _Graph:
        # ColSet mates can only be pre-existing nodes (existing indexes +
        # this round's targets), never nodes materialized mid-walk — a
        # materialized child is strictly narrower than its creator, and
        # the walk is narrow-to-wide, so it can never share a column set
        # with a later target.  Mate groups are therefore derivable from
        # (exact + targets) alone, which is what makes per-(target,
        # group-version) record caching exact under target-set deltas.
        by_set: Dict[Tuple[str, frozenset, str], List[NodeKey]] = {}
        for _, k, _ in self._exact:
            by_set.setdefault(k.gkey(), []).append(k)
        seen = set()
        for t in targets:
            self._add_node(t)
            if t not in seen:
                seen.add(t)
                by_set.setdefault(t.gkey(), []).append(t)

        # group registry: bump the version (and drop members' stale
        # records) only when a group's membership actually changed; the
        # last bump's survivor/insert masks are kept so member-level
        # verification derives its masks with one np.delete instead of
        # two np.isin sorts per member
        for gk, members in by_set.items():
            mt = tuple(members)
            reg = self._groups.get(gk)
            if reg is not None and reg[0] == mt:
                continue
            ver = 0 if reg is None else reg[4] + 1
            ids = np.array([self._node_id[m] for m in mt], dtype=np.int64)
            trans = None
            if reg is not None:
                for m in reg[0]:
                    self._recs.pop((m, reg[4]), None)
                old_ids = reg[1]
                kept_old_g = np.isin(old_ids, ids, assume_unique=True)
                kept_new_g = np.isin(ids, old_ids, assume_unique=True)
                order_ok = bool(np.array_equal(ids[kept_new_g],
                                               old_ids[kept_old_g]))
                trans = (reg[4], kept_old_g, kept_new_g, order_ok)
            pos_map = {m: i for i, m in enumerate(mt)}
            ded_list = [_colset_ded(o) for o in mt]
            self._groups[gk] = [mt, ids, pos_map, ded_list, ver, trans]

        recs: List[_TargetRec] = []
        for t in sorted(targets, key=lambda k: (len(k.cols), k.cols)):
            if METHODS[t.method].order_dependent:
                group = None
                rkey = (t, -1)
            else:
                group = self._groups[t.gkey()]
                rkey = (t, group[4])
            rec = self._recs.get(rkey)
            if rec is None:
                rec = self._recs[rkey] = self._build_rec(t, group)
                self.rec_builds += 1
            else:
                self.rec_hits += 1
            recs.append(rec)
        return _Graph(self._node_keys, self._node_id,
                      list(self._exact), recs)

    def _evict_universe(self) -> None:
        """EPOCH eviction: reset the node universe and everything keyed
        by (or holding) node ids — cached target records, mate groups,
        ColExt blocks, built graphs, grown cost columns, replay stores.
        The §5.1 sampling-cost memo (keyed by (table, cols, f)) and the
        probability memo (keyed by floats) survive: they are id-free.
        Every dropped structure is a pure function of the next round's
        targets, so the rebuild is bit-identical — eviction trades CPU
        for a bounded footprint, never results."""
        self._graphs.clear()
        self._recs.clear()
        self._groups.clear()
        self._colext.clear()
        self._scost_cols.clear()
        self._replay.clear()
        self._node_keys = []
        self._node_id = {}
        self._exact = [(self._add_node(k), k, size)
                       for k, size in self.existing.items()]
        self.universe_evictions += 1

    def _graph(self, targets: Sequence[NodeKey]) -> _Graph:
        if self.max_nodes is not None and \
                len(self._node_keys) > self.max_nodes:
            self._evict_universe()
        key = tuple(targets)
        g = self._graphs.get(key)
        if g is None:
            if len(self._graphs) > 128:   # bound a long session's footprint
                self._graphs.clear()
            g = self._graphs[key] = self._build_graph(targets)
            self.graph_builds += 1
        self.peak_nodes = max(self.peak_nodes, len(self._node_keys))
        return g

    def _sampling_cost(self, key: NodeKey, f: float) -> float:
        return memoized_sampling_cost(self.tables, self._scost, key, f)

    # ------------------------------------------------------------------
    # Scoring backend (probability + fused candidate scoring)
    # ------------------------------------------------------------------
    def _prob(self, means: np.ndarray, stds: np.ndarray,
              e: float) -> np.ndarray:
        if self.backend == "jax":
            from ..kernels import planner_score as _ps
            return _ps.prob_within(means, stds, e)
        return err.prob_within_batch(means, stds, e)

    def _jax_score(self, rec: _TargetRec, m_s, s_s, m_x, s_x,
                   mask67: np.ndarray, pre9, extra, e: float,
                   q: float) -> tuple:
        """jax backend: score one record's whole (candidate, child, f)
        stack with the fused Pallas kernel.  ColSet candidates sit at
        k=0 with EXACT pads after them — folding exact (1, 0) factors is
        the float32 multiplicative identity, so the packed stack scores
        bit-identically to per-block kernel calls (the property
        `_verify_changed` relies on when it re-scores inserted mates
        alone).  Returns (cm, cs, p): float32 values in float64 arrays,
        p masked to mask67|pre9 exactly like the numpy path."""
        from ..kernels import planner_score as _ps
        nc, nf = mask67.shape
        ncs = rec.ncs
        kmax = m_x.shape[1] if m_x is not None else 1
        m = np.ones((nc, kmax, nf))
        s = np.zeros((nc, kmax, nf))
        dm = np.empty((nc, 1))
        vt = np.empty((nc, 1))
        mq = np.empty((nc, 1))
        cs_dm, cs_msq, cs_vt = self._cs_fac
        if m_s is not None:
            m[:ncs, 0, :] = m_s
            s[:ncs, 0, :] = s_s
        dm[:ncs] = cs_dm
        vt[:ncs] = cs_vt
        mq[:ncs] = cs_msq
        if m_x is not None:
            m[ncs:] = m_x
            s[ncs:] = s_x
            dm[ncs:] = rec.cx_dm
            vt[ncs:] = rec.cx_vterm
            mq[ncs:] = rec.cx_msq
        cm, cs, p, _, _ = _ps.fused_score(m, s, dm, vt, mq, mask67, pre9,
                                          extra, e, q)
        return cm, cs, p

    def _prob_cached(self, means: np.ndarray, stds: np.ndarray,
                     e: float) -> np.ndarray:
        """`_prob` behind a (e, mean, std) memo — the engine's analogue of
        the scalar path's `lru_cache` on `prob_within`: composed RVs recur
        heavily across candidates, targets, fractions and repeated runs.
        Cache values are exactly the batch-computed floats, so parity is
        unaffected.  Large requests are deduplicated first (packing the
        exact float pair into a complex for one `np.unique`): a ColSet
        group's candidates mostly share one composed RV."""
        pc = self._pcache
        if means.size > 64:
            u, inv = np.unique(means + stds * 1j, return_inverse=True)
            um = u.real
            us = u.imag
        else:
            inv = None
            um, us = means, stds
        ml = um.tolist()
        sl = us.tolist()
        out = [0.0] * len(ml)
        miss: List[int] = []
        for i, a in enumerate(ml):
            v = pc.get((e, a, sl[i]))
            if v is None:
                miss.append(i)
            else:
                out[i] = v
        if miss:
            got = self._prob(np.array([ml[i] for i in miss]),
                             np.array([sl[i] for i in miss]), e).tolist()
            for i, v in zip(miss, got):
                out[i] = v
                pc[(e, ml[i], sl[i])] = v
        res = np.array(out)
        return res[inv] if inv is not None else res

    # ------------------------------------------------------------------
    # The batched greedy (paper §5.2, all fractions at once)
    # ------------------------------------------------------------------
    def _scost_matrix(self, g: _Graph, f_grid: Tuple[float, ...]
                      ) -> np.ndarray:
        """(node x f) §5.1 sampling-cost rows for the universe's first
        len(g.node_keys) nodes — pure in table stats, grown incrementally
        as the universe grows (never recomputed)."""
        n = len(g.node_keys)
        ent = self._scost_cols.get(f_grid)
        if ent is None:
            ent = self._scost_cols[f_grid] = \
                [np.zeros((max(n, 64), len(f_grid))), 0]
        if n > ent[0].shape[0]:
            grown = np.zeros((max(n, 2 * ent[0].shape[0]), len(f_grid)))
            grown[:ent[0].shape[0]] = ent[0]
            ent[0] = grown
        arr, filled = ent
        if filled < n:
            for nid in range(filled, n):
                k = g.node_keys[nid]
                for fi, f in enumerate(f_grid):
                    arr[nid, fi] = self._sampling_cost(k, f)
            ent[1] = n
        return arr[:n]

    def greedy_batch(self, targets: Sequence[NodeKey], e: float, q: float,
                     f_grid: Sequence[float] = F_GRID) -> List[Plan]:
        """One `Plan` per fraction in `f_grid`, plan-identical to running
        `EstimationPlanner.greedy_scalar(targets, f, e, q)` per fraction."""
        st = self._run(targets, e, q, f_grid)
        feas = self._feasible_vec(st, e, q)
        return [self._assemble_one(st, fi, bool(feas[fi]))
                for fi in range(len(st.f_grid))]

    def plan_batch(self, targets: Sequence[NodeKey], e: float, q: float,
                   f_grid: Sequence[float] = F_GRID) -> Plan:
        """§5.2 outer loop: cheapest feasible plan over the f grid (else
        the cheapest overall), materializing only the winner."""
        st = self._run(targets, e, q, f_grid)
        feas = self._feasible_vec(st, e, q)
        best_fi: Optional[int] = None
        fb_fi = 0
        for fi in range(len(st.f_grid)):
            if feas[fi] and (best_fi is None
                             or st.total[fi] < st.total[best_fi]):
                best_fi = fi
            if st.total[fi] < st.total[fb_fi]:
                fb_fi = fi
        fi = best_fi if best_fi is not None else fb_fi
        return self._assemble_one(st, fi, bool(feas[fi]))

    def plan_all_sampled_batch(self, targets: Sequence[NodeKey], e: float,
                               q: float, f_grid: Sequence[float] = F_GRID
                               ) -> Plan:
        """The "All" baseline: greedy under FORCE_ALL_Q (every deduction
        fails, so everything samples), feasibility re-judged against the
        caller's q; first feasible fraction wins, else the cheapest."""
        from .estimation_graph import FORCE_ALL_Q
        st = self._run(targets, e, FORCE_ALL_Q, f_grid)
        feas = self._feasible_vec(st, e, q)
        fb_fi = 0
        for fi in range(len(st.f_grid)):
            if feas[fi]:
                return self._assemble_one(st, fi, True)
            if st.total[fi] < st.total[fb_fi]:
                fb_fi = fi
        return self._assemble_one(st, fb_fi, False)

    @staticmethod
    def _gather(rec: _TargetRec, buf: np.ndarray) -> tuple:
        """Pre-decision child views, one per block: ((ncs, 4, nf) ColSet
        children, (ncx, K, 4, nf) ColExt children), None when empty."""
        return (buf[rec.cs_ids] if rec.ncs else None,
                buf[rec.cx_ids] if len(rec.cands) > rec.ncs else None)

    @staticmethod
    def _views_equal(a: tuple, b: tuple) -> bool:
        for x, y in zip(a, b):
            if (x is None) != (y is None):
                return False
            if x is not None and not np.array_equal(x, y):
                return False
        return True

    @staticmethod
    def _concat(a: Optional[np.ndarray],
                b: Optional[np.ndarray]) -> np.ndarray:
        if a is None:
            return b
        if b is None:
            return a
        return np.concatenate([a, b], axis=0)

    def _verify_changed(self, rec: _TargetRec, rr: _RecReplay,
                        buf: np.ndarray, dirty: np.ndarray, e: float,
                        q: float, samp_mean: np.ndarray,
                        samp_std: np.ndarray, scost: np.ndarray) -> tuple:
        """Decision-level replay check for a target whose candidate RECORD
        changed.  A record only changes through its ColSet mate group, and
        group deltas preserve the survivors' relative order (the candidate
        union is kept in a canonical sorted order): mates are removed or
        inserted, never permuted.  The scalar §5.2 choice is a first-max
        argmax (or first-min argmin), so the recorded decision still
        stands iff no removed candidate was the winner and no inserted
        candidate would now qualify ahead of it — checkable by scoring
        ONLY the inserted candidates.  Surviving mates' recorded views are
        trusted outright when the run's dirty flags show them untouched
        (the usual case), and the stored record's views are then stitched
        rather than re-gathered.  Returns (ok, view_ch): ok=True means
        the stored writes replay verbatim with `view_ch` as the record's
        refreshed view tuple."""
        tid = rec.tid
        if dirty[tid] and not np.array_equal(rr.view_tid, buf[tid]):
            return False, None
        act = rr.view_tid[0] == _NONE
        if rr.view_ch is None:
            # recorded with no reads (fully-decided target): the matching
            # state row already proves the no-op decision stands
            return (not act.any()), None
        old_ids = rr.rec.cs_ids
        new_ids = rec.cs_ids
        group = self._groups.get(rec.key.gkey())
        trans = group[5] if group is not None else None
        if (trans is not None and trans[0] == rr.rec.ver
                and group[4] == rec.ver and rr.rec.pos >= 0):
            # the group's last transition covers exactly this old->new
            # record pair: derive the member masks by dropping self
            if not trans[3]:
                return False, None     # survivors permuted: full rescore
            kept_old = np.delete(trans[1], rr.rec.pos)
            kept_new = np.delete(trans[2], rec.pos)
        else:
            kept_new = np.isin(new_ids, old_ids, assume_unique=True)
            kept_old = np.isin(old_ids, new_ids, assume_unique=True)
            if not np.array_equal(new_ids[kept_new], old_ids[kept_old]):
                return False, None     # survivors permuted: full rescore
        old_chs, old_chx = rr.view_ch
        surv = new_ids[kept_new]
        ncx = len(rec.cands) - rec.ncs
        trusted = (not dirty[tid]
                   and (not surv.size or not dirty[surv].any())
                   and (not ncx or not dirty[rec.cx_ids].any()))
        ins = ~kept_new
        nins = int(ins.sum())
        if trusted:
            # untouched inputs are bit-identical by the run invariant:
            # reuse the recorded rows, gather only the inserted mates
            chx = old_chx
            app = buf[new_ids[ins]] if nins else None
            if new_ids.size:
                chs = np.empty(
                    (new_ids.shape[0],) + rr.view_tid.shape, dtype=np.float64)
                if surv.size:
                    chs[kept_new] = old_chs[kept_old]
                if nins:
                    chs[ins] = app
            else:
                chs = None
        else:
            chs = buf[new_ids] if new_ids.size else None
            chx = buf[rec.cx_ids] if ncx else None
            if (chx is None) != (old_chx is None) or \
                    (chx is not None and not np.array_equal(chx, old_chx)):
                return False, None
            if kept_old.any() and \
                    not np.array_equal(chs[kept_new], old_chs[kept_old]):
                return False, None
            app = chs[ins] if nins else None
        removed_set = (set(old_ids[~kept_old].tolist())
                       if not kept_old.all() else ())
        nf = act.shape[0]
        if nins:
            # score just the inserted single-child ColSet candidates
            ins_pos = np.nonzero(ins)[0]
            known = app[:, 0, :] != _NONE
            m_a = app[:, 1, :]
            s_a = app[:, 2, :]
            if not known.all():
                m_a = np.where(known, m_a, samp_mean[rec.kind])
                s_a = np.where(known, s_a, samp_std[rec.kind])
            cs_dm, cs_msq, cs_vt = self._cs_fac
            elig67 = known & act              # single child: allk == known
            pre9 = ~known & (app[:, 3, :] < scost[rec.tid]) & act
            if self.backend == "jax":
                # same fused float32 op sequence as the run that recorded
                # the decision — the EXACT (1, 0) K-pads of the recorded
                # run are the exact multiplicative identity, so this K=1
                # fold is bitwise what the full stack produced
                from ..kernels import planner_score as _ps
                _, _, p, _, _ = _ps.fused_score(
                    m_a[:, None, :], s_a[:, None, :],
                    np.full((nins, 1), cs_dm), np.full((nins, 1), cs_vt),
                    np.full((nins, 1), cs_msq), elig67, pre9, None, e, q)
            else:
                msq = m_a * m_a
                cm_a = m_a * cs_dm
                v_a = (s_a * s_a + msq) * cs_vt
                e2_a = msq * cs_msq
                std_a = np.sqrt(np.maximum(v_a - e2_a, 0.0))
                maskp = elig67 | pre9
                p = np.zeros((nins, nf))
                ii = maskp.nonzero()
                if ii[0].size:
                    p[ii] = self._prob_cached(cm_a[ii], std_a[ii], e)
            sat = p >= q
            pos_of = {int(v): i for i, v in enumerate(new_ids)}
        b9 = set(rr.child_w[1].tolist()) if rr.child_w is not None else ()
        for fi in np.nonzero(act)[0].tolist():
            if rr.post_tid[0, fi] == _DEDUCED and fi not in b9:
                # old decision: lines 6-7 winner.  It stands unless it was
                # removed, or an inserted candidate now scores ahead of it
                # (strictly better p; or equal p at an earlier position —
                # every inserted ColSet precedes every ColExt candidate).
                d = rr.chosen[(tid, fi)]
                is_cx = d.kind == "colext"
                wid = None if is_cx else self._node_id[d.children[0]]
                if removed_set and wid is not None and wid in removed_set:
                    return False, (chs, chx)
                if nins:
                    el = elig67[:, fi] & sat[:, fi]
                    if el.any():
                        best_p = self._prob_cached(
                            np.array([rr.post_tid[1, fi]]),
                            np.array([rr.post_tid[2, fi]]), e)[0]
                        pm = p[el, fi].max()
                        if pm > best_p or (pm == best_p and is_cx):
                            return False, (chs, chx)
                        if pm == best_p:
                            tie = el & (p[:, fi] == best_p)
                            if (ins_pos[tie] < pos_of[wid]).any():
                                return False, (chs, chx)
            else:
                # old decision: lines 8-9 (fi in b9) or 10-11 fallback.
                # Any newly eligible inserted candidate re-opens it; so
                # does removing a lines-8-9 winner.
                if fi in b9 and removed_set:
                    d = rr.chosen.get((tid, fi))
                    if d is not None and d.kind == "colset" and \
                            self._node_id[d.children[0]] in removed_set:
                        return False, (chs, chx)
                if nins and (sat[:, fi]
                             & (elig67[:, fi] | pre9[:, fi])).any():
                    return False, (chs, chx)
        return True, (chs, chx)

    @staticmethod
    def _replay_rec(rr: _RecReplay, buf: np.ndarray, used: np.ndarray,
                    chosen: Dict, total: List[float]) -> None:
        """Replay a recorded decision: write the stored post-state.  The
        stored floats ARE the values recomputation would produce (the
        pre-decision view is bit-identical), so the run stays exact."""
        buf[rr.rec.tid, :3, :] = rr.post_tid
        if rr.child_w is not None:
            cids, fis, ms, ss = rr.child_w
            buf[cids, 0, fis] = _SAMPLED
            buf[cids, 1, fis] = ms
            buf[cids, 2, fis] = ss
        if rr.used_w is not None:
            used[rr.used_w[0], rr.used_w[1]] = True
        if rr.chosen:
            chosen.update(rr.chosen)
        for fi, c in rr.totals:
            total[fi] += c

    def _run(self, targets: Sequence[NodeKey], e: float, q: float,
             f_grid: Sequence[float]) -> "_RunState":
        """One pass over the targets, scoring lines 6-9 of the §5.2
        pseudocode for the whole candidate set, for every f, at once.

        One composed-RV evaluation serves BOTH phases: with unknown
        children substituted by their hypothetical SampleCF error, the
        trial RV of lines 8-9 equals the actual deduction RV of lines 6-7
        on fully-known rows (the where() substitutes nothing there), so
        the two phases share one `compose`-equivalent and one
        mask-compressed probability call.

        Across runs with the same (e, q, f_grid) — the online-session
        regime — each target's decision is replayed from its recorded
        write ops when its pre-decision input view (its own state row and
        the gathered child rows) is bit-identical to the recorded one.
        Only targets actually affected by a workload delta (changed mate
        groups, changed child states, new targets) are re-scored.
        """
        self.batch_runs += 1
        f_grid = tuple(f_grid)
        if self.record:
            if self.faults is not None and \
                    self.faults.fires("planner_replay"):
                # injected replay-store loss: every decision recomputes
                # from scratch next, which is bit-identical by contract
                if self._replay:
                    self._replay.clear()
                    self.replay_faults += 1
            if self.max_replay is not None and sum(
                    len(d) for d in self._replay.values()) > self.max_replay:
                self._replay.clear()
                self.replay_evictions += 1
        g = self._graph(targets)
        nf = len(f_grid)
        n = len(g.node_keys)
        pad = n   # child_ids pad id -1 wraps to this last row

        # packed per-(node, f) state: [state code, rv mean, rv std, cost]
        # — one fancy-index gathers everything a candidate row needs
        buf = np.zeros((n + 1, 4, nf))
        buf[:, 1, :] = 1.0                        # default rv = EXACT
        buf[pad, 0, :] = _EXACT
        for nid, _, _ in g.exact:
            buf[nid, 0, :] = _EXACT
        buf[:n, 3, :] = self._scost_matrix(g, f_grid)
        state = buf[:, 0, :]
        scost = buf[:, 3, :]

        # SampleCF error RVs per (order class, f) — Table 2 fits
        samp = np.empty((2, 2, nf))               # [kind, mean/std, f]
        rep = {_kind_code(m): m for m in METHODS}
        for kc, method in rep.items():
            for fi, f in enumerate(f_grid):
                rv = err.samplecf_error(method, f)
                samp[kc, 0, fi] = rv.mean
                samp[kc, 1, fi] = rv.std
        samp_mean = samp[:, 0, :]
        samp_std = samp[:, 1, :]

        total = [0.0] * nf
        used = np.zeros((n + 1, nf), dtype=bool)
        chosen: Dict[Tuple[int, int], Deduction] = {}
        false_f = np.zeros(nf, dtype=bool)
        store = (self._replay.setdefault((e, q, f_grid), {})
                 if self.record else None)

        # dirty-node pre-pass: a target that vanished from the round leaves
        # its recorded writes unapplied — flag (and forget) them so every
        # dependent takes the compare path instead of the fast one
        dirty = np.zeros(n + 1, dtype=bool)
        if store:
            cur = {rec.key for rec in g.recs}
            for k in [k for k in store if k not in cur]:
                dirty[store[k].written] = True
                del store[k]

        for rec in g.recs:
            tid = rec.tid
            rr = store.get(rec.key) if store is not None else None
            fresh = rr is not None and rr.rec is rec
            if (fresh and not dirty[tid]
                    and not dirty[rec.all_child_ids].any()):
                # fast path: nothing this rec reads was touched this round,
                # so its input view is bit-identical by induction
                self.replay_hits += 1
                self._replay_rec(rr, buf, used, chosen, total)
                continue
            tview = buf[tid].copy() if store is not None else None
            ch = None
            if fresh and np.array_equal(rr.view_tid, tview):
                if rr.view_ch is not None:
                    ch = self._gather(rec, buf)
                if rr.view_ch is None or self._views_equal(rr.view_ch, ch):
                    # inputs bit-identical despite dirty neighbors: the
                    # replayed writes reproduce last round's values, so
                    # nothing new becomes dirty
                    self.replay_hits += 1
                    self._replay_rec(rr, buf, used, chosen, total)
                    continue
            elif rr is not None and rr.rec is not rec:
                # candidate record changed (mate-group delta): decision-
                # level verification scores only the inserted mates
                ok, ch = self._verify_changed(
                    rec, rr, buf, dirty, e, q, samp_mean, samp_std, scost)
                if ok:
                    self.replay_verified += 1
                    self._replay_rec(rr, buf, used, chosen, total)
                    store[rec.key] = dataclasses.replace(
                        rr, rec=rec, view_ch=ch)
                    continue
            self.replay_misses += 1
            r_chosen: Dict[Tuple[int, int], Deduction] = {}
            r_used: List[Tuple[np.ndarray, int]] = []
            r_child: List[Tuple[int, int, float, float]] = []
            r_tot: List[Tuple[int, float]] = []
            act = state[tid] == _NONE              # (nf,)
            nc = len(rec.cands) if act.any() else 0
            kc = rec.kind
            has6 = has9 = false_f
            if nc:
                if ch is None:
                    ch = self._gather(rec, buf)
                chs, chx = ch                      # per-block child views
                # per-block Goodman accumulators, concatenated in candidate
                # order (ColSet first): a single-child fold equals the
                # padded fold (the EXACT pads multiply by exact 1.0), so
                # the block split is bit-identical to one padded block
                known_s = chs[:, 0, :] != _NONE if chs is not None else None
                if chx is not None:
                    known_x = chx[:, :, 0, :] != _NONE
                    allk_x = known_x.all(axis=1)   # (ncx, nf)
                else:
                    allk_x = None
                allk = self._concat(known_s, allk_x)   # (nc, nf)
                any_unknown = not allk.all()
                cs_dm, cs_msq, cs_vt = self._cs_fac
                m_s = s_s = m_x = s_x = None
                if chs is not None:
                    m_s = chs[:, 1, :]
                    s_s = chs[:, 2, :]
                    if any_unknown:
                        # children RVs, unknown ones hypothetically sampled
                        # (all children share the target's method, hence
                        # one Table 2 error fit per record)
                        m_s = np.where(known_s, m_s, samp_mean[kc])
                        s_s = np.where(known_s, s_s, samp_std[kc])
                if chx is not None:
                    m_x = chx[:, :, 1, :]
                    s_x = chx[:, :, 2, :]
                    if any_unknown:
                        m_x = np.where(known_x, m_x, samp_mean[kc])
                        s_x = np.where(known_x, s_x, samp_std[kc])
                if self.backend != "jax":
                    cmA = vA = e2A = None
                    if chs is not None:
                        msq_s = m_s * m_s
                        cmA = m_s * cs_dm
                        vA = (s_s * s_s + msq_s) * cs_vt
                        e2A = msq_s * cs_msq
                    cmB = vB = e2B = None
                    if chx is not None:
                        # Goodman fold over the children axis, continued
                        # with the deduction-error factor — bit-identical
                        # to the scalar compose (children in order,
                        # deduction last)
                        cmB, vB, e2B = err.goodman_fold(m_x, s_x, axis=1)
                        cmB = cmB * rec.cx_dm
                        vB = vB * rec.cx_vterm
                        e2B = e2B * rec.cx_msq
                    cm = self._concat(cmA, cmB)
                    v = self._concat(vA, vB)
                    e2 = self._concat(e2A, e2B)
                    cs = np.sqrt(np.maximum(v - e2, 0.0))

                mask67 = allk & act
                if any_unknown:
                    # lines 8-9 precondition: summed sampling cost of the
                    # unknown children.  add.reduce over a non-contiguous
                    # axis is a sequential fold (numpy pairwise blocking
                    # needs the reduction axis contiguous), and the known
                    # children's exact 0.0 terms leave every partial sum
                    # unchanged — so this matches the scalar child-order
                    # sum bit-for-bit (asserted in the parity tests).
                    extraA = None if chs is None else \
                        np.where(known_s, 0.0, chs[:, 3, :])
                    extraB = None if chx is None else np.add.reduce(
                        np.where(known_x, 0.0, chx[:, :, 3, :]), axis=1)
                    extra = self._concat(extraA, extraB)
                    my_cost = scost[tid]           # (nf,)
                    pre9 = ~allk & (extra < my_cost) & act
                    mask_p = mask67 | pre9
                else:
                    pre9 = None
                    mask_p = mask67

                if self.backend == "jax":
                    # fused Pallas kernel: compose + masked probability in
                    # one pass (winner selection stays on the host; p is
                    # float32-exact so argmax over it agrees)
                    cm, cs, p = self._jax_score(
                        rec, m_s, s_s, m_x, s_x, mask67, pre9,
                        extra if any_unknown else None, e, q)
                else:
                    # one probability pass over both phases' eligible
                    # entries
                    p = np.zeros((nc, nf))
                    ii = mask_p.nonzero()
                    if ii[0].size:
                        p[ii] = self._prob_cached(cm[ii], cs[ii], e)
                sat = p >= q

                # ---- lines 6-7: an enabled deduction satisfying (e, q) --
                elig = mask67 & sat
                has6 = elig.any(axis=0)
                if has6.any():
                    w6 = np.argmax(np.where(elig, p, -1.0), axis=0)
                    for fi_ in np.nonzero(has6)[0]:
                        fi = int(fi_)
                        w = int(w6[fi])
                        buf[tid, :3, fi] = _DEDUCED, cm[w, fi], cs[w, fi]
                        chosen[(tid, fi)] = rec.cands[w]
                        used[rec.child_row(w), fi] = True
                        r_chosen[(tid, fi)] = rec.cands[w]
                        r_used.append((rec.child_row(w), fi))

                # ---- lines 8-9: enable one by sampling unknown children -
                has9 = false_f
                if pre9 is not None:
                    ok9 = pre9 & sat & ~has6
                    has9 = ok9.any(axis=0)
                if has9.any():
                    w9 = np.argmin(np.where(ok9, extra, np.inf), axis=0)
                    for fi_ in np.nonzero(has9)[0]:
                        fi = int(fi_)
                        w = int(w9[fi])
                        for cid in rec.child_row(w)[:rec.nchild[w]]:
                            if buf[cid, 0, fi] == _NONE:
                                buf[cid, :3, fi] = (_SAMPLED,
                                                    samp_mean[kc, fi],
                                                    samp_std[kc, fi])
                                c = float(scost[cid, fi])
                                total[fi] += c
                                r_child.append((int(cid), fi,
                                                float(samp_mean[kc, fi]),
                                                float(samp_std[kc, fi])))
                                r_tot.append((fi, c))
                        buf[tid, :3, fi] = _DEDUCED, cm[w, fi], cs[w, fi]
                        chosen[(tid, fi)] = rec.cands[w]
                        used[rec.child_row(w), fi] = True
                        r_chosen[(tid, fi)] = rec.cands[w]
                        r_used.append((rec.child_row(w), fi))

            # ---- lines 10-11: fall back to SampleCF on this target ------
            rest = np.nonzero(act & ~has6 & ~has9)[0]
            if rest.size:
                buf[tid, 0, rest] = _SAMPLED
                buf[tid, 1, rest] = samp_mean[kc, rest]
                buf[tid, 2, rest] = samp_std[kc, rest]
                for fi_ in rest:
                    fi = int(fi_)
                    c = float(scost[tid, fi])
                    total[fi] += c
                    r_tot.append((fi, c))

            # ---- record the decision + propagate dirtiness --------------
            if store is None:
                continue
            if r_child:
                cids = np.array([x[0] for x in r_child], dtype=np.int64)
                child_w = (cids,
                           np.array([x[1] for x in r_child], dtype=np.int64),
                           np.array([x[2] for x in r_child]),
                           np.array([x[3] for x in r_child]))
                written = np.unique(np.concatenate(
                    [np.array([tid], dtype=np.int64), cids]))
            else:
                child_w = None
                written = (np.array([tid], dtype=np.int64) if act.any()
                           else np.empty(0, dtype=np.int64))
            if r_used:
                used_w = (np.concatenate([u[0] for u in r_used]),
                          np.repeat(
                              np.array([u[1] for u in r_used],
                                       dtype=np.int64),
                              np.array([u[0].shape[0] for u in r_used])))
            else:
                used_w = None
            rr2 = _RecReplay(rec, tview, ch, buf[tid, :3, :].copy(),
                             written, child_w, used_w, r_chosen, r_tot)
            if rr is not None:
                dirty[rr.written] = True
            if written.size:
                dirty[written] = True
            store[rec.key] = rr2

        return _RunState(g=g, targets=tuple(targets), f_grid=f_grid,
                         state=state, mean=buf[:, 1, :], std=buf[:, 2, :],
                         used=used, chosen=chosen, total=total)

    # ------------------------------------------------------------------
    def _feasible_vec(self, st: "_RunState", e: float,
                      q: float) -> np.ndarray:
        """Per-f feasibility: every target's final RV satisfies (e, q).
        Probability values are the same memoized batch floats the scalar
        `err.satisfies` would produce, so flags agree bit-for-bit."""
        tids = [st.g.node_id[t] for t in st.targets]
        m = st.mean[tids]                          # (ntargets, nf)
        s = st.std[tids]
        p = self._prob_cached(m.ravel(), s.ravel(), e).reshape(m.shape)
        return (p >= q).all(axis=0)

    def _assemble_one(self, st: "_RunState", fi: int,
                      feasible: bool) -> Plan:
        """Materialize fraction `fi`'s `Plan` (scalar lines 13-14 cleanup:
        keep only targets, used children, and EXACT existing nodes)."""
        g = st.g
        f = st.f_grid[fi]
        n = st.state.shape[0] - 1   # nodes at run time (universe may grow)
        is_target = np.zeros(n, dtype=bool)
        is_target[[g.node_id[t] for t in st.targets]] = True
        # pull the f column out as plain Python scalars once — per-node
        # numpy scalar indexing would dominate the assembly otherwise
        st_col = st.state[:, fi].tolist()
        m_col = st.mean[:, fi].tolist()
        s_col = st.std[:, fi].tolist()
        nodes: Dict[NodeKey, Node] = {}
        for _, k, size in g.exact:
            nodes[k] = Node(k, State.EXACT, rv=err.EXACT, exact_bytes=size)
        for nid in np.nonzero(st.used[:n, fi] | is_target)[0].tolist():
            k = g.node_keys[nid]
            if k in nodes:
                continue
            code = int(st_col[nid])
            assert code != _NONE, f"unresolved plan node {k.label()}"
            node = Node(k, _STATE_OF[code])
            if code == _SAMPLED:
                node.rv = err.samplecf_error(k.method, f)
            else:  # DEDUCED
                node.chosen = st.chosen[(nid, fi)]
                node.rv = err.ErrorRV(m_col[nid], s_col[nid])
            nodes[k] = node
        return Plan(f=f, nodes=nodes, targets=st.targets,
                    total_cost=st.total[fi], feasible=feasible)

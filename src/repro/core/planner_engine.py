"""Batched §5.2 deduction planner: the greedy graph search as array code.

The scalar planner (`EstimationPlanner.greedy_scalar`) walks the targets
narrow-to-wide and, per target, scores every candidate deduction with
Python-level RV composition and erf calls — then the §5.2 outer loop repeats
the whole walk for every sampling fraction on F_GRID.  After PRs 1-2 batched
what-if costing and SampleCF execution, this walk is the advisor's last
scalar hot path (~0.7s of ~0.8s `estimate_sizes` at 200 statements).

This engine runs the greedy for **all fractions in one pass over a shared
deduction graph**:

* **Graph build (f-independent, built once).**  The node universe and each
  target's candidate-deduction set do not depend on f: ColSet mates can only
  be pre-existing nodes (existing indexes + targets), never nodes
  materialized mid-walk — a materialized child is strictly narrower than its
  creator, and the walk is narrow-to-wide, so it can never share a column
  set with a later target.  The build therefore records, per target in
  processing order, the candidate `Deduction`s with their children packed
  into (ncand, K) id/kind arrays (EXACT-padded), plus the deduction-error
  term of each candidate.

* **Per-(node, f) state arrays.**  Decisions differ across fractions, so
  node state / error-RV mean / error-RV std live in (nnodes, nf) arrays.
  One pass over the targets then scores lines 6-9 of the §5.2 pseudocode
  for a target's whole candidate set, for every f, in a handful of NumPy
  calls: `errors.goodman_fold` (the sequential-fold core of
  `errors.compose_batch`, continued with the deduction-error factor) and
  `errors.prob_within_batch` (vectorized erf over the mask-compressed
  eligible entries, memoized).

* **(node × f) sampling-cost matrix.**  §5.1 sampling costs are pure in
  table stats, so the lines 8-9 "enable by sampling unknown children"
  comparison is an argmin over `extra = Σ cost(unknown child)` arrays.

Parity: decisions reduce to comparisons of floats produced by the same
IEEE operations in the same order as the scalar reference (see
`errors.compose_batch` / `errors.prob_within_batch`), so the engine is
**plan-identical** to `greedy_scalar` — same per-node states, same chosen
deductions, same `total_cost`, for every f — asserted in
tests/test_core_estimation.py, tests/test_estimation_engine.py and in
benchmarks/estimation_scaling.py.

An optional jax.jit scoring backend (`PlannerEngine(backend="jax")`,
mirroring `CostEngine(backend="jax")` / `estimation_backend="jax"`) swaps
the erf evaluation for a jitted `jax.scipy.special.erf`; it is gated on
jax + x64 availability and is NOT bit-parity (jax's erf is a different
polynomial) — the NumPy backend is the parity reference.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import errors as err
from .compression import METHODS, jax_batch_ready
from .estimation_graph import (Deduction, F_GRID, Node, NodeKey, Plan, State,
                               _colext_deductions, _colset_ded,
                               memoized_sampling_cost)

try:  # optional accelerator backend (repro.kernels idiom: gate, don't require)
    import jax
    import jax.numpy as jnp
    from jax.scipy.special import erf as _jax_erf
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    jax = None
    jnp = None
    _jax_erf = None
    HAVE_JAX = False

# state codes (match estimation_graph.State member order)
_NONE, _DEDUCED, _SAMPLED, _EXACT = 0, 1, 2, 3
_STATE_OF = {_DEDUCED: State.DEDUCED, _SAMPLED: State.SAMPLED,
             _EXACT: State.EXACT}


def _kind_code(method: str) -> int:
    return 1 if METHODS[method].order_dependent else 0


def assert_plan_identical(ref: Plan, got: Plan, label: str = "") -> None:
    """The engine's parity contract vs `EstimationPlanner.greedy_scalar`:
    same nodes, states, chosen deductions, error RVs, exact sizes,
    total_cost and feasibility.  Shared by the parity tests and
    benchmarks/estimation_scaling.py so the asserted contract cannot
    drift between suites."""
    tag = f"{label}: " if label else ""
    assert got.f == ref.f and got.targets == ref.targets, \
        tag + "plan identity (f / targets) diverged"
    assert set(got.nodes) == set(ref.nodes), f"{tag}node sets diverged"
    for k, na in ref.nodes.items():
        nb = got.nodes[k]
        assert na.state is nb.state, f"{tag}state diverged at {k.label()}"
        assert na.chosen == nb.chosen, \
            f"{tag}chosen deduction diverged at {k.label()}"
        assert na.rv == nb.rv, f"{tag}error RV diverged at {k.label()}"
        assert na.exact_bytes == nb.exact_bytes, \
            f"{tag}exact size diverged at {k.label()}"
    assert got.total_cost == ref.total_cost, \
        f"{tag}total_cost {got.total_cost} != {ref.total_cost}"
    assert got.feasible == ref.feasible, tag + "feasibility diverged"


@dataclasses.dataclass
class _TargetRec:
    """One target's candidate-deduction set, packed for array scoring.

    Every candidate child shares the target's compression method (ColSet
    mates by definition, ColExt parts by construction), so one order-class
    code covers the whole record.
    """
    tid: int
    key: NodeKey
    kind: int                # order-class code of target AND all children
    cands: Tuple[Deduction, ...]
    child_ids: np.ndarray    # (ncand, K) node ids, PAD-padded
    nchild: List[int]        # real (unpadded) child count per candidate
    ded_mean: np.ndarray     # (ncand, 1) deduction-error term (Table 3)
    ded_msq: np.ndarray      # (ncand, 1) ded mean^2   (Goodman E^2 factor)
    ded_vterm: np.ndarray    # (ncand, 1) ded std^2 + mean^2 (V factor)


@dataclasses.dataclass
class _Graph:
    node_keys: List[NodeKey]
    node_id: Dict[NodeKey, int]
    exact: List[Tuple[int, NodeKey, float]]
    recs: List[_TargetRec]
    scost: Dict[Tuple[float, ...], np.ndarray] = \
        dataclasses.field(default_factory=dict)   # per-f-grid cost matrix


@dataclasses.dataclass
class _RunState:
    """Resolved per-(node, f) arrays of one `_run` pass, pre-assembly."""
    g: _Graph
    targets: Tuple[NodeKey, ...]
    f_grid: Tuple[float, ...]
    state: np.ndarray             # (nnodes+1, nf) state codes
    mean: np.ndarray              # (nnodes+1, nf) rv mean
    std: np.ndarray               # (nnodes+1, nf) rv std
    used: np.ndarray              # (nnodes+1, nf) used-as-child flags
    chosen: Dict[Tuple[int, int], Deduction]
    total: List[float]            # per-f accumulated sampling cost


class PlannerEngine:
    """Runs the §5.2 greedy for a whole f grid over one shared graph."""

    def __init__(self, tables: Dict, existing: Optional[Dict] = None,
                 backend: str = "numpy",
                 scost_memo: Optional[Dict] = None):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "jax" and not (HAVE_JAX and jax_batch_ready()):
            backend = "numpy"
        self.backend = backend
        self.tables = tables
        self.existing = dict(existing or {})
        self._graphs: Dict[Tuple[NodeKey, ...], _Graph] = {}
        # (table, cols, f) -> §5.1 sampling cost; an owning
        # EstimationPlanner shares its memo so scalar reference and engine
        # price from one cache
        self._scost: Dict[Tuple[str, Tuple[str, ...], float], float] = \
            scost_memo if scost_memo is not None else {}
        self._pcache: Dict[Tuple[float, float, float], float] = {}
        self.graph_builds = 0   # distinct target sets built
        self.batch_runs = 0     # greedy_batch invocations

    # ------------------------------------------------------------------
    # Graph construction (f-independent; cached per target tuple)
    # ------------------------------------------------------------------
    def _build_graph(self, targets: Sequence[NodeKey]) -> _Graph:
        node_keys: List[NodeKey] = []
        node_id: Dict[NodeKey, int] = {}
        by_set: Dict[Tuple[str, frozenset, str], List[NodeKey]] = {}

        def add(k: NodeKey) -> int:
            nid = node_id.get(k)
            if nid is None:
                nid = node_id[k] = len(node_keys)
                node_keys.append(k)
                by_set.setdefault((k.table, frozenset(k.cols), k.method),
                                  []).append(k)
            return nid

        exact = [(add(k), k, size) for k, size in self.existing.items()]
        for t in targets:
            add(t)

        # materialize candidates in the scalar walk's order; children are
        # always strictly narrower than their creator, so later targets'
        # ColSet-mate lists are unaffected by what gets created here
        raw: List[Tuple[int, NodeKey, Tuple[Deduction, ...]]] = []
        for t in sorted(targets, key=lambda k: (len(k.cols), k.cols)):
            mates = by_set.get((t.table, frozenset(t.cols), t.method), ())
            if METHODS[t.method].order_dependent:
                colset: List[Deduction] = []
            else:
                colset = [_colset_ded(o) for o in mates if o.cols != t.cols]
            cands = tuple(colset + list(_colext_deductions(t)))
            for d in cands:
                for c in d.children:
                    add(c)
            raw.append((node_id[t], t, cands))

        n = len(node_keys)
        pad = n  # virtual EXACT node: neutral under compose, zero cost
        colset_rv = err.colset_error()
        recs: List[_TargetRec] = []
        for tid, t, cands in raw:
            nc = len(cands)
            nchild = [len(d.children) for d in cands]
            # per-target K: most candidates are single-child ColSets, so a
            # global max (wide ColExt partitions) would pad every target
            kmax = max(nchild, default=1)
            child_ids = np.full((nc, kmax), pad, dtype=np.int64)
            ded_mean = np.empty(nc)
            ded_std = np.empty(nc)
            for i, d in enumerate(cands):
                row = child_ids[i]
                for j, c in enumerate(d.children):
                    row[j] = node_id[c]
                drv = (colset_rv if d.kind == "colset"
                       else err.colext_error(t.method, nchild[i]))
                ded_mean[i] = drv.mean
                ded_std[i] = drv.std
            dm = ded_mean[:, None]
            ds = ded_std[:, None]
            msq = dm * dm
            recs.append(_TargetRec(tid, t, _kind_code(t.method), cands,
                                   child_ids, nchild, dm, msq,
                                   ds * ds + msq))
        return _Graph(node_keys, node_id, exact, recs)

    def _graph(self, targets: Sequence[NodeKey]) -> _Graph:
        key = tuple(targets)
        g = self._graphs.get(key)
        if g is None:
            g = self._graphs[key] = self._build_graph(targets)
            self.graph_builds += 1
        return g

    def _sampling_cost(self, key: NodeKey, f: float) -> float:
        return memoized_sampling_cost(self.tables, self._scost, key, f)

    # ------------------------------------------------------------------
    # Scoring backend (vectorized erf)
    # ------------------------------------------------------------------
    def _erf(self, x: np.ndarray) -> np.ndarray:
        """jax backend: jitted erf, padded to pow2 lengths to bound the
        number of compiled shapes.  Not bit-parity with math.erf."""
        n = x.shape[0]
        if n == 0:
            return x
        m = 1 << max(int(n - 1).bit_length(), 0)
        xp = np.zeros(m)
        xp[:n] = x
        return np.asarray(_jax_erf(jnp.asarray(xp)), dtype=np.float64)[:n]

    def _prob(self, means: np.ndarray, stds: np.ndarray,
              e: float) -> np.ndarray:
        if self.backend == "jax":
            return err.prob_within_batch(means, stds, e, erf=self._erf)
        return err.prob_within_batch(means, stds, e)

    def _prob_cached(self, means: np.ndarray, stds: np.ndarray,
                     e: float) -> np.ndarray:
        """`_prob` behind a (e, mean, std) memo — the engine's analogue of
        the scalar path's `lru_cache` on `prob_within`: composed RVs recur
        heavily across candidates, targets, fractions and repeated runs.
        Cache values are exactly the batch-computed floats, so parity is
        unaffected.  Large requests are deduplicated first (packing the
        exact float pair into a complex for one `np.unique`): a ColSet
        group's candidates mostly share one composed RV."""
        pc = self._pcache
        if means.size > 64:
            u, inv = np.unique(means + stds * 1j, return_inverse=True)
            um = u.real
            us = u.imag
        else:
            inv = None
            um, us = means, stds
        ml = um.tolist()
        sl = us.tolist()
        out = [0.0] * len(ml)
        miss: List[int] = []
        for i, a in enumerate(ml):
            v = pc.get((e, a, sl[i]))
            if v is None:
                miss.append(i)
            else:
                out[i] = v
        if miss:
            got = self._prob(np.array([ml[i] for i in miss]),
                             np.array([sl[i] for i in miss]), e).tolist()
            for i, v in zip(miss, got):
                out[i] = v
                pc[(e, ml[i], sl[i])] = v
        res = np.array(out)
        return res[inv] if inv is not None else res

    # ------------------------------------------------------------------
    # The batched greedy (paper §5.2, all fractions at once)
    # ------------------------------------------------------------------
    def _scost_matrix(self, g: _Graph, f_grid: Tuple[float, ...]
                      ) -> np.ndarray:
        """(node x f) §5.1 sampling-cost matrix (pure in table stats)."""
        got = g.scost.get(f_grid)
        if got is None:
            n = len(g.node_keys)
            got = np.zeros((n + 1, len(f_grid)))  # pad row: zero cost
            for nid, k in enumerate(g.node_keys):
                for fi, f in enumerate(f_grid):
                    got[nid, fi] = self._sampling_cost(k, f)
            g.scost[f_grid] = got
        return got

    def greedy_batch(self, targets: Sequence[NodeKey], e: float, q: float,
                     f_grid: Sequence[float] = F_GRID) -> List[Plan]:
        """One `Plan` per fraction in `f_grid`, plan-identical to running
        `EstimationPlanner.greedy_scalar(targets, f, e, q)` per fraction."""
        st = self._run(targets, e, q, f_grid)
        feas = self._feasible_vec(st, e, q)
        return [self._assemble_one(st, fi, bool(feas[fi]))
                for fi in range(len(st.f_grid))]

    def plan_batch(self, targets: Sequence[NodeKey], e: float, q: float,
                   f_grid: Sequence[float] = F_GRID) -> Plan:
        """§5.2 outer loop: cheapest feasible plan over the f grid (else
        the cheapest overall), materializing only the winner."""
        st = self._run(targets, e, q, f_grid)
        feas = self._feasible_vec(st, e, q)
        best_fi: Optional[int] = None
        fb_fi = 0
        for fi in range(len(st.f_grid)):
            if feas[fi] and (best_fi is None
                             or st.total[fi] < st.total[best_fi]):
                best_fi = fi
            if st.total[fi] < st.total[fb_fi]:
                fb_fi = fi
        fi = best_fi if best_fi is not None else fb_fi
        return self._assemble_one(st, fi, bool(feas[fi]))

    def plan_all_sampled_batch(self, targets: Sequence[NodeKey], e: float,
                               q: float, f_grid: Sequence[float] = F_GRID
                               ) -> Plan:
        """The "All" baseline: greedy under FORCE_ALL_Q (every deduction
        fails, so everything samples), feasibility re-judged against the
        caller's q; first feasible fraction wins, else the cheapest."""
        from .estimation_graph import FORCE_ALL_Q
        st = self._run(targets, e, FORCE_ALL_Q, f_grid)
        feas = self._feasible_vec(st, e, q)
        fb_fi = 0
        for fi in range(len(st.f_grid)):
            if feas[fi]:
                return self._assemble_one(st, fi, True)
            if st.total[fi] < st.total[fb_fi]:
                fb_fi = fi
        return self._assemble_one(st, fb_fi, False)

    def _run(self, targets: Sequence[NodeKey], e: float, q: float,
             f_grid: Sequence[float]) -> "_RunState":
        """One pass over the targets, scoring lines 6-9 of the §5.2
        pseudocode for the whole candidate set, for every f, at once.

        One composed-RV evaluation serves BOTH phases: with unknown
        children substituted by their hypothetical SampleCF error, the
        trial RV of lines 8-9 equals the actual deduction RV of lines 6-7
        on fully-known rows (the where() substitutes nothing there), so
        the two phases share one `compose`-equivalent and one
        mask-compressed probability call.
        """
        self.batch_runs += 1
        f_grid = tuple(f_grid)
        g = self._graph(targets)
        nf = len(f_grid)
        n = len(g.node_keys)
        pad = n

        # packed per-(node, f) state: [state code, rv mean, rv std, cost]
        # — one fancy-index gathers everything a candidate row needs
        buf = np.zeros((n + 1, 4, nf))
        buf[:, 1, :] = 1.0                        # default rv = EXACT
        buf[pad, 0, :] = _EXACT
        for nid, _, _ in g.exact:
            buf[nid, 0, :] = _EXACT
        buf[:, 3, :] = self._scost_matrix(g, f_grid)
        state = buf[:, 0, :]
        scost = buf[:, 3, :]

        # SampleCF error RVs per (order class, f) — Table 2 fits
        samp = np.empty((2, 2, nf))               # [kind, mean/std, f]
        rep = {_kind_code(m): m for m in METHODS}
        for kc, method in rep.items():
            for fi, f in enumerate(f_grid):
                rv = err.samplecf_error(method, f)
                samp[kc, 0, fi] = rv.mean
                samp[kc, 1, fi] = rv.std
        samp_mean = samp[:, 0, :]
        samp_std = samp[:, 1, :]

        total = [0.0] * nf
        used = np.zeros((n + 1, nf), dtype=bool)
        chosen: Dict[Tuple[int, int], Deduction] = {}
        false_f = np.zeros(nf, dtype=bool)

        for rec in g.recs:
            tid = rec.tid
            act = state[tid] == _NONE              # (nf,)
            if not act.any():
                continue
            nc = len(rec.cands)
            kc = rec.kind
            has6 = has9 = false_f
            if nc:
                ch = buf[rec.child_ids]            # (nc, K, 4, nf)
                known = ch[:, :, 0, :] != _NONE
                allk = known.all(axis=1)           # (nc, nf)
                any_unknown = not allk.all()
                m_t = ch[:, :, 1, :]
                s_t = ch[:, :, 2, :]
                if any_unknown:
                    # children RVs, unknown ones hypothetically sampled
                    # (all children share the target's method, hence one
                    # Table 2 error fit per record)
                    m_t = np.where(known, m_t, samp_mean[kc])
                    s_t = np.where(known, s_t, samp_std[kc])

                # Goodman fold over the children axis, continued with the
                # deduction-error factor — bit-identical to the scalar
                # compose (children in order, deduction term last)
                cm, v, e2 = err.goodman_fold(m_t, s_t, axis=1)
                cm = cm * rec.ded_mean
                v = v * rec.ded_vterm
                e2 = e2 * rec.ded_msq
                cs = np.sqrt(np.maximum(v - e2, 0.0))

                mask67 = allk & act
                if any_unknown:
                    # lines 8-9 precondition: summed sampling cost of the
                    # unknown children.  add.reduce over a non-contiguous
                    # axis is a sequential fold (numpy pairwise blocking
                    # needs the reduction axis contiguous), and the known
                    # children's exact 0.0 terms leave every partial sum
                    # unchanged — so this matches the scalar child-order
                    # sum bit-for-bit (asserted in the parity tests).
                    extra = np.add.reduce(
                        np.where(known, 0.0, ch[:, :, 3, :]), axis=1)
                    my_cost = scost[tid]           # (nf,)
                    pre9 = ~allk & (extra < my_cost) & act
                    mask_p = mask67 | pre9
                else:
                    pre9 = None
                    mask_p = mask67

                # one probability pass over both phases' eligible entries
                p = np.zeros((nc, nf))
                ii = mask_p.nonzero()
                if ii[0].size:
                    p[ii] = self._prob_cached(cm[ii], cs[ii], e)
                sat = p >= q

                # ---- lines 6-7: an enabled deduction satisfying (e, q) --
                elig = mask67 & sat
                has6 = elig.any(axis=0)
                if has6.any():
                    w6 = np.argmax(np.where(elig, p, -1.0), axis=0)
                    for fi in np.nonzero(has6)[0]:
                        w = int(w6[fi])
                        buf[tid, :3, fi] = _DEDUCED, cm[w, fi], cs[w, fi]
                        chosen[(tid, fi)] = rec.cands[w]
                        used[rec.child_ids[w], fi] = True

                # ---- lines 8-9: enable one by sampling unknown children -
                has9 = false_f
                if pre9 is not None:
                    ok9 = pre9 & sat & ~has6
                    has9 = ok9.any(axis=0)
                if has9.any():
                    w9 = np.argmin(np.where(ok9, extra, np.inf), axis=0)
                    for fi in np.nonzero(has9)[0]:
                        w = int(w9[fi])
                        for cid in rec.child_ids[w, :rec.nchild[w]]:
                            if buf[cid, 0, fi] == _NONE:
                                buf[cid, :3, fi] = (_SAMPLED,
                                                    samp_mean[kc, fi],
                                                    samp_std[kc, fi])
                                total[fi] += float(scost[cid, fi])
                        buf[tid, :3, fi] = _DEDUCED, cm[w, fi], cs[w, fi]
                        chosen[(tid, fi)] = rec.cands[w]
                        used[rec.child_ids[w], fi] = True

            # ---- lines 10-11: fall back to SampleCF on this target ------
            rest = np.nonzero(act & ~has6 & ~has9)[0]
            if rest.size:
                buf[tid, 0, rest] = _SAMPLED
                buf[tid, 1, rest] = samp_mean[kc, rest]
                buf[tid, 2, rest] = samp_std[kc, rest]
                for fi in rest:
                    total[fi] += float(scost[tid, fi])

        return _RunState(g=g, targets=tuple(targets), f_grid=f_grid,
                         state=state, mean=buf[:, 1, :], std=buf[:, 2, :],
                         used=used, chosen=chosen, total=total)

    # ------------------------------------------------------------------
    def _feasible_vec(self, st: "_RunState", e: float,
                      q: float) -> np.ndarray:
        """Per-f feasibility: every target's final RV satisfies (e, q).
        Probability values are the same memoized batch floats the scalar
        `err.satisfies` would produce, so flags agree bit-for-bit."""
        tids = [st.g.node_id[t] for t in st.targets]
        m = st.mean[tids]                          # (ntargets, nf)
        s = st.std[tids]
        p = self._prob_cached(m.ravel(), s.ravel(), e).reshape(m.shape)
        return (p >= q).all(axis=0)

    def _assemble_one(self, st: "_RunState", fi: int,
                      feasible: bool) -> Plan:
        """Materialize fraction `fi`'s `Plan` (scalar lines 13-14 cleanup:
        keep only targets, used children, and EXACT existing nodes)."""
        g = st.g
        f = st.f_grid[fi]
        n = len(g.node_keys)
        is_target = np.zeros(n, dtype=bool)
        is_target[[g.node_id[t] for t in st.targets]] = True
        # pull the f column out as plain Python scalars once — per-node
        # numpy scalar indexing would dominate the assembly otherwise
        st_col = st.state[:, fi].tolist()
        m_col = st.mean[:, fi].tolist()
        s_col = st.std[:, fi].tolist()
        nodes: Dict[NodeKey, Node] = {}
        for _, k, size in g.exact:
            nodes[k] = Node(k, State.EXACT, rv=err.EXACT, exact_bytes=size)
        for nid in np.nonzero(st.used[:n, fi] | is_target)[0].tolist():
            k = g.node_keys[nid]
            if k in nodes:
                continue
            code = int(st_col[nid])
            assert code != _NONE, f"unresolved plan node {k.label()}"
            node = Node(k, _STATE_OF[code])
            if code == _SAMPLED:
                node.rv = err.samplecf_error(k.method, f)
            else:  # DEDUCED
                node.chosen = st.chosen[(nid, fi)]
                node.rv = err.ErrorRV(m_col[nid], s_col[nid])
            nodes[k] = node
        return Plan(f=f, nodes=nodes, targets=st.targets,
                    total_cost=st.total[fi], feasible=feasible)

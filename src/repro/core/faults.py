"""Deterministic fault injection for the advisor stack.

A long-lived advisor deployment (the fleet service) has to survive
transient estimation failures, poisoned deltas, lost prefetches and
outright session loss — and the repo's exact-parity contract has to
hold THROUGH those failures, not just in the happy path.  Testing that
requires failures that are perfectly reproducible: `FaultInjector`
draws every fire/no-fire decision from a per-site seed-derived RNG
stream indexed by that site's own check counter, so the fault schedule
is a pure function of (seed, site, per-site check index) — independent
of how checks at DIFFERENT sites interleave, exactly like
`SampleManager`'s order-independent sample streams.

Sites (the places the stack calls `check()` / `fires()`):

* ``estimation``   — `AdvisorSession._estimate_sizes` (the SampleCF
  execution phase of a recommend).
* ``costing``      — `AdvisorSession.recommend` before the what-if
  costing phase.
* ``planner_replay`` — `PlannerEngine._run`: a firing here does not
  raise; it DROPS the replay store (cache-loss semantics — the next
  run recomputes every decision, bit-identically).
* ``prefetch``     — `AdvisorFleetService._prefetch`, once per
  (group, f) batch.
* ``apply_delta``  — top of `AdvisorSession.apply`, before any state
  is touched (so a faulted delta is cleanly retryable).
* ``disk_write``   — `durability.DurableStore.log_delta`: a firing
  here tears the append (only a prefix of the record reaches the file)
  and raises; the next append truncates back to the last good offset,
  and recovery truncates the torn tail the same way.
* ``fsync``        — the store's WAL group-commit fsync: the record is
  fully written but its durability is unconfirmed, so the store
  appends an ABORT record for it and raises (the retry re-journals
  under a fresh sequence number — replay can never double-apply).
* ``bit_flip``     — silent media corruption: one bit of the record
  payload is flipped BEFORE it is written (deterministically derived
  from the site's check index), no error is raised, and only
  recovery's CRC scan can detect it (mid-log corruption quarantines
  the tenant).

Site streams are seeded independently per site — (seed,
crc32(site)) — so enabling the disk sites cannot shift a single draw
of the PR 7 sites' schedules (pinned by a regression test in
tests/test_faults.py).

`FaultError` marks a fault as TRANSIENT: the fleet service retries
requests that fail with it (bounded, deterministic backoff) and treats
anything else as a real failure feeding the per-tenant circuit
breaker.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple, Union

import numpy as np

#: The named sites the advisor stack is instrumented with.  The disk
#: sites ("disk_write", "fsync", "bit_flip") were appended for the
#: durability layer; appending keeps every earlier site's stream seed —
#: (seed, crc32(site)) — untouched.
SITES = ("estimation", "costing", "planner_replay", "prefetch",
         "apply_delta", "disk_write", "fsync", "bit_flip")


class FaultError(RuntimeError):
    """An injected, transient fault (retryable by the fleet service)."""

    def __init__(self, site: str, n: int, detail: str = ""):
        msg = f"injected fault at site {site!r} (check #{n})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)
        self.site = site
        self.n = n


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """When a site fires.

    `rate` fires each check independently with that probability (drawn
    from the site's own RNG stream).  `at` additionally fires at the
    given 0-based check indices — the deterministic way to script "the
    second estimation of the run fails".  `max_fires` caps the total
    fires at the site (the stream keeps advancing, so the schedule of a
    capped site is a prefix of the uncapped one)."""
    rate: float = 0.0
    at: Tuple[int, ...] = ()
    max_fires: Optional[int] = None


class FaultInjector:
    """Seeded, per-site deterministic fault source.

    Usage::

        inj = FaultInjector(seed=7, specs={
            "estimation": 0.05,                  # shorthand for rate
            "apply_delta": FaultSpec(at=(0, 3)), # scripted checks
        })
        inj.check("estimation")    # raises FaultError when it fires
        if inj.fires("planner_replay"): ...   # poll form (no raise)

    Determinism: site streams are seeded by (seed, crc32(site)) and
    consumed one draw per check at that site, so two runs issuing the
    same per-site check sequences see the same faults regardless of how
    sites interleave globally.
    """

    def __init__(self, seed: int = 0,
                 specs: Optional[Dict[str, Union[float, FaultSpec]]] = None):
        self.seed = int(seed)
        self.specs: Dict[str, FaultSpec] = {}
        for site, sp in (specs or {}).items():
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; known sites: {SITES}")
            self.specs[site] = (sp if isinstance(sp, FaultSpec)
                                else FaultSpec(rate=float(sp)))
        self._rng = {
            site: np.random.default_rng(
                (self.seed, zlib.crc32(site.encode("utf-8"))))
            for site in SITES}
        self.checks: Dict[str, int] = {s: 0 for s in SITES}
        self.fired: Dict[str, int] = {s: 0 for s in SITES}

    def fires(self, site: str) -> bool:
        """Advance `site`'s stream one check; True when the fault fires."""
        n = self.checks[site]
        self.checks[site] = n + 1
        sp = self.specs.get(site)
        if sp is None:
            return False
        hit = n in sp.at
        if sp.rate > 0.0:
            # always draw, so the stream position is a pure function of
            # the check index (scripted `at` hits don't shift it)
            hit = bool(self._rng[site].random() < sp.rate) or hit
        if not hit:
            return False
        if sp.max_fires is not None and self.fired[site] >= sp.max_fires:
            return False
        self.fired[site] += 1
        return True

    def check(self, site: str, detail: str = "") -> None:
        """Raise `FaultError` when the fault at `site` fires."""
        if self.fires(site):
            raise FaultError(site, self.checks[site] - 1, detail)

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"checks": dict(self.checks), "fired": dict(self.fired)}

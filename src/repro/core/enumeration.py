"""Enumeration (paper §6.2): greedy search over the candidate pool.

Three variants:
* pure      — classic greedy: add the index with the largest workload-cost
              reduction that still fits the budget.
* density   — greedy on benefit/size ratio (DB2-style [15]).
* backtrack — the paper's contribution: pure greedy, but when the best
              choice is OVERSIZED, try to recover it by replacing members
              of the would-be configuration with their compressed variants
              (Figure 8), then compare against the feasible greedy choices.

Clustered candidates replace the table's current clustered layout instead of
being added alongside it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .relation import IndexDef
from .whatif import Configuration, SizeProvider, WhatIfOptimizer, storage_used


@dataclasses.dataclass
class EnumerationResult:
    config: Configuration
    cost: float
    used_bytes: float
    steps: List[str]


def _apply(config: Configuration, idx: IndexDef) -> Configuration:
    if idx.clustered:
        old = config.clustered(idx.table)
        return config.replace(old, idx) if old else config.add(idx)
    return config.add(idx)


def _variants_of(idx: IndexDef, pool: Sequence[IndexDef]) -> List[IndexDef]:
    """Compressed variants of `idx` available in the pool."""
    return [p for p in pool
            if p.table == idx.table and p.cols == idx.cols
            and p.clustered == idx.clustered and p.predicate == idx.predicate
            and p.compression != idx.compression
            and p.compression is not None]


def _already_present(config: Configuration, idx: IndexDef) -> bool:
    for i in config.indexes:
        if (i.table == idx.table and i.cols == idx.cols
                and i.predicate == idx.predicate
                and i.clustered == idx.clustered):
            return True
    return False


def greedy_enumerate(optimizer: WhatIfOptimizer, sizes: SizeProvider,
                     pool: Sequence[IndexDef], base: Configuration,
                     budget_bytes: float, variant: str = "backtrack",
                     max_indexes: int = 64) -> EnumerationResult:
    assert variant in ("pure", "density", "backtrack")
    config = base
    cost = optimizer.workload_cost(config)
    steps: List[str] = []

    for _ in range(max_indexes):
        used = storage_used(config, base, sizes)
        best_feasible: Optional[Tuple[float, IndexDef, Configuration]] = None
        best_any: Optional[Tuple[float, IndexDef, Configuration]] = None

        for idx in pool:
            if _already_present(config, idx):
                continue
            cfg2 = _apply(config, idx)
            used2 = storage_used(cfg2, base, sizes)
            cost2 = optimizer.workload_cost(cfg2)
            benefit = cost - cost2
            if benefit <= 1e-9:
                continue
            delta_size = max(used2 - used, 1.0)
            score = benefit / delta_size if variant == "density" else benefit
            entry = (score, idx, cfg2)
            if used2 <= budget_bytes:
                if best_feasible is None or score > best_feasible[0]:
                    best_feasible = entry
            if best_any is None or score > best_any[0]:
                best_any = entry

        chosen: Optional[Tuple[IndexDef, Configuration]] = None
        if variant == "backtrack" and best_any is not None and (
                best_feasible is None or best_any[1] != best_feasible[1]):
            # The greedy-best choice is oversized: attempt recovery by
            # swapping each member for a compressed variant (Figure 8).
            oversized_cfg = best_any[2]
            recovered = _recover_oversized(
                oversized_cfg, base, pool, sizes, optimizer, budget_bytes)
            cand_cost = optimizer.workload_cost(recovered) \
                if recovered is not None else float("inf")
            feas_cost = optimizer.workload_cost(best_feasible[2]) \
                if best_feasible is not None else float("inf")
            if recovered is not None and cand_cost < min(feas_cost, cost):
                chosen = (best_any[1], recovered)
                steps.append(f"backtrack-recovered via {best_any[1].label()}")
            elif best_feasible is not None:
                chosen = (best_feasible[1], best_feasible[2])
        elif best_feasible is not None:
            chosen = (best_feasible[1], best_feasible[2])

        if chosen is None:
            break
        config = chosen[1]
        new_cost = optimizer.workload_cost(config)
        steps.append(f"add {chosen[0].label()}  cost {cost:.1f}->{new_cost:.1f}")
        cost = new_cost

    return EnumerationResult(config=config, cost=cost,
                             used_bytes=storage_used(config, base, sizes),
                             steps=steps)


def _recover_oversized(config: Configuration, base: Configuration,
                       pool: Sequence[IndexDef], sizes: SizeProvider,
                       optimizer: WhatIfOptimizer,
                       budget_bytes: float) -> Optional[Configuration]:
    """Figure 8: replace members with compressed variants until it fits.

    Considers replacing each index (including repeatedly, cheapest-cost-loss
    first) and returns the fastest configuration that fits, or None.
    """
    best: Optional[Tuple[float, Configuration]] = None
    frontier = [config]
    seen = {config.indexes}
    for _ in range(4):  # bounded replacement depth
        nxt: List[Configuration] = []
        for cfg in frontier:
            for idx in sorted(cfg.indexes, key=lambda i: i.label()):
                if idx.compression is not None:
                    continue
                for var in _variants_of(idx, pool):
                    cfg2 = cfg.replace(idx, var)
                    if cfg2.indexes in seen:
                        continue
                    seen.add(cfg2.indexes)
                    if storage_used(cfg2, base, sizes) <= budget_bytes:
                        c = optimizer.workload_cost(cfg2)
                        if best is None or c < best[0]:
                            best = (c, cfg2)
                    else:
                        nxt.append(cfg2)
        if best is not None or not nxt:
            break
        frontier = nxt
    return best[1] if best else None

"""Enumeration (paper §6.2): greedy search over the candidate pool.

Three variants:
* pure      — classic greedy: add the index with the largest workload-cost
              reduction that still fits the budget.
* density   — greedy on benefit/size ratio (DB2-style [15]).
* backtrack — the paper's contribution: pure greedy, but when the best
              choice is OVERSIZED, try to recover it by replacing members
              of the would-be configuration with their compressed variants
              (Figure 8), then compare against the feasible greedy choices.

Clustered candidates replace the table's current clustered layout instead of
being added alongside it.

Two execution paths:

* the batched path (default) drives a repro.core.cost_engine.CostEngine and
  scores the whole pool per greedy step with a few vectorized ops, using
  incremental delta evaluation — a candidate on table T only re-evaluates
  statements on T;
* `greedy_enumerate_scalar` is the original statement-at-a-time
  implementation, kept as the correctness reference (the benchmark and the
  parity tests compare the two).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_engine import CostEngine, TableEval
from .relation import IndexDef
from .whatif import Configuration, SizeProvider, WhatIfOptimizer, storage_used


@dataclasses.dataclass
class EnumerationResult:
    config: Configuration
    cost: float
    used_bytes: float
    steps: List[str]


def _apply(config: Configuration, idx: IndexDef) -> Configuration:
    if idx.clustered:
        old = config.clustered(idx.table)
        return config.replace(old, idx) if old else config.add(idx)
    return config.add(idx)


def _variants_of(idx: IndexDef, pool: Sequence[IndexDef]) -> List[IndexDef]:
    """Compressed variants of `idx` available in the pool."""
    return [p for p in pool
            if p.table == idx.table and p.cols == idx.cols
            and p.clustered == idx.clustered and p.predicate == idx.predicate
            and p.compression != idx.compression
            and p.compression is not None]


def _already_present(config: Configuration, idx: IndexDef) -> bool:
    for i in config.indexes:
        if (i.table == idx.table and i.cols == idx.cols
                and i.predicate == idx.predicate
                and i.clustered == idx.clustered):
            return True
    return False


# ---------------------------------------------------------------------------
# Batched greedy (the default path)
# ---------------------------------------------------------------------------

def greedy_enumerate(optimizer: WhatIfOptimizer, sizes: SizeProvider,
                     pool: Sequence[IndexDef], base: Configuration,
                     budget_bytes: float, variant: str = "backtrack",
                     max_indexes: int = 64,
                     engine: Optional[CostEngine] = None,
                     score_chunk_cells: int = 1 << 22,
                     backend: str = "numpy") -> EnumerationResult:
    """Engine-backed hierarchical greedy: candidates are partitioned by
    table, a step re-scores only the partitions its chosen index touched
    (the `stale` set), and each partition's vectorized scoring runs in
    candidate chunks of at most `score_chunk_cells` matrix cells — so the
    peak scratch allocation stays bounded on large workloads.  Chunking is
    value-neutral: every candidate column is scored independently, so the
    results are bit-identical to one monolithic scoring call.

    `backend` selects the accelerator for a fallback-constructed engine
    (the unified knob, resolved via `core.backend`); a caller-supplied
    `engine` keeps its own backend."""
    assert variant in ("pure", "density", "backtrack")
    if engine is None:
        engine = CostEngine(optimizer.workload, sizes, backend=backend)
    pool = list(pool)
    engine.register(base.indexes)

    config = base
    evals: Dict[str, TableEval] = {
        t: engine.table_eval(config, t) for t in engine.blocks}
    cost = sum(e.total for e in evals.values())
    steps: List[str] = []

    # ---- per-step bookkeeping, precomputed once over the pool ----------
    # engine column ids, per-table candidate index arrays, and an
    # incrementally-maintained already-present mask replace the former
    # per-step pool scans (O(pool x config) per greedy step).
    n = len(pool)
    pool_ids = engine.register(pool)
    pool_sizes = np.array([sizes.size(p) for p in pool]) if n else np.zeros(0)
    pool_tables = sorted({p.table for p in pool})

    def sig(idx: IndexDef) -> Tuple:
        # the identity _already_present() compares on
        return (idx.table, idx.cols, idx.predicate, idx.clustered)

    sig_to_ks: Dict[Tuple, List[int]] = {}
    for k, p in enumerate(pool):
        sig_to_ks.setdefault(sig(p), []).append(k)
    sec_ks_by_table = {
        t: np.array([k for k, p in enumerate(pool)
                     if p.table == t and not p.clustered], dtype=np.int64)
        for t in pool_tables}
    cl_ks_by_table = {
        t: np.array([k for k, p in enumerate(pool)
                     if p.table == t and p.clustered], dtype=np.int64)
        for t in pool_tables}
    present = np.zeros(n, dtype=bool)

    def recompute_present(cfg: Configuration) -> None:
        present[:] = False
        for idx in cfg.indexes:
            ks = sig_to_ks.get(sig(idx))
            if ks:
                present[ks] = True

    recompute_present(config)

    # per-table benefit/delta-used caches: a greedy step only changes ONE
    # table's configuration, so every other table's scores are reused
    # verbatim (the recomputed values would be bit-identical)
    benefit = np.full(n, -np.inf)
    delta_used = np.zeros(n)
    stale = set(pool_tables)

    def rescore(t: str) -> None:
        c_id, sec_ids = engine.split(config, t)
        cur = evals[t]
        nq = max(1, len(engine.blocks[t].queries))
        all_sec = sec_ks_by_table[t]
        benefit[all_sec] = -np.inf
        sec_ks = all_sec[~present[all_sec]]
        step = max(1, score_chunk_cells // nq)
        for lo in range(0, sec_ks.size, step):
            ks = sec_ks[lo:lo + step]
            q_tot, upd_delta = engine.score_add_secondary(
                t, c_id, cur.q_cost, pool_ids[ks])
            benefit[ks] = cur.total - (q_tot + cur.u_total + upd_delta)
            delta_used[ks] = pool_sizes[ks]
        all_cl = cl_ks_by_table[t]
        benefit[all_cl] = -np.inf
        cl_ks = all_cl[~present[all_cl]]
        if cl_ks.size:
            old_c = config.clustered(t)
            old_size = sizes.size(old_c) if old_c is not None else 0.0
            # the clustered-swap kernel allocates (nq, n_sec, chunk) paths
            step = max(1, score_chunk_cells // (nq * max(1, len(sec_ids))))
            for lo in range(0, cl_ks.size, step):
                ks = cl_ks[lo:lo + step]
                q_tot, upd_c = engine.score_replace_clustered(
                    t, sec_ids, pool_ids[ks])
                benefit[ks] = cur.total - (q_tot + upd_c + cur.sec_upd)
                delta_used[ks] = pool_sizes[ks] - old_size

    for _ in range(max_indexes):
        if not n:
            break
        used = storage_used(config, base, sizes)
        for t in sorted(stale):
            rescore(t)
        stale.clear()

        valid = benefit > 1e-9
        if not valid.any():
            break
        if variant == "density":
            score = np.where(valid,
                             benefit / np.maximum(delta_used, 1.0), -np.inf)
        else:
            score = np.where(valid, benefit, -np.inf)
        feasible = valid & (used + delta_used <= budget_bytes)

        best_any_k = int(np.argmax(score))
        best_feas_k: Optional[int] = None
        if feasible.any():
            feas_score = np.where(feasible, score, -np.inf)
            best_feas_k = int(np.argmax(feas_score))

        chosen: Optional[Tuple[IndexDef, Configuration]] = None
        recovered_choice = False
        if variant == "backtrack" and (best_feas_k is None
                                       or best_any_k != best_feas_k):
            # The greedy-best choice is oversized: attempt recovery by
            # swapping members for compressed variants (Figure 8).
            oversized_cfg = _apply(config, pool[best_any_k])
            recovered = _recover_oversized(
                oversized_cfg, base, pool, sizes, engine.config_cost,
                budget_bytes)
            cand_cost = engine.config_cost(recovered) \
                if recovered is not None else float("inf")
            feas_cost = engine.config_cost(
                _apply(config, pool[best_feas_k])) \
                if best_feas_k is not None else float("inf")
            if recovered is not None and cand_cost < min(feas_cost, cost):
                chosen = (pool[best_any_k], recovered)
                recovered_choice = True
                steps.append(
                    f"backtrack-recovered via {pool[best_any_k].label()}")
            elif best_feas_k is not None:
                chosen = (pool[best_feas_k],
                          _apply(config, pool[best_feas_k]))
        elif best_feas_k is not None:
            chosen = (pool[best_feas_k], _apply(config, pool[best_feas_k]))

        if chosen is None:
            break
        config = chosen[1]
        # re-derive the present mask from the new config: a clustered
        # replacement also REMOVES a layout, which can free pool entries
        recompute_present(config)
        if recovered_choice:
            evals = {t: engine.table_eval(config, t) for t in engine.blocks}
            stale.update(pool_tables)
        else:
            t = chosen[0].table
            evals[t] = engine.table_eval(config, t)
            stale.add(t)
        new_cost = sum(e.total for e in evals.values())
        steps.append(f"add {chosen[0].label()}  cost {cost:.1f}->{new_cost:.1f}")
        cost = new_cost

    return EnumerationResult(config=config, cost=cost,
                             used_bytes=storage_used(config, base, sizes),
                             steps=steps)


# ---------------------------------------------------------------------------
# Scalar reference (the original statement-at-a-time implementation)
# ---------------------------------------------------------------------------

def greedy_enumerate_scalar(optimizer: WhatIfOptimizer, sizes: SizeProvider,
                            pool: Sequence[IndexDef], base: Configuration,
                            budget_bytes: float, variant: str = "backtrack",
                            max_indexes: int = 64) -> EnumerationResult:
    assert variant in ("pure", "density", "backtrack")
    config = base
    cost = optimizer.workload_cost(config)
    steps: List[str] = []

    for _ in range(max_indexes):
        used = storage_used(config, base, sizes)
        best_feasible: Optional[Tuple[float, IndexDef, Configuration]] = None
        best_any: Optional[Tuple[float, IndexDef, Configuration]] = None

        for idx in pool:
            if _already_present(config, idx):
                continue
            cfg2 = _apply(config, idx)
            used2 = storage_used(cfg2, base, sizes)
            cost2 = optimizer.workload_cost(cfg2)
            benefit = cost - cost2
            if benefit <= 1e-9:
                continue
            delta_size = max(used2 - used, 1.0)
            score = benefit / delta_size if variant == "density" else benefit
            entry = (score, idx, cfg2)
            if used2 <= budget_bytes:
                if best_feasible is None or score > best_feasible[0]:
                    best_feasible = entry
            if best_any is None or score > best_any[0]:
                best_any = entry

        chosen: Optional[Tuple[IndexDef, Configuration]] = None
        if variant == "backtrack" and best_any is not None and (
                best_feasible is None or best_any[1] != best_feasible[1]):
            # The greedy-best choice is oversized: attempt recovery by
            # swapping each member for a compressed variant (Figure 8).
            oversized_cfg = best_any[2]
            recovered = _recover_oversized(
                oversized_cfg, base, pool, sizes, optimizer.workload_cost,
                budget_bytes)
            cand_cost = optimizer.workload_cost(recovered) \
                if recovered is not None else float("inf")
            feas_cost = optimizer.workload_cost(best_feasible[2]) \
                if best_feasible is not None else float("inf")
            if recovered is not None and cand_cost < min(feas_cost, cost):
                chosen = (best_any[1], recovered)
                steps.append(f"backtrack-recovered via {best_any[1].label()}")
            elif best_feasible is not None:
                chosen = (best_feasible[1], best_feasible[2])
        elif best_feasible is not None:
            chosen = (best_feasible[1], best_feasible[2])

        if chosen is None:
            break
        config = chosen[1]
        new_cost = optimizer.workload_cost(config)
        steps.append(f"add {chosen[0].label()}  cost {cost:.1f}->{new_cost:.1f}")
        cost = new_cost

    return EnumerationResult(config=config, cost=cost,
                             used_bytes=storage_used(config, base, sizes),
                             steps=steps)


def _recover_oversized(config: Configuration, base: Configuration,
                       pool: Sequence[IndexDef], sizes: SizeProvider,
                       cost_fn: Callable[[Configuration], float],
                       budget_bytes: float) -> Optional[Configuration]:
    """Figure 8: replace members with compressed variants until it fits.

    Considers replacing each index (including repeatedly, cheapest-cost-loss
    first) and returns the fastest configuration that fits, or None.
    `cost_fn` is any workload-cost oracle — the scalar optimizer or the
    batched engine.
    """
    best: Optional[Tuple[float, Configuration]] = None
    frontier = [config]
    seen = {config.indexes}
    for _ in range(4):  # bounded replacement depth
        nxt: List[Configuration] = []
        for cfg in frontier:
            for idx in sorted(cfg.indexes, key=lambda i: i.label()):
                if idx.compression is not None:
                    continue
                for var in _variants_of(idx, pool):
                    cfg2 = cfg.replace(idx, var)
                    if cfg2.indexes in seen:
                        continue
                    seen.add(cfg2.indexes)
                    if storage_used(cfg2, base, sizes) <= budget_bytes:
                        c = cost_fn(cfg2)
                        if best is None or c < best[0]:
                            best = (c, cfg2)
                    else:
                        nxt.append(cfg2)
        if best is not None or not nxt:
            break
        frontier = nxt
    return best[1] if best else None

"""Online advisor sessions: delta-aware re-advising over long-lived engines.

The paper's advisor is a one-shot tool; under the ROADMAP's continuous-
retuning regime (self-driving databases re-advise as the workload drifts)
every `DesignAdvisor.recommend` call would rebuild the candidate universe,
the CostEngine matrices, the shared deduction graph and all size estimates
from scratch — even when only a few statements changed.  `AdvisorSession`
owns persistent engines and supports `add_statements` / `remove_statements`
/ `reweight` followed by cheap `recommend(budget)` calls whose cost is
proportional to the workload *delta*:

* **Candidate universe** — per-query syntactic candidates and their
  compression expansions are pure in the query, cached by statement name;
  only the dedup/merge pass re-runs per round (it is order-sensitive and
  cheap).
* **Size estimation** — the persistent `PlannerEngine` keeps its node
  universe, packed target records and per-target decision replays across
  rounds (only delta-affected targets are re-scored), and SAMPLED
  estimates are cached by (NodeKey, f) over the order-independent
  `SampleManager`, so only genuinely new compressed candidates are
  sampled.
* **What-if costing** — the persistent `CostEngine` appends/drops
  statement rows and refreshes only columns whose registered size changed
  (`apply_delta` / `sync_sizes`) instead of rebuilding its matrices; the
  engine honors the unified `AdvisorOptions(backend=...)` knob, and the
  fleet can prefetch candidate costs across tenants via
  `peek_cost_jobs` / `accept_cost_results` (keyed by workload_version,
  consumed verbatim by the next `recommend` — bit-identical to costing
  in-line).
* **Selection** — per-query skyline/top-k selections are reused unless a
  delta re-sized one of the query's candidates (checked against the set
  of re-registered index keys).

Correctness contract (asserted in tests/test_session.py and
benchmarks/session_scaling.py): after ANY delta sequence, `recommend`
returns a recommendation identical — config, cost, used_bytes — to a
fresh `DesignAdvisor` built on the resulting workload.  Every stage
either reuses the one-shot advisor's code verbatim or caches values that
are pure functions of the same inputs, so the parity is bit-exact, not
approximate.
"""
from __future__ import annotations

import dataclasses
import pickle
import struct
import time
import zlib
from typing import Dict, Iterable, List, Mapping, Optional, Set, Tuple, Union

from . import candidates as cand
from .advisor import (AdvisorOptions, DesignAdvisor, Recommendation,
                      enumerate_pool, pool_with_merged, select_candidates)
from .cost_engine import CostEngine
from .estimation_engine import EstimationEngine
from .estimation_graph import EstimationPlanner, NodeKey, Plan, State
from .faults import FaultInjector
from .relation import IndexDef
from .samplecf import EstimateCache, SampleManager, SizeEstimate
from .whatif import SizeProvider, WhatIfOptimizer, base_configuration
from .workload import Query, Statement, Workload, WorkloadDelta
from .workload_compression import ClusterIndex, CompressedWorkload


@dataclasses.dataclass
class _QueryEntry:
    """Per-statement candidate cache (pure in the query)."""
    raw: List[IndexDef]        # syntactically relevant candidates
    exp: List[IndexDef]        # compression-expanded candidates
    key_set: frozenset         # exp candidates' index keys (invalidation)


@dataclasses.dataclass
class _Selection:
    """Per-statement §6.1 selection cache (pure in query + sizes)."""
    selected: List[cand.Candidate]
    n_costed: int


#: Serialized-snapshot framing: magic + format version + payload length
#: + CRC32(payload), then the pickled snapshot.  The header is what lets
#: `from_bytes` tell "tampered or truncated" (SnapshotCorrupt, with the
#: offset and expected-vs-actual checksum) apart from "a different,
#: incompatible format version" — instead of surfacing whatever
#: `pickle.loads` happens to throw at corrupt bytes.
SNAPSHOT_MAGIC = b"RSNP"
SNAPSHOT_FORMAT_VERSION = 1
_SNAP_HEADER = struct.Struct("<4sHII")   # magic, version, length, crc32


class SnapshotCorrupt(ValueError):
    """Serialized `SessionSnapshot` bytes failed validation.

    `offset` is the byte offset of the failure; for checksum failures
    `expected_crc` / `actual_crc` carry the header CRC vs the CRC of the
    bytes actually present."""

    def __init__(self, msg: str, offset: int = 0,
                 expected_crc: Optional[int] = None,
                 actual_crc: Optional[int] = None):
        detail = f"{msg} (at byte {offset}"
        if expected_crc is not None:
            detail += (f"; checksum expected {expected_crc:#010x}, "
                       f"actual {actual_crc:#010x}")
        super().__init__(detail + ")")
        self.offset = offset
        self.expected_crc = expected_crc
        self.actual_crc = actual_crc


@dataclasses.dataclass
class SessionSnapshot:
    """Self-contained checkpoint of an `AdvisorSession`.

    Captures exactly the state the parity contract depends on — the
    workload (schema + statements), the options, the retired-name set,
    and the monotone workload version — plus the warm (NodeKey, f)
    SampleCF estimates (pure in (schema content, sample seed, NodeKey,
    f), so carrying them is a pure optimization).  Everything else a
    session holds (cost matrices, planner records, cluster index,
    selections) is derivable from these and is rebuilt lazily by the
    restored session; `AdvisorSession.restore(snapshot)` therefore
    recommends exactly `==` a fresh `DesignAdvisor` on the snapshot
    workload.  `to_bytes`/`from_bytes` give a durable serialized form
    (the fleet's crash-recovery path round-trips through it in tests).
    """
    workload: Workload
    options: AdvisorOptions
    workload_version: int
    retired: frozenset
    estimates: Dict[Tuple[NodeKey, float], SizeEstimate]

    def to_bytes(self) -> bytes:
        payload = pickle.dumps(self)
        return _SNAP_HEADER.pack(SNAPSHOT_MAGIC, SNAPSHOT_FORMAT_VERSION,
                                 len(payload), zlib.crc32(payload)) + payload

    @staticmethod
    def from_bytes(data: bytes) -> "SessionSnapshot":
        data = bytes(data)
        if len(data) < _SNAP_HEADER.size:
            raise SnapshotCorrupt(
                f"truncated snapshot: {len(data)} bytes is shorter than "
                f"the {_SNAP_HEADER.size}-byte header", offset=len(data))
        magic, version, length, crc = _SNAP_HEADER.unpack_from(data, 0)
        if magic != SNAPSHOT_MAGIC:
            raise SnapshotCorrupt(
                f"bad magic {magic!r} (expected {SNAPSHOT_MAGIC!r}) — not "
                "a serialized SessionSnapshot", offset=0)
        if version != SNAPSHOT_FORMAT_VERSION:
            raise SnapshotCorrupt(
                f"snapshot format version {version} is not supported by "
                f"this build (supported version: "
                f"{SNAPSHOT_FORMAT_VERSION})", offset=4)
        if len(data) - _SNAP_HEADER.size < length:
            raise SnapshotCorrupt(
                f"truncated snapshot payload: header promises {length} "
                f"bytes, {len(data) - _SNAP_HEADER.size} present",
                offset=len(data))
        payload = data[_SNAP_HEADER.size:_SNAP_HEADER.size + length]
        actual = zlib.crc32(payload)
        if actual != crc:
            raise SnapshotCorrupt(
                "snapshot payload checksum mismatch (tampered or "
                "corrupted bytes)", offset=_SNAP_HEADER.size,
                expected_crc=crc, actual_crc=actual)
        snap = pickle.loads(payload)
        if not isinstance(snap, SessionSnapshot):
            raise TypeError(f"not a SessionSnapshot: {type(snap)!r}")
        return snap


class AdvisorSession:
    """A persistent, delta-aware `DesignAdvisor`.

    Usage::

        session = AdvisorSession(workload, AdvisorOptions.dtac())
        rec = session.recommend(budget)           # cold: full build
        session.add_statements([...])
        session.remove_statements(["q07"])
        session.reweight({"q01": 3.0})
        rec = session.recommend(budget)           # cheap: delta work only
    """

    def __init__(self, workload: Workload,
                 options: Optional[AdvisorOptions] = None,
                 samples: Optional[SampleManager] = None,
                 sampled_cache: Optional[Dict[Tuple[NodeKey, float],
                                              SizeEstimate]] = None,
                 faults: Optional[FaultInjector] = None):
        workload.by_name()                  # validates name uniqueness
        self.schema = workload.schema
        self.workload = Workload(schema=workload.schema,
                                 statements=list(workload.statements))
        self.opt = options or AdvisorOptions()
        # seeded fault injector (faults.FaultInjector) or None; sites
        # "apply_delta" / "estimation" / "costing" fire HERE (each before
        # any state mutation, so a faulted call is cleanly retryable and
        # the retry is bit-identical), "planner_replay" inside the
        # threaded PlannerEngine
        self.faults = faults
        # SampleManager draws are per-(table, fraction) seed-derived and
        # order-independent, so an outer compressed session can hand its
        # manager to successive inner sessions without changing estimates
        self.samples = (samples if samples is not None
                        else SampleManager(self.schema.tables,
                                           seed=self.opt.sample_seed))
        # `sampled_cache` lets MANY sessions share one (NodeKey, f) ->
        # SizeEstimate dict.  Estimates are pure functions of (schema
        # content, sample_seed, NodeKey, f) — see samplecf.schema_
        # fingerprint — so sharing is bit-exact between sessions whose
        # fingerprints match (the fleet service groups tenants by it);
        # sharing across MISMATCHED fingerprints silently corrupts
        # estimates, so callers own that grouping.
        self._compressed_mode = self.opt.compression_budget is not None
        # monotone workload version: bumped by every applied delta; keys
        # the peek_estimation_plan() memo below
        self.workload_version = 0
        self._peeked = None
        # peeked estimation result + prefetched candidate costs, both
        # keyed by workload_version (the fleet's COST-phase prefetch)
        self._peeked_est = None
        self._cost_results = None
        if self._compressed_mode:
            # outer mode: keep only O(delta) cluster membership here and
            # delegate the heavy pipeline to an inner session over the
            # derived representative workload (rebuilt on structural
            # change, reweighted in place otherwise)
            self._cluster = ClusterIndex.from_workload(self.workload)
            self._inner: Optional["AdvisorSession"] = None
            self._inner_comp: Optional[CompressedWorkload] = None
            self._pending: List[WorkloadDelta] = []
            self._est_cache: Dict[Tuple[NodeKey, float], SizeEstimate] = (
                self._new_sampled_cache(sampled_cache))
            self._retired: Set[str] = set()
            self.rounds = 0
            self.compression_rebuilds = 0
            self.compression_reweights = 0
            self.compression_bypasses = 0
            return
        self.sizes = SizeProvider(self.schema)
        self.optimizer = WhatIfOptimizer(self.workload, self.sizes)
        self.planner = EstimationPlanner(
            self.schema.tables, backend=self.opt.planner_backend,
            use_engine=self.opt.use_batched_planner,
            max_nodes=self.opt.max_planner_nodes,
            max_replay=self.opt.max_replay_entries, faults=faults)
        self.engine: Optional[CostEngine] = (
            CostEngine(self.workload, self.sizes,
                       backend=self.opt.engine_backend)
            if self.opt.use_engine else None)
        self.est_engine: Optional[EstimationEngine] = (
            EstimationEngine(self.schema.tables, self.samples,
                             backend=self.opt.estimation_backend)
            if self.opt.use_batched_estimation else None)
        # incremental caches
        self._queries: Dict[str, _QueryEntry] = {}
        self._selections: Dict[str, _Selection] = {}
        self._sampled_est: Dict[Tuple[NodeKey, float], SizeEstimate] = (
            self._new_sampled_cache(sampled_cache))
        self._registered: Dict[NodeKey, float] = {}
        # raw candidate key -> [(interned NodeKey, compressed variant)]:
        # reusing the SAME NodeKey objects across rounds turns the
        # planner's per-round dict lookups and group-membership compares
        # into identity fast-paths (their hashes are cached on first use)
        self._target_cache: Dict[Tuple,
                                 List[Tuple[NodeKey, IndexDef]]] = {}
        self._retired: Set[str] = set()
        # counters (exposed via .stats; asserted in tests)
        self.rounds = 0
        self.samplecf_cache_hits = 0
        self.samplecf_cache_misses = 0
        self.selection_hits = 0
        self.selection_misses = 0
        self.cost_prefetch_consumed = 0

    def _new_sampled_cache(self, sampled_cache):
        """The session's (NodeKey, f) SampleCF cache: the caller's shared
        mapping when given (the fleet's share-group cache — possibly
        already a bounded `EstimateCache`), else a bounded LRU when
        `samplecf_cache_entries` asks for one, else a plain dict."""
        if sampled_cache is not None:
            return sampled_cache
        if self.opt.samplecf_cache_entries is not None:
            return EstimateCache(self.opt.samplecf_cache_entries)
        return {}

    # ------------------------------------------------------------------
    # Checkpoint / restore
    # ------------------------------------------------------------------
    def snapshot(self, include_estimates: bool = True) -> SessionSnapshot:
        """Checkpoint the session (cheap: copies the statement list, the
        retired-name set and the warm estimate cache; engines are NOT
        serialized — they are pure in the workload and rebuilt lazily by
        `restore`).  Pass `include_estimates=False` when the estimate
        cache outlives the session anyway (the fleet's share-group cache)
        — estimates are pure in (NodeKey, f), so a cold cache changes
        nothing but recomputation time."""
        est = self._est_cache if self._compressed_mode else self._sampled_est
        return SessionSnapshot(
            workload=Workload(schema=self.schema,
                              statements=list(self.workload.statements)),
            options=self.opt,
            workload_version=self.workload_version,
            retired=frozenset(self._retired),
            estimates=dict(est.items()) if include_estimates else {})

    @classmethod
    def restore(cls, snap: SessionSnapshot,
                samples: Optional[SampleManager] = None,
                sampled_cache: Optional[Dict[Tuple[NodeKey, float],
                                             SizeEstimate]] = None,
                faults: Optional[FaultInjector] = None) -> "AdvisorSession":
        """Rebuild a session from a checkpoint.

        The restored session's next `recommend` is exactly `==` a fresh
        `DesignAdvisor` on the snapshot workload: the constructor
        rebuilds every engine from the workload (including the cluster
        index via `ClusterIndex.from_workload`, which PR 5 pinned as
        `==` the incrementally-maintained one), and the transplanted
        estimates are pure in (NodeKey, f), so warming the cache cannot
        change any value — only skip recomputation.  `samples` /
        `sampled_cache` re-attach fleet share-group state; the snapshot
        estimates are merged into the shared cache, never replacing it.
        """
        sess = cls(snap.workload, snap.options, samples=samples,
                   sampled_cache=sampled_cache, faults=faults)
        cache = (sess._est_cache if sess._compressed_mode
                 else sess._sampled_est)
        for k, v in snap.estimates.items():
            if k not in cache:
                cache[k] = v
        sess.workload_version = snap.workload_version
        sess._retired = set(snap.retired)
        return sess

    # ------------------------------------------------------------------
    # Delta API
    # ------------------------------------------------------------------
    def apply(self, delta: WorkloadDelta) -> "AdvisorSession":
        """Apply one mutation batch to the session's workload and every
        long-lived engine.  Statement names are stable ids: a removed
        name is retired for the session's lifetime (re-adding it could
        silently alias cached candidates of the old statement)."""
        if self.faults is not None:
            # before ANY validation or mutation: a faulted apply leaves
            # the session untouched, so the caller can simply retry it
            self.faults.check("apply_delta")
        for s in delta.added:
            if s.name in self._retired:
                raise ValueError(
                    f"statement name {s.name!r} was removed earlier in "
                    "this session; names are stable ids and cannot be "
                    "reused")
        # apply_delta validates EVERYTHING (names, reweights, removals,
        # added statements' tables) before any engine is touched, so a
        # bad delta raises here and leaves the session unchanged
        new_wl = self.workload.apply_delta(delta)
        self.workload_version += 1
        self._peeked = None
        self._peeked_est = None
        self._cost_results = None
        if self._compressed_mode:
            # O(delta) cluster-membership maintenance; the inner session
            # catches up lazily at the next recommend()
            self._cluster.apply_delta(delta)
            for name in delta.removed:
                self._retired.add(name)
            self.workload = new_wl
            self._pending.append(delta)
            return self
        if self.engine is not None:
            self.engine.apply_delta(delta)
            self.engine.workload = new_wl
        for name in delta.removed:
            self._retired.add(name)
            self._queries.pop(name, None)
            self._selections.pop(name, None)
        self.workload = new_wl
        self.optimizer.workload = new_wl
        return self

    def add_statements(self, statements: Iterable[Statement]
                       ) -> "AdvisorSession":
        return self.apply(WorkloadDelta(added=tuple(statements)))

    def remove_statements(self, names: Iterable[str]) -> "AdvisorSession":
        return self.apply(WorkloadDelta(removed=tuple(names)))

    def reweight(self, weights: Union[Mapping[str, float],
                                      Iterable[Tuple[str, float]]]
                 ) -> "AdvisorSession":
        items = (tuple(weights.items()) if isinstance(weights, Mapping)
                 else tuple(weights))
        return self.apply(WorkloadDelta(reweighted=items))

    # ------------------------------------------------------------------
    # Pipeline stages (each mirrors the DesignAdvisor stage it caches)
    # ------------------------------------------------------------------
    def _query_entry(self, q: Query) -> _QueryEntry:
        e = self._queries.get(q.name)
        if e is None:
            raw = cand.syntactically_relevant(
                q, self.schema.tables[q.table],
                include_clustered=self.opt.include_clustered)
            exp = (cand.expand_with_compression(raw, self.opt.methods)
                   if self.opt.consider_compression else raw)
            e = self._queries[q.name] = _QueryEntry(
                raw, exp, frozenset(i.key for i in exp))
        return e

    def _candidate_universe(self) -> Tuple[Dict[str, List[IndexDef]],
                                           List[IndexDef], List[IndexDef]]:
        """`DesignAdvisor._candidate_universe` over cached per-query
        lists; only the (order-sensitive, cheap) dedup + merge pass
        re-runs per round."""
        per_query_raw: Dict[str, List[IndexDef]] = {}
        per_query_exp: Dict[str, List[IndexDef]] = {}
        seen: Dict[Tuple, IndexDef] = {}
        for q in self.workload.queries():
            e = self._query_entry(q)
            per_query_raw[q.name] = e.raw
            per_query_exp[q.name] = e.exp
            for idx in e.raw:
                seen.setdefault(idx.key, idx)
        merged = cand.merged_candidates(per_query_raw)
        for idx in merged:
            seen.setdefault(idx.key, idx)
        raw = sorted(seen.values(),
                     key=lambda i: (i.table, i.cols, i.clustered))
        if not self.opt.consider_compression:
            return per_query_exp, merged, raw
        merged_exp = cand.expand_with_compression(merged, self.opt.methods)
        return per_query_exp, merged_exp, raw

    def _estimation_targets(self, raw_union: List[IndexDef]
                            ) -> Dict[NodeKey, List[IndexDef]]:
        """`DesignAdvisor.estimation_targets` over the (unexpanded) raw
        candidate union: the target of each raw candidate's compressed
        variant is pure in (candidate, method), so the (NodeKey, variant)
        pairs are cached — and the NodeKey objects interned — by raw
        candidate key.  Iterating raw candidates in union order yields
        exactly the target order the one-shot advisor derives from the
        expanded candidate list."""
        out: Dict[NodeKey, List[IndexDef]] = {}
        if not self.opt.consider_compression:
            return out
        tc = self._target_cache
        for idx in raw_union:
            ent = tc.get(idx.key)
            if ent is None:
                if idx.predicate is not None:
                    ent = []
                else:
                    ent = [(NodeKey(idx.table, idx.cols, m),
                            idx.with_compression(m))
                           for m in self.opt.methods]
                tc[idx.key] = ent
            for k, v in ent:
                out.setdefault(k, []).append(v)
        return out

    def _plan_targets(self, raw_union: List[IndexDef]
                      ) -> Tuple[Dict[NodeKey, List[IndexDef]],
                                 Optional[Plan]]:
        """Derive this round's (NodeKey -> variants, estimation Plan)
        pair — the pure planning half of `_estimate_sizes`."""
        tkey_to_defs = self._estimation_targets(raw_union)
        targets = list(tkey_to_defs)
        if not targets:
            return tkey_to_defs, None
        if self.opt.use_deduction:
            plan = self.planner.plan(targets, self.opt.e, self.opt.q)
        else:
            plan = self.planner.plan_all_sampled(targets, self.opt.e,
                                                 self.opt.q)
        return tkey_to_defs, plan

    def peek_estimation_plan(self) -> Optional[Plan]:
        """Plan this round's size estimation WITHOUT executing it.

        Memoized by `workload_version`: the (candidate universe, target
        map, Plan) triple computed here is reused verbatim by the next
        `recommend()` on the same version, so peeking costs nothing
        extra.  The fleet service peeks every admitted tenant's plan to
        union their missing (NodeKey, f) SampleCF targets into one
        cross-tenant batched prefetch before the recommends run.
        Returns None in compressed (outer) mode — the representative
        workload is only derived inside recommend — and when the round
        has no compressed candidates to estimate."""
        if self._compressed_mode:
            return None
        if self._peeked is not None and \
                self._peeked[0] == self.workload_version:
            return self._peeked[3]
        universe = self._candidate_universe()
        tkey_to_defs, plan = self._plan_targets(universe[2])
        self._peeked = (self.workload_version, universe, tkey_to_defs, plan)
        return plan

    def peek_cost_jobs(self) -> List[Tuple[Query, List[IndexDef]]]:
        """Expose this round's stale per-query costing jobs WITHOUT
        scoring them — the fleet service's COST-phase prefetch hook.

        Runs the estimation stage once (memoized by `workload_version`
        and consumed verbatim by the next `recommend()`; size
        registration is idempotent, so a later retry re-registers the
        same values) and syncs the engine, so the returned (query,
        expanded-candidates) jobs can be gathered from live engine
        columns by `CostEngine.cost_job_arrays`.  The selection-staleness
        test is the same one `recommend()` applies, against the same
        `changed` set.  Returns [] in compressed (outer) mode and when
        the session has no batched engine."""
        if self._compressed_mode or self.engine is None:
            return []
        self.peek_estimation_plan()
        ver, universe, tkey_to_defs, plan = self._peeked
        if self._peeked_est is None or self._peeked_est[0] != ver:
            est = self._estimate_sizes(universe[2], (tkey_to_defs, plan))
            self._peeked_est = (ver, est)
        changed = self._peeked_est[1][4]
        self.engine.sync_sizes()
        jobs: List[Tuple[Query, List[IndexDef]]] = []
        for q in self.workload.queries():
            entry = self._queries[q.name]
            sel = self._selections.get(q.name)
            if sel is None or (changed
                               and not changed.isdisjoint(entry.key_set)):
                jobs.append((q, entry.exp))
        return jobs

    def accept_cost_results(self, version: int,
                            results: Mapping[str, "object"]) -> int:
        """Install prefetched candidate-cost arrays, keyed by query name
        and aligned with the `peek_cost_jobs()` candidate lists, for
        workload `version`.  A stale version is dropped (returns 0).
        The caller owns the exact-parity contract: each array must hold
        exactly what `engine.candidate_query_costs` would return for
        that job, so consuming it cannot perturb the recommendation."""
        if version != self.workload_version:
            return 0
        self._cost_results = (version, dict(results))
        return len(results)

    def _estimate_sizes(self, raw_union: List[IndexDef],
                        planned: Optional[Tuple[Dict[NodeKey,
                                                     List[IndexDef]],
                                                Optional[Plan]]] = None
                        ) -> Tuple[float, Optional[Plan], int, int,
                                   Set[Tuple]]:
        """`DesignAdvisor.estimate_sizes` with the persistent planner and
        the (NodeKey, f) SampleCF cache.  Returns the usual aggregates
        plus the set of index keys whose registered size CHANGED this
        round — the selection stage's invalidation set."""
        tkey_to_defs, plan = (planned if planned is not None
                              else self._plan_targets(raw_union))
        changed: Set[Tuple] = set()
        if plan is None:
            return 0.0, None, 0, 0, changed
        if self.faults is not None:
            # before execute_cached touches the cache: a faulted
            # estimation leaves all caches consistent for the retry
            self.faults.check("estimation")
        # count misses by membership, not by cache growth: a bounded
        # EstimateCache may evict while inserting, keeping len() flat
        misses = sum(1 for k, n in plan.nodes.items()
                     if n.state is State.SAMPLED
                     and (k, plan.f) not in self._sampled_est)
        ests = self.planner.execute_cached(
            plan, self.samples, self._sampled_est, engine=self.est_engine,
            scalar=not self.opt.use_batched_estimation)
        self.samplecf_cache_misses += misses
        self.samplecf_cache_hits += plan.n_sampled() - misses
        for k, est in ests.items():
            defs = tkey_to_defs.get(k)
            if not defs:
                continue
            if self._registered.get(k) != est.est_bytes:
                self._registered[k] = est.est_bytes
                changed.update(d.key for d in defs)
            for d in defs:
                self.sizes.register(d, est.est_bytes)
        return (plan.total_cost, plan, plan.n_sampled(), plan.n_deduced(),
                changed)

    # ------------------------------------------------------------------
    def _inner_options(self) -> AdvisorOptions:
        return dataclasses.replace(self.opt, compression_budget=None)

    def _make_inner(self, workload: Workload) -> "AdvisorSession":
        """A fresh inner session sharing the outer SampleManager and the
        (NodeKey, f)-keyed sampled-estimate cache — both order-independent,
        so transplanting them across rebuilds cannot change any estimate
        (the PR-4 property the incremental engines already rely on)."""
        inner = AdvisorSession(workload, self._inner_options(),
                               samples=self.samples, faults=self.faults)
        self._est_cache.update(inner._sampled_est)
        inner._sampled_est = self._est_cache
        self.compression_rebuilds += 1
        return inner

    def _recommend_compressed(self, budget_bytes: float) -> Recommendation:
        """Outer-mode recommend: derive the budgeted representative
        workload from the incrementally-maintained `ClusterIndex`, then
        reuse, reweight, or rebuild the inner session.

        Representatives are signature-pure (content-addressed names,
        canonical predicates), so membership churn that keeps the cluster
        set intact only changes representative WEIGHTS — the reweight
        fast path, which preserves every inner engine.  Structural change
        (clusters appearing/disappearing) rebuilds the inner session: the
        compressed statement order is signature-sorted, and an in-place
        append could not reproduce it (float summation order is part of
        the parity contract)."""
        t0 = time.perf_counter()
        self.rounds += 1
        comp = self._cluster.derive(self.opt.compression_budget)
        if comp is None:
            # exact-parity bypass: inner session over the FULL workload
            if self._inner is None or self._inner_comp is not None:
                self._inner = self._make_inner(self.workload)
            else:
                # internal catch-up, not a user-facing apply: suppress
                # the "apply_delta" fault site so a mid-loop fault can
                # never leave the pending list half-applied (the outer
                # apply() already took its fault check for each delta)
                inner_faults, self._inner.faults = self._inner.faults, None
                try:
                    for d in self._pending:
                        self._inner.apply(d)
                finally:
                    self._inner.faults = inner_faults
            self._inner_comp = None
            self._pending.clear()
            self.compression_bypasses += 1
            rec = self._inner.recommend(budget_bytes)
            return dataclasses.replace(
                rec, wall_seconds=time.perf_counter() - t0)
        cur = (self._inner.workload.statements
               if self._inner is not None and self._inner_comp is not None
               else None)
        new_stmts = comp.workload.statements
        if cur is not None and [s.name for s in cur] == \
                [s.name for s in new_stmts]:
            diffs = {s.name: n.weight for s, n in zip(cur, new_stmts)
                     if s.weight != n.weight}
            if diffs:
                self._inner.reweight(diffs)
            self.compression_reweights += 1
        else:
            self._inner = self._make_inner(comp.workload)
        self._inner_comp = comp
        self._pending.clear()
        rec = self._inner.recommend(budget_bytes)
        eps = comp.error_bound(rec.config, self._inner.sizes)
        return dataclasses.replace(
            rec, n_statements_full=comp.n_full,
            n_representatives=comp.n_representatives,
            compression_error_bound=eps,
            compression_error_rel=eps / max(abs(rec.cost), 1e-12),
            wall_seconds=time.perf_counter() - t0)

    def recommend(self, budget_bytes: float) -> Recommendation:
        """Re-advise the current workload.  Identical to
        `DesignAdvisor(current_workload, options).recommend(budget)` —
        the correctness contract — at delta-proportional cost."""
        if self._compressed_mode:
            return self._recommend_compressed(budget_bytes)
        t0 = time.perf_counter()
        self.rounds += 1
        base = base_configuration(self.schema)
        peeked = self._peeked
        if peeked is not None and peeked[0] == self.workload_version:
            # reuse the universe + plan peek_estimation_plan() derived
            # for this exact workload version (same inputs, same code
            # path — bit-exact with the un-peeked round)
            per_query_exp, merged_all, raw_union = peeked[1]
            planned = (peeked[2], peeked[3])
        else:
            per_query_exp, merged_all, raw_union = self._candidate_universe()
            planned = None
        self._peeked = None
        est_state, self._peeked_est = self._peeked_est, None
        if est_state is not None and est_state[0] == self.workload_version:
            # estimation already ran inside peek_cost_jobs() for this
            # exact workload version: sizes are registered and the
            # engine is synced (both idempotent), so reuse its result
            est_cost, plan, n_s, n_d, changed = est_state[1]
        else:
            est_cost, plan, n_s, n_d, changed = self._estimate_sizes(
                raw_union, planned)

        if self.faults is not None:
            # size registration above is idempotent, so a fault here is
            # retryable and the retry recommends bit-identically
            self.faults.check("costing")
        engine = self.engine
        if engine is not None:
            engine.sync_sizes()
        elif changed:
            # the scalar optimizer memoizes statement costs by (statement,
            # config); re-registered sizes invalidate those entries
            self.optimizer._cache.clear()
        base_cost = (engine.config_cost(base) if engine is not None
                     else self.optimizer.workload_cost(base))

        pre, self._cost_results = self._cost_results, None
        pre_costs = (pre[1] if pre is not None
                     and pre[0] == self.workload_version else {})
        pool: Dict[Tuple, IndexDef] = {}
        n_cand = 0
        for q in self.workload.queries():
            entry = self._queries[q.name]
            sel = self._selections.get(q.name)
            if sel is None or (changed
                               and not changed.isdisjoint(entry.key_set)):
                pre_q = pre_costs.get(q.name)
                if pre_q is not None:
                    self.cost_prefetch_consumed += 1
                costed = cand.cost_candidates(q, entry.exp, base,
                                              self.optimizer, self.sizes,
                                              engine=engine,
                                              precomputed=pre_q)
                sel = _Selection(select_candidates(costed, self.opt),
                                 len(costed))
                self._selections[q.name] = sel
                self.selection_misses += 1
            else:
                self.selection_hits += 1
            n_cand += sel.n_costed
            for c in sel.selected:
                pool.setdefault(c.index.key, c.index)
        pool_with_merged(pool, merged_all)

        res = enumerate_pool(self.optimizer, self.sizes, self.opt, pool,
                             base, budget_bytes, engine)
        n_full = len(self.workload.statements)
        return Recommendation(
            config=res.config, base=base, base_cost=base_cost, cost=res.cost,
            used_bytes=res.used_bytes, budget_bytes=budget_bytes,
            estimation_cost_pages=est_cost, estimation_plan=plan,
            n_sampled=n_s, n_deduced=n_d, candidate_count=n_cand,
            pool_size=len(pool), wall_seconds=time.perf_counter() - t0,
            steps=res.steps, n_statements_full=n_full,
            n_representatives=n_full)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, int]:
        """Incrementality counters (graph/record/replay/selection/cache
        hits) — the session's evidence that re-advising cost tracked the
        delta, asserted in tests and reported by the benchmark."""
        if self._compressed_mode:
            out = dict(self._inner.stats) if self._inner is not None else {}
            out.update(
                rounds=self.rounds,
                compression_rebuilds=self.compression_rebuilds,
                compression_reweights=self.compression_reweights,
                compression_bypasses=self.compression_bypasses)
            return out
        out = {
            "rounds": self.rounds,
            "selection_hits": self.selection_hits,
            "selection_misses": self.selection_misses,
            "cost_prefetch_consumed": self.cost_prefetch_consumed,
            "samplecf_cache_hits": self.samplecf_cache_hits,
            "samplecf_cache_misses": self.samplecf_cache_misses,
            "sampled_estimates_cached": len(self._sampled_est),
        }
        if isinstance(self._sampled_est, EstimateCache):
            out.update(samplecf_cache_evictions=self._sampled_est.evictions,
                       samplecf_cache_maxsize=self._sampled_est.maxsize)
        if self.engine is not None:
            out.update(engine_rows_added=self.engine.rows_added,
                       engine_rows_removed=self.engine.rows_removed,
                       engine_cols_refreshed=self.engine.cols_refreshed)
        peng = self.planner._engine
        if peng is not None:
            out.update(graph_builds=peng.graph_builds,
                       rec_builds=peng.rec_builds,
                       rec_hits=peng.rec_hits,
                       replay_hits=peng.replay_hits,
                       replay_verified=peng.replay_verified,
                       replay_misses=peng.replay_misses,
                       universe_nodes=len(peng._node_keys),
                       universe_peak_nodes=peng.peak_nodes,
                       universe_evictions=peng.universe_evictions,
                       replay_entries=sum(len(d) for d in
                                          peng._replay.values()),
                       replay_evictions=peng.replay_evictions,
                       replay_faults=peng.replay_faults)
        return out

"""Workload model + TPC-H-like synthetic generator (paper §7 / App. D.2).

Statements are single-table analytic SELECTs (range/equality filters +
aggregated columns) and bulk-load INSERTs, with weights that skew the mix
SELECT-intensive or INSERT-intensive exactly as in the paper's experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .relation import ColumnDef, Predicate, Table
from .synopses import ForeignKey, Schema


@dataclasses.dataclass(frozen=True)
class Query:
    name: str
    table: str
    filters: Tuple[Predicate, ...]
    cols_used: Tuple[str, ...]  # projected / aggregated columns
    weight: float = 1.0

    def all_cols(self) -> Tuple[str, ...]:
        seen = dict.fromkeys([p.col for p in self.filters])
        seen.update(dict.fromkeys(self.cols_used))
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class BulkInsert:
    name: str
    table: str
    nrows: int
    weight: float = 1.0


Statement = Union[Query, BulkInsert]


@dataclasses.dataclass(frozen=True)
class WorkloadDelta:
    """One batch of workload mutations (the online-session delta unit).

    Statement *names* are the stable ids: `added` appends new statements
    (their names must be fresh), `removed` drops statements by name, and
    `reweighted` replaces the weight of existing statements in place.
    Statement order is preserved: survivors keep their relative order and
    additions go to the end — exactly how `Workload.apply_delta` builds
    the resulting workload a fresh advisor would be given.
    """
    added: Tuple[Statement, ...] = ()
    removed: Tuple[str, ...] = ()
    reweighted: Tuple[Tuple[str, float], ...] = ()

    def __bool__(self) -> bool:
        return bool(self.added or self.removed or self.reweighted)


@dataclasses.dataclass
class Workload:
    schema: Schema
    statements: List[Statement]

    def queries(self) -> List[Query]:
        return [s for s in self.statements if isinstance(s, Query)]

    def updates(self) -> List[BulkInsert]:
        return [s for s in self.statements if isinstance(s, BulkInsert)]

    # -- delta API (stable statement ids = names) -----------------------
    def by_name(self) -> Dict[str, Statement]:
        out: Dict[str, Statement] = {}
        for s in self.statements:
            if s.name in out:
                raise ValueError(f"duplicate statement name {s.name!r}")
            out[s.name] = s
        return out

    def apply_delta(self, delta: WorkloadDelta) -> "Workload":
        """The resulting workload after `delta` (functional; `self` is
        untouched).  Reweights apply in place, removals drop, additions
        append — so a fresh advisor on the result sees statements in the
        same order an `AdvisorSession` maintains them."""
        have = self.by_name()
        for name in delta.removed:
            if name not in have:
                raise KeyError(f"cannot remove unknown statement {name!r}")
        removed = set(delta.removed)
        reweight: Dict[str, float] = {}
        for name, w in delta.reweighted:
            if name not in have:
                raise KeyError(f"cannot reweight unknown statement {name!r}")
            if name in removed:
                raise ValueError(f"statement {name!r} both removed and "
                                 "reweighted in one delta")
            reweight[name] = float(w)
        seen_add = set()
        for s in delta.added:
            if s.name in have or s.name in seen_add:
                raise ValueError(f"added statement name {s.name!r} is not "
                                 "fresh")
            seen_add.add(s.name)
            if s.table not in self.schema.tables:
                raise KeyError(f"added statement {s.name!r} references "
                               f"unknown table {s.table!r}")
        stmts: List[Statement] = []
        for s in self.statements:
            if s.name in removed:
                continue
            w = reweight.get(s.name)
            stmts.append(s if w is None
                         else dataclasses.replace(s, weight=w))
        stmts.extend(delta.added)
        return Workload(schema=self.schema, statements=stmts)


# ---------------------------------------------------------------------------
# Synthetic TPC-H-like data
# ---------------------------------------------------------------------------

def _zipf_choice(rng: np.random.Generator, n_distinct: int, size: int,
                 z: float) -> np.ndarray:
    if z <= 0:
        return rng.integers(0, n_distinct, size=size)
    ranks = np.arange(1, n_distinct + 1, dtype=np.float64)
    p = ranks ** (-z)
    p /= p.sum()
    return rng.choice(n_distinct, size=size, p=p)


def make_tpch_like(scale: float = 1.0, z: float = 0.0, seed: int = 0) -> Schema:
    """A miniature TPC-H-shaped schema; `scale`=1 => 60k lineitem rows."""
    rng = np.random.default_rng(seed)
    n_li = max(int(60_000 * scale), 1000)
    n_ord = max(n_li // 4, 100)
    n_part = max(n_li // 30, 50)
    n_supp = max(n_li // 150, 10)
    n_cust = max(n_ord // 10, 20)

    date_lo, n_dates = 728_000, 2_400  # ~6.5 years of day numbers

    orders = Table("orders", [
        ColumnDef("o_orderkey", 4), ColumnDef("o_custkey", 4),
        ColumnDef("o_orderstatus", 1), ColumnDef("o_totalprice", 4),
        ColumnDef("o_orderdate", 4), ColumnDef("o_orderpriority", 1),
        ColumnDef("o_clerk", 2),
    ], {
        "o_orderkey": np.arange(n_ord),
        "o_custkey": _zipf_choice(rng, n_cust, n_ord, z),
        "o_orderstatus": _zipf_choice(rng, 3, n_ord, z),
        "o_totalprice": rng.integers(1_000, 500_000, n_ord),
        "o_orderdate": date_lo + _zipf_choice(rng, n_dates, n_ord, z),
        "o_orderpriority": _zipf_choice(rng, 5, n_ord, z),
        "o_clerk": _zipf_choice(rng, 1000, n_ord, z),
    })

    li_orderkey = rng.integers(0, n_ord, n_li)
    li_shipdate = (orders.values["o_orderdate"][li_orderkey]
                   + rng.integers(1, 120, n_li))
    lineitem = Table("lineitem", [
        ColumnDef("l_orderkey", 4), ColumnDef("l_partkey", 4),
        ColumnDef("l_suppkey", 4), ColumnDef("l_quantity", 1),
        ColumnDef("l_extendedprice", 4), ColumnDef("l_discount", 1),
        ColumnDef("l_tax", 1), ColumnDef("l_returnflag", 1),
        ColumnDef("l_linestatus", 1), ColumnDef("l_shipdate", 4),
        ColumnDef("l_shipmode", 1),
    ], {
        "l_orderkey": li_orderkey,
        "l_partkey": _zipf_choice(rng, n_part, n_li, z),
        "l_suppkey": _zipf_choice(rng, n_supp, n_li, z),
        "l_quantity": 1 + _zipf_choice(rng, 50, n_li, z),
        "l_extendedprice": rng.integers(100, 100_000, n_li),
        "l_discount": _zipf_choice(rng, 11, n_li, z),
        "l_tax": _zipf_choice(rng, 9, n_li, z),
        "l_returnflag": _zipf_choice(rng, 3, n_li, z),
        "l_linestatus": _zipf_choice(rng, 2, n_li, z),
        "l_shipdate": li_shipdate,
        "l_shipmode": _zipf_choice(rng, 7, n_li, z),
    })

    part = Table("part", [
        ColumnDef("p_partkey", 4), ColumnDef("p_brand", 1),
        ColumnDef("p_type", 1), ColumnDef("p_size", 1),
        ColumnDef("p_container", 1), ColumnDef("p_retailprice", 4),
    ], {
        "p_partkey": np.arange(n_part),
        "p_brand": _zipf_choice(rng, 25, n_part, z),
        "p_type": _zipf_choice(rng, 150, n_part, z) % 256,
        "p_size": 1 + _zipf_choice(rng, 50, n_part, z),
        "p_container": _zipf_choice(rng, 40, n_part, z),
        "p_retailprice": rng.integers(900, 2_000, n_part),
    })

    supplier = Table("supplier", [
        ColumnDef("s_suppkey", 4), ColumnDef("s_nationkey", 1),
        ColumnDef("s_acctbal", 4),
    ], {
        "s_suppkey": np.arange(n_supp),
        "s_nationkey": _zipf_choice(rng, 25, n_supp, z),
        "s_acctbal": rng.integers(0, 100_000, n_supp),
    })

    customer = Table("customer", [
        ColumnDef("c_custkey", 4), ColumnDef("c_nationkey", 1),
        ColumnDef("c_mktsegment", 1), ColumnDef("c_acctbal", 4),
    ], {
        "c_custkey": np.arange(n_cust),
        "c_nationkey": _zipf_choice(rng, 25, n_cust, z),
        "c_mktsegment": _zipf_choice(rng, 5, n_cust, z),
        "c_acctbal": rng.integers(0, 100_000, n_cust),
    })

    fks = [
        ForeignKey("lineitem", "l_orderkey", "orders", "o_orderkey"),
        ForeignKey("lineitem", "l_partkey", "part", "p_partkey"),
        ForeignKey("lineitem", "l_suppkey", "supplier", "s_suppkey"),
        ForeignKey("orders", "o_custkey", "customer", "c_custkey"),
    ]
    return Schema({t.name: t for t in
                   (lineitem, orders, part, supplier, customer)}, fks)


def make_tpch_workload(schema: Schema, insert_weight: float = 0.1,
                       query_weight: float = 1.0) -> Workload:
    """~20 analytic queries + 2 bulk loads, TPC-H-flavored (App. D.2).

    insert_weight 0.1 => SELECT-intensive; 20 => INSERT-intensive.
    """
    li = schema.tables["lineitem"]
    od = schema.tables["orders"]
    dlo, dhi = li.minmax("l_shipdate")
    olo, ohi = od.minmax("o_orderdate")
    span = dhi - dlo
    ospan = ohi - olo

    def drange(frac_lo: float, frac_hi: float) -> Tuple[int, int]:
        return (int(dlo + span * frac_lo), int(dlo + span * frac_hi))

    P = Predicate
    qs: List[Statement] = []

    def q(name, table, filters, cols):
        qs.append(Query(name, table, tuple(filters), tuple(cols),
                        weight=query_weight))

    # pricing summary (Q1-like): wide scan, small date filter
    a, b = drange(0.0, 0.9)
    q("q01", "lineitem", [P("l_shipdate", a, b)],
      ["l_returnflag", "l_linestatus", "l_quantity", "l_extendedprice",
       "l_discount", "l_tax"])
    # revenue in a year with discount/quantity bands (Q6-like)
    a, b = drange(0.3, 0.45)
    q("q06", "lineitem", [P("l_shipdate", a, b), P("l_discount", 5, 7),
                          P("l_quantity", 1, 24)],
      ["l_extendedprice", "l_discount"])
    # shipping modes (Q12-like)
    a, b = drange(0.5, 0.65)
    q("q12", "lineitem", [P("l_shipdate", a, b), P("l_shipmode", 2, 3)],
      ["l_orderkey", "l_shipmode"])
    # narrow selective seek
    a, b = drange(0.70, 0.72)
    q("q03", "lineitem", [P("l_shipdate", a, b)],
      ["l_orderkey", "l_extendedprice", "l_discount"])
    a, b = drange(0.10, 0.13)
    q("q04", "lineitem", [P("l_shipdate", a, b), P("l_returnflag", 1, 1)],
      ["l_extendedprice", "l_suppkey"])
    q("q05", "lineitem", [P("l_suppkey", 0, max(2, li.minmax("l_suppkey")[1] // 20))],
      ["l_extendedprice", "l_discount", "l_shipdate"])
    q("q07", "lineitem", [P("l_returnflag", 2, 2)],
      ["l_extendedprice", "l_quantity"])
    q("q08", "lineitem", [P("l_shipmode", 5, 6)],
      ["l_extendedprice", "l_shipdate"])
    a, b = drange(0.2, 0.8)
    q("q09", "lineitem", [P("l_shipdate", a, b), P("l_tax", 0, 2)],
      ["l_partkey", "l_extendedprice"])
    q("q10", "lineitem", [P("l_quantity", 40, 50)],
      ["l_extendedprice", "l_discount", "l_partkey"])
    a, b = drange(0.55, 0.60)
    q("q11", "lineitem", [P("l_shipdate", a, b)],
      ["l_suppkey", "l_quantity", "l_extendedprice"])
    q("q14", "lineitem", [P("l_partkey", 0, max(2, li.minmax("l_partkey")[1] // 10))],
      ["l_extendedprice", "l_discount", "l_shipdate"])

    def orange(fl, fh):
        return (int(olo + ospan * fl), int(olo + ospan * fh))

    a, b = orange(0.4, 0.55)
    q("q21", "orders", [P("o_orderdate", a, b)],
      ["o_totalprice", "o_orderpriority"])
    a, b = orange(0.8, 1.0)
    q("q22", "orders", [P("o_orderdate", a, b), P("o_orderstatus", 0, 0)],
      ["o_totalprice", "o_custkey"])
    q("q23", "orders", [P("o_orderpriority", 0, 1)],
      ["o_totalprice", "o_orderdate"])
    a, b = orange(0.1, 0.12)
    q("q24", "orders", [P("o_orderdate", a, b)],
      ["o_custkey", "o_totalprice", "o_clerk"])
    q("q25", "orders", [P("o_custkey", 0, max(2, od.minmax("o_custkey")[1] // 15))],
      ["o_totalprice", "o_orderdate"])
    q("q26", "customer", [P("c_mktsegment", 1, 1)],
      ["c_custkey", "c_acctbal"])
    q("q27", "part", [P("p_brand", 3, 4), P("p_size", 10, 20)],
      ["p_partkey", "p_retailprice"])
    q("q28", "part", [P("p_container", 7, 9)],
      ["p_retailprice", "p_size"])

    # two bulk loads on fact tables (App. D.2)
    qs.append(BulkInsert("load_lineitem", "lineitem",
                         max(li.nrows // 50, 100), weight=insert_weight))
    qs.append(BulkInsert("load_orders", "orders",
                         max(od.nrows // 50, 50), weight=insert_weight))
    return Workload(schema=schema, statements=qs)


def make_scaled_workload_reference(schema: Schema, n_statements: int = 200,
                                   insert_fraction: float = 0.1, seed: int = 0,
                                   insert_weight: float = 0.1) -> Workload:
    """Original scalar generator (one rng call per draw, per statement).

    Kept as the behavioural reference for `make_scaled_workload`: the
    vectorized generator must produce structurally equivalent output (same
    statement-name sequence, same query/insert split, predicates within
    column bounds, same weight ranges) — asserted by the test suite.  Too
    slow beyond a few thousand statements; do not use on hot paths.
    """
    rng = np.random.default_rng(seed)
    tables = list(schema.tables.values())
    # weight table choice by row count: fact tables dominate, like TPC-H
    p = np.array([t.nrows for t in tables], dtype=np.float64)
    p /= p.sum()
    n_inserts = int(round(n_statements * insert_fraction))
    n_queries = n_statements - n_inserts
    stmts: List[Statement] = []
    for k in range(n_queries):
        t = tables[int(rng.choice(len(tables), p=p))]
        cols = [c.name for c in t.columns]
        nf = int(rng.integers(1, min(3, len(cols)) + 1))
        fcols = list(rng.choice(len(cols), size=nf, replace=False))
        filters = []
        for ci in fcols:
            name = cols[int(ci)]
            mn, mx = t.minmax(name)
            if mx <= mn or rng.random() < 0.25:      # equality predicate
                v = int(rng.integers(mn, mx + 1))
                filters.append(Predicate(name, v, v))
            else:                                    # range predicate
                frac = float(rng.uniform(0.01, 0.6))
                lo = int(rng.integers(mn, max(mn, int(mx - (mx - mn) * frac))
                                      + 1))
                hi = min(mx, lo + max(1, int((mx - mn) * frac)))
                filters.append(Predicate(name, lo, hi))
        rest = [c for c in cols if c not in {f.col for f in filters}]
        nu = int(rng.integers(1, min(4, max(1, len(rest))) + 1))
        used = [rest[int(i)] for i in
                rng.choice(len(rest), size=min(nu, len(rest)),
                           replace=False)] if rest else [filters[0].col]
        stmts.append(Query(f"s{k:04d}", t.name, tuple(filters), tuple(used),
                           weight=float(rng.uniform(0.5, 2.0))))
    for k in range(n_inserts):
        t = tables[int(rng.choice(len(tables), p=p))]
        stmts.append(BulkInsert(f"ins{k:03d}", t.name,
                                max(t.nrows // 50, 50),
                                weight=insert_weight))
    return Workload(schema=schema, statements=stmts)


def make_scaled_workload(schema: Schema, n_statements: int = 200,
                         insert_fraction: float = 0.1, seed: int = 0,
                         insert_weight: float = 0.1) -> Workload:
    """Synthetic workload with an arbitrary statement count (advisor-scaling
    experiments, paper §7's 'large workload' regime).

    Random single-table analytic SELECTs — 1-3 range/equality filters over
    random columns, 1-4 projected columns, mixed selectivities — plus an
    `insert_fraction` share of bulk loads.  Deterministic in `seed`.

    All random draws are batched into a fixed sequence of array-shaped rng
    calls (one per draw *kind*, not per statement), so generating 100k
    statements costs milliseconds of rng time instead of seconds.  The
    per-statement loop below only assembles Query objects from precomputed
    arrays.  Distributionally matches `make_scaled_workload_reference`
    (same draw ranges and branch probabilities) but the draws land in a
    different stream order, so individual statements differ for the same
    seed.
    """
    rng = np.random.default_rng(seed)
    tables = list(schema.tables.values())
    # weight table choice by row count: fact tables dominate, like TPC-H
    p = np.array([t.nrows for t in tables], dtype=np.float64)
    p /= p.sum()
    n_inserts = int(round(n_statements * insert_fraction))
    n_queries = n_statements - n_inserts

    ncols = np.array([len(t.columns) for t in tables], dtype=np.int64)
    maxc = int(ncols.max())
    colnames = [[c.name for c in t.columns] for t in tables]
    mn_tab = np.zeros((len(tables), maxc), dtype=np.int64)
    mx_tab = np.zeros((len(tables), maxc), dtype=np.int64)
    for a, t in enumerate(tables):
        for j, c in enumerate(t.columns):
            mn, mx = t.minmax(c.name)
            mn_tab[a, j], mx_tab[a, j] = int(mn), int(mx)

    MAXF = 3
    ti = rng.choice(len(tables), size=n_queries, p=p)
    tc = ncols[ti]
    nf = 1 + np.floor(rng.random(n_queries)
                      * np.minimum(MAXF, tc)).astype(np.int64)
    # filter-column choice without replacement: random sort keys per row,
    # slots beyond the table's column count pushed past every valid slot
    invalid = np.arange(maxc)[None, :] >= tc[:, None]
    fkeys = rng.random((n_queries, maxc))
    fkeys[invalid] = np.inf
    forder = np.argsort(fkeys, axis=1, kind="stable")
    eq_u = rng.random((n_queries, MAXF))
    val_u = rng.random((n_queries, MAXF))
    frac = 0.01 + 0.59 * rng.random((n_queries, MAXF))
    lo_u = rng.random((n_queries, MAXF))
    # projected-column choice: fresh keys with the chosen filter slots
    # (and invalid slots) masked out, so projection never repeats a filter
    pkeys = rng.random((n_queries, maxc))
    pkeys[invalid] = np.inf
    if n_queries:
        rows = np.repeat(np.arange(n_queries), MAXF)
        slot = np.tile(np.arange(MAXF), n_queries)
        taken = slot < nf[rows]
        pkeys[rows[taken], forder[:, :MAXF].ravel()[taken]] = np.inf
    porder = np.argsort(pkeys, axis=1, kind="stable")
    nrest = tc - nf
    nu = 1 + np.floor(rng.random(n_queries)
                      * np.minimum(4, np.maximum(1, nrest))).astype(np.int64)
    nu = np.minimum(nu, nrest)
    weights = 0.5 + 1.5 * rng.random(n_queries)
    ti_ins = rng.choice(len(tables), size=n_inserts, p=p)

    # convert once to plain Python containers — per-element numpy scalar
    # boxing inside the assembly loop dominates otherwise
    ti_l, nf_l, nu_l = ti.tolist(), nf.tolist(), nu.tolist()
    forder_l, porder_l = forder[:, :maxc].tolist(), porder.tolist()
    eq_l, val_l = eq_u.tolist(), val_u.tolist()
    frac_l, lo_l, w_l = frac.tolist(), lo_u.tolist(), weights.tolist()
    mn_l, mx_l = mn_tab.tolist(), mx_tab.tolist()
    tnames = [t.name for t in tables]

    stmts: List[Statement] = []
    for k in range(n_queries):
        a = ti_l[k]
        names = colnames[a]
        mns, mxs = mn_l[a], mx_l[a]
        fo, eqr, valr, fracr, lor = (forder_l[k], eq_l[k], val_l[k],
                                     frac_l[k], lo_l[k])
        filters = []
        for j in range(nf_l[k]):
            ci = fo[j]
            mn, mx = mns[ci], mxs[ci]
            if mx <= mn or eqr[j] < 0.25:            # equality predicate
                v = mn + int(valr[j] * (mx - mn + 1))
                filters.append(Predicate(names[ci], v, v))
            else:                                    # range predicate
                f = fracr[j]
                top = max(mn, int(mx - (mx - mn) * f))
                lo = mn + int(lor[j] * (top - mn + 1))
                hi = min(mx, lo + max(1, int((mx - mn) * f)))
                filters.append(Predicate(names[ci], lo, hi))
        nuk = nu_l[k]
        if nuk > 0:
            po = porder_l[k]
            used = tuple(names[po[j]] for j in range(nuk))
        else:                                        # every column filtered
            used = (filters[0].col,)
        stmts.append(Query(f"s{k:04d}", tnames[a], tuple(filters),
                           used, weight=w_l[k]))
    ins_l = ti_ins.tolist()
    for k in range(n_inserts):
        t = tables[ins_l[k]]
        stmts.append(BulkInsert(f"ins{k:03d}", t.name,
                                max(t.nrows // 50, 50),
                                weight=insert_weight))
    return Workload(schema=schema, statements=stmts)

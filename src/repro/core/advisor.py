"""DTAc — the compression-aware physical design advisor (paper Figure 1).

Pipeline: per-query candidate generation -> compressed-size estimation
(§4-§5 framework: amortized SampleCF + deductions chosen by the greedy graph
search) -> candidate selection (top-k or Skyline, §6.1) -> enumeration
(pure/density/backtracking greedy, §6.2) -> recommendation.

`AdvisorOptions` reproduces every tool variant the paper evaluates:
  DTA      = no compression, top-k, pure greedy
  DTAc     = compression + skyline + backtrack (the full tool)
  staged   = DTA first, then compress chosen indexes (the poor decoupled
             strategy of Example 1)
  ablations= DTAc(None)/DTAc(Skyline)/DTAc(Backtrack) for Figures 12-13

Large workloads: `AdvisorOptions.compression_budget = N` advises on at
most ~N weighted representative statements instead of the raw workload
(repro.core.workload_compression), reporting a per-recommendation cost-
error certificate on the Recommendation (`compression_error_bound` /
`compression_error_rel`).  `None` (default) — and any budget >= the
statement count — runs the uncompressed pipeline bit-identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from . import candidates as cand
from .compression import DEFAULT_ADVISOR_METHODS
from .cost_engine import CostEngine
from .enumeration import (EnumerationResult, greedy_enumerate,
                          greedy_enumerate_scalar)
from .estimation_engine import EstimationEngine
from .estimation_graph import EstimationPlanner, NodeKey, Plan
from .relation import IndexDef
from .samplecf import SampleManager
from .whatif import (Configuration, SizeProvider, WhatIfOptimizer,
                     base_configuration, storage_used)
from .workload import Query, Workload
from .workload_compression import CompressedWorkload, compress_workload


@dataclasses.dataclass
class AdvisorOptions:
    methods: Tuple[str, ...] = DEFAULT_ADVISOR_METHODS
    consider_compression: bool = True
    candidate_mode: str = "skyline"        # "skyline" | "topk"
    enumeration: str = "backtrack"         # "backtrack" | "pure" | "density"
    topk: int = 2
    max_skyline_points: int = 8
    include_clustered: bool = True
    e: float = 0.5                         # size-estimation error tolerance
    q: float = 0.9                         # ... at this confidence
    use_deduction: bool = True
    sample_seed: int = 0
    use_engine: bool = True                # batched cost engine (hot path)
    engine_backend: str = "numpy"          # "numpy" | "jax"
    use_batched_estimation: bool = True    # batched SampleCF engine (§4-§5)
    estimation_backend: str = "numpy"      # "numpy" | "jax"
    use_batched_planner: bool = True       # batched §5.2 planner engine
    planner_backend: str = "numpy"         # "numpy" | "jax"
    # THE unified accelerator knob: backend="jax" (or "numpy") overrides
    # every per-module *_backend above, threading one backend through
    # costing, codec-bytes kernels, estimation, planner scoring, and the
    # fleet COST phase.  None keeps the per-module knobs (compat).
    backend: Optional[str] = None

    def __post_init__(self):
        if self.backend is not None:
            from .backend import BACKENDS
            if self.backend not in BACKENDS:
                raise ValueError(f"unknown backend {self.backend!r} "
                                 f"(expected one of {BACKENDS})")
            self.engine_backend = self.backend
            self.estimation_backend = self.backend
            self.planner_backend = self.backend
    # advise on <= ~N weighted representatives (workload compression);
    # None disables, and budget >= n_statements is an exact bypass
    compression_budget: Optional[int] = None
    # --- durability knobs for long-lived sessions (None = unbounded).
    # All three bound RECOMPUTABLE state, so results stay bit-identical;
    # see session.AdvisorSession / planner_engine.PlannerEngine.
    samplecf_cache_entries: Optional[int] = None  # LRU (NodeKey, f) cache
    max_planner_nodes: Optional[int] = None       # node-universe epoch bound
    max_replay_entries: Optional[int] = None      # replay-store bound

    @staticmethod
    def dta() -> "AdvisorOptions":
        return AdvisorOptions(consider_compression=False,
                              candidate_mode="topk", enumeration="pure")

    @staticmethod
    def dtac() -> "AdvisorOptions":
        return AdvisorOptions()


def select_candidates(costed: Sequence[cand.Candidate],
                      options: AdvisorOptions) -> List[cand.Candidate]:
    """§6.1 per-query selection switch (skyline or top-k) — one shared
    implementation for the one-shot advisor and the online session."""
    if options.candidate_mode == "skyline":
        sel = cand.select_skyline(costed)
        return cand.skyline_representatives(sel, options.max_skyline_points)
    return cand.select_topk(costed, options.topk)


def pool_with_merged(pool: Dict[Tuple, IndexDef],
                     merged_all: Sequence[IndexDef]
                     ) -> Dict[Tuple, IndexDef]:
    """Append merged candidates to the selection pool (Figure 1: Merging
    sits between candidate selection and enumeration) — shared so the
    one-shot advisor and the online session cannot drift."""
    for idx in merged_all:
        pool.setdefault(idx.key, idx)
    return pool


def enumerate_pool(optimizer, sizes, options: AdvisorOptions,
                   pool: Dict[Tuple, IndexDef], base: Configuration,
                   budget_bytes: float,
                   engine: Optional[CostEngine]) -> EnumerationResult:
    """§6.2 greedy enumeration dispatch — one shared implementation for
    the one-shot advisor and the online session (their bit-exact parity
    contract depends on running the same code here)."""
    if engine is not None:
        return greedy_enumerate(optimizer, sizes, list(pool.values()),
                                base, budget_bytes,
                                variant=options.enumeration, engine=engine)
    return greedy_enumerate_scalar(optimizer, sizes, list(pool.values()),
                                   base, budget_bytes,
                                   variant=options.enumeration)


@dataclasses.dataclass
class Recommendation:
    config: Configuration
    base: Configuration
    base_cost: float
    cost: float
    used_bytes: float
    budget_bytes: float
    estimation_cost_pages: float
    estimation_plan: Optional[Plan]
    n_sampled: int
    n_deduced: int
    candidate_count: int
    pool_size: int
    wall_seconds: float
    steps: List[str]
    # workload-compression annotations (trailing defaults keep older
    # construction sites and dataclasses.replace uses valid)
    n_statements_full: int = 0      # raw workload statement count
    n_representatives: int = 0      # statements actually advised on
    compression_error_bound: float = 0.0   # certified |C_full - C_comp|
    compression_error_rel: float = 0.0     # ... relative to `cost`

    @property
    def improvement(self) -> float:
        """Estimated runtime improvement vs. the base design (Fig. 12-17)."""
        if self.base_cost <= 0:
            return 0.0
        return 1.0 - self.cost / self.base_cost


class DesignAdvisor:
    def __init__(self, workload: Workload,
                 options: Optional[AdvisorOptions] = None):
        self.workload = workload
        self.schema = workload.schema
        self.opt = options or AdvisorOptions()
        self.sizes = SizeProvider(self.schema)
        self.optimizer = WhatIfOptimizer(workload, self.sizes)
        self.samples = SampleManager(self.schema.tables,
                                     seed=self.opt.sample_seed)
        # populated by `recommend` when workload compression engages
        self.compressed: Optional[CompressedWorkload] = None
        self.inner: Optional["DesignAdvisor"] = None

    # ------------------------------------------------------------------
    def per_query_raw(self) -> Dict[str, List[IndexDef]]:
        return {
            q.name: cand.syntactically_relevant(
                q, self.schema.tables[q.table],
                include_clustered=self.opt.include_clustered)
            for q in self.workload.queries()
        }

    def _candidate_universe(self) -> Tuple[Dict[str, List[IndexDef]],
                                           List[IndexDef], List[IndexDef]]:
        """One pass over candidate generation + compression expansion.

        Returns (per-query expanded candidates, expanded merged candidates,
        the deduplicated union of both).  Everything downstream — size
        estimation, per-query costing, the enumeration pool — reuses these
        lists, so `expand_with_compression` runs once per candidate set
        instead of once in generate_candidates() and again per query.
        """
        per_query = self.per_query_raw()
        seen: Dict[Tuple, IndexDef] = {}
        for cands in per_query.values():
            for idx in cands:
                seen.setdefault(idx.key, idx)
        merged = cand.merged_candidates(per_query)
        for idx in merged:
            seen.setdefault(idx.key, idx)
        # canonical union order (raw candidates are predicate-free, so
        # (table, cols, clustered) is unique): a first-seen order would
        # reshuffle whenever an early statement leaves the workload,
        # churning the estimation targets' deduction groups for nothing —
        # sorted order is stable under workload deltas
        raw = sorted(seen.values(),
                     key=lambda i: (i.table, i.cols, i.clustered))
        if not self.opt.consider_compression:
            return per_query, merged, raw
        per_query_exp = {name: cand.expand_with_compression(c,
                                                            self.opt.methods)
                         for name, c in per_query.items()}
        merged_exp = cand.expand_with_compression(merged, self.opt.methods)
        all_cands = cand.expand_with_compression(raw, self.opt.methods)
        return per_query_exp, merged_exp, all_cands

    def generate_candidates(self) -> List[IndexDef]:
        return self._candidate_universe()[2]

    # ------------------------------------------------------------------
    @staticmethod
    def estimation_targets(all_cands: Sequence[IndexDef]
                           ) -> Dict[NodeKey, List[IndexDef]]:
        """Size-estimation targets of a candidate set: the compressed,
        predicate-free candidates, deduplicated (in order) into NodeKeys
        mapped to their IndexDef variants.  Shared with the estimation
        benchmark and parity tests so they measure exactly the target
        set the advisor estimates."""
        tkey_to_defs: Dict[NodeKey, List[IndexDef]] = {}
        for idx in all_cands:
            if idx.compression is None or idx.predicate is not None:
                continue
            k = NodeKey(idx.table, idx.cols, idx.compression)
            tkey_to_defs.setdefault(k, []).append(idx)
        return tkey_to_defs

    def estimate_sizes(self, all_cands: Sequence[IndexDef]
                       ) -> Tuple[float, Optional[Plan], int, int]:
        """Register estimated sizes for every compressed candidate."""
        tkey_to_defs = self.estimation_targets(all_cands)
        targets = list(tkey_to_defs)
        if not targets:
            return 0.0, None, 0, 0

        # one-shot planner: skip the cross-run replay bookkeeping the
        # persistent AdvisorSession planner records
        planner = EstimationPlanner(self.schema.tables,
                                    backend=self.opt.planner_backend,
                                    use_engine=self.opt.use_batched_planner,
                                    record=False)
        if self.opt.use_deduction:
            plan = planner.plan(targets, self.opt.e, self.opt.q)
        else:
            # "All": SampleCF on every target (the paper's baseline),
            # scanning the f grid for the cheapest fraction that satisfies
            # the (e, q) constraint without deductions.
            plan = planner.plan_all_sampled(targets, self.opt.e, self.opt.q)
        if self.opt.use_batched_estimation:
            engine = EstimationEngine(self.schema.tables, self.samples,
                                      backend=self.opt.estimation_backend)
            ests = planner.execute(plan, self.samples, engine=engine)
        else:
            ests = planner.execute_scalar(plan, self.samples)
        # execute() also resolves intermediate plan nodes; only register
        # sizes for defs that were actually requested as targets.
        for k, est in ests.items():
            for idx in tkey_to_defs.get(k, ()):
                self.sizes.register(idx, est.est_bytes)
        return plan.total_cost, plan, plan.n_sampled(), plan.n_deduced()

    # ------------------------------------------------------------------
    # Pipeline stages.  `recommend` composes them; the online
    # `repro.core.session.AdvisorSession` invokes them selectively with
    # its incremental caches.  This one-shot composition is the frozen
    # parity reference for the session.
    # ------------------------------------------------------------------
    def build_engine(self) -> Optional[CostEngine]:
        """Stage: the batched what-if engine over the current sizes (None
        on the scalar path).  Built after size estimation so every
        compressed candidate is scored with its estimated size."""
        if not self.opt.use_engine:
            return None
        return CostEngine(self.workload, self.sizes,
                          backend=self.opt.engine_backend)

    def select_pool(self, per_query_exp: Dict[str, List[IndexDef]],
                    merged_all: Sequence[IndexDef], base: Configuration,
                    engine: Optional[CostEngine]
                    ) -> Tuple[Dict[Tuple, IndexDef], int]:
        """Stage: per-query candidate costing + §6.1 selection; merged
        candidates enter the pool directly (Figure 1: Merging sits
        between candidate selection and enumeration)."""
        pool: Dict[Tuple, IndexDef] = {}
        n_cand = 0
        for q in self.workload.queries():
            costed = cand.cost_candidates(q, per_query_exp[q.name], base,
                                          self.optimizer, self.sizes,
                                          engine=engine)
            n_cand += len(costed)
            for c in select_candidates(costed, self.opt):
                pool.setdefault(c.index.key, c.index)
        return pool_with_merged(pool, merged_all), n_cand

    def enumerate_pool(self, pool: Dict[Tuple, IndexDef],
                       base: Configuration, budget_bytes: float,
                       engine: Optional[CostEngine]) -> EnumerationResult:
        """Stage: §6.2 greedy enumeration over the selected pool."""
        return enumerate_pool(self.optimizer, self.sizes, self.opt, pool,
                              base, budget_bytes, engine)

    def _recommend_full(self, budget_bytes: float) -> Recommendation:
        """The uncompressed pipeline (every statement advised directly)."""
        t0 = time.perf_counter()
        base = base_configuration(self.schema)

        per_query_exp, merged_all, all_cands = self._candidate_universe()
        est_cost, plan, n_s, n_d = self.estimate_sizes(all_cands)

        engine = self.build_engine()
        base_cost = (engine.config_cost(base) if engine is not None
                     else self.optimizer.workload_cost(base))
        pool, n_cand = self.select_pool(per_query_exp, merged_all, base,
                                        engine)
        res = self.enumerate_pool(pool, base, budget_bytes, engine)
        n_full = len(self.workload.statements)
        return Recommendation(
            config=res.config, base=base, base_cost=base_cost, cost=res.cost,
            used_bytes=res.used_bytes, budget_bytes=budget_bytes,
            estimation_cost_pages=est_cost, estimation_plan=plan,
            n_sampled=n_s, n_deduced=n_d, candidate_count=n_cand,
            pool_size=len(pool), wall_seconds=time.perf_counter() - t0,
            steps=res.steps, n_statements_full=n_full,
            n_representatives=n_full)

    def recommend(self, budget_bytes: float) -> Recommendation:
        """Full recommendation; with `opt.compression_budget` set (and
        below the statement count) the pipeline runs on the compressed
        weighted-representative workload and the returned recommendation
        carries the certified cost-error bound.  A disabled or >= n
        budget runs `_recommend_full` — bit-identical to a pre-compression
        advisor (the exact-parity contract)."""
        comp = compress_workload(self.workload, self.opt.compression_budget)
        if comp is None:
            self.compressed = None
            self.inner = None
            return self._recommend_full(budget_bytes)
        t0 = time.perf_counter()
        inner = DesignAdvisor(
            comp.workload,
            dataclasses.replace(self.opt, compression_budget=None))
        inner.samples = self.samples   # draw-order-independent: shareable
        self.compressed = comp
        self.inner = inner
        rec = inner._recommend_full(budget_bytes)
        eps = comp.error_bound(rec.config, inner.sizes)
        return dataclasses.replace(
            rec, n_statements_full=comp.n_full,
            n_representatives=comp.n_representatives,
            compression_error_bound=eps,
            compression_error_rel=eps / max(abs(rec.cost), 1e-12),
            wall_seconds=time.perf_counter() - t0)


def staged_recommend(workload: Workload, budget_bytes: float,
                     methods: Optional[Sequence[str]] = None,
                     options: Optional[AdvisorOptions] = None
                     ) -> Recommendation:
    """The decoupled strategy of Example 1: select uncompressed indexes
    first, then compress the chosen ones to reclaim space (repeat once).

    Honors the caller's `AdvisorOptions`: stage 1 runs DTA (no
    compression) but inherits the caller's estimation settings and
    backends, stage 2 plans compressed sizes against the caller's (e, q)
    rather than a hard-coded (0.5, 0.9), and the stage-2/3 recompression
    loop is costed through the batched `CostEngine.config_cost` (the
    scalar `workload_cost` when `use_engine` is off)."""
    opt = options or AdvisorOptions()
    if methods is None:
        methods = opt.methods
    stage1 = dataclasses.replace(
        AdvisorOptions.dta(), e=opt.e, q=opt.q,
        sample_seed=opt.sample_seed, include_clustered=opt.include_clustered,
        use_engine=opt.use_engine, engine_backend=opt.engine_backend,
        use_batched_estimation=opt.use_batched_estimation,
        estimation_backend=opt.estimation_backend,
        use_batched_planner=opt.use_batched_planner,
        planner_backend=opt.planner_backend)
    adv = DesignAdvisor(workload, stage1)
    rec = adv.recommend(budget_bytes)
    # stage 2: compress every selected secondary index with the best method
    sizes, optimizer = adv.sizes, adv.optimizer
    # register sizes for compressed variants of the chosen indexes
    chosen = [i for i in rec.config.indexes if not i.clustered]
    variants = cand.expand_with_compression(chosen, methods)
    planner = EstimationPlanner(adv.schema.tables,
                                backend=opt.planner_backend,
                                use_engine=opt.use_batched_planner,
                                record=False)
    targets = [NodeKey(i.table, i.cols, i.compression) for i in variants
               if i.compression is not None]
    if targets:
        plan = planner.plan(targets, opt.e, opt.q)
        ests = (planner.execute(plan, adv.samples)
                if opt.use_batched_estimation
                else planner.execute_scalar(plan, adv.samples))
        for k, est in ests.items():
            sizes.register(IndexDef(k.table, k.cols, k.method), est.est_bytes)
    # the recompression loop's cost oracle: the batched engine, built
    # AFTER the compressed sizes are registered so variants score with
    # their estimated sizes
    if opt.use_engine:
        cost_fn = CostEngine(workload, sizes,
                             backend=opt.engine_backend).config_cost
    else:
        cost_fn = optimizer.workload_cost
    config = rec.config
    for idx in chosen:
        best = (cost_fn(config), config)
        for m in methods:
            cfg2 = config.replace(idx, idx.with_compression(m))
            c2 = cost_fn(cfg2)
            if c2 < best[0]:
                best = (c2, cfg2)
        config = best[1]
    # stage 3: with reclaimed space, account the recompressed footprint
    used = storage_used(config, rec.base, sizes)
    return dataclasses.replace(
        rec, config=config, cost=cost_fn(config), used_bytes=used)

"""SampleCF (paper §2.2) with per-table amortized sampling (§4.1).

SampleCF(I, method, f): take a uniform random sample of fraction f of I's
table (ONE sample per (table, f), reused for every index on that table —
the §4.1 amortization), build the index on the sample, compress it, and
return CF = S^c / S.

The *cost* of a SampleCF call is modeled as the number of pages of the
index built on the sample, before compression (paper §5.1).
"""
from __future__ import annotations

import dataclasses
import hashlib
import zlib
from collections import OrderedDict
from typing import Dict, Iterator, MutableMapping, Optional, Tuple

import numpy as np

from . import compression
from .relation import (IndexDef, Table, build_index_data, rows_per_page,
                       uncompressed_pages)


def table_fingerprint(table: Table) -> str:
    """Content digest of a table: name, column defs, row count, and the
    raw int64 column buffers.  Cached in the table's stats cache — tables
    are immutable once built (deltas produce new Table objects), so the
    digest is computed at most once per table object."""
    key = ("content_fingerprint",)
    fp = table._stats_cache.get(key)
    if fp is None:
        h = hashlib.sha256()
        h.update(table.name.encode("utf-8"))
        h.update(str(table.nrows).encode("ascii"))
        for c in table.columns:
            h.update(f"|{c.name}:{c.width}".encode("utf-8"))
            h.update(np.ascontiguousarray(table.values[c.name]).tobytes())
        fp = table._stats_cache[key] = h.hexdigest()
    return fp


def schema_fingerprint(schema, sample_seed: int) -> str:
    """Digest identifying everything SampleCF estimates depend on: every
    table's content, the foreign keys, and the sampling seed.

    Two workloads with equal fingerprints draw byte-identical samples for
    any (table, f) and therefore produce byte-identical `SizeEstimate`s
    for any (NodeKey, f) — the soundness condition for sharing one
    `SampleManager` and one sampled-estimate cache across tenants (the
    fleet service's cross-tenant amortization)."""
    h = hashlib.sha256()
    h.update(str(int(sample_seed)).encode("ascii"))
    for name in sorted(schema.tables):
        h.update(table_fingerprint(schema.tables[name]).encode("ascii"))
    for fk in schema.foreign_keys:
        h.update(f"|{fk.fact_table}.{fk.fk_col}->"
                 f"{fk.dim_table}.{fk.dim_key}".encode("utf-8"))
    return h.hexdigest()


@dataclasses.dataclass
class SizeEstimate:
    index: IndexDef
    est_bytes: float
    method: str            # "samplecf" | "deduction:..." | "exact"
    cost_pages: float      # estimation cost charged (paper §5.1)
    cf: float              # estimated compression fraction


class EstimateCache(MutableMapping):
    """Bounded LRU (NodeKey, f) -> `SizeEstimate` mapping.

    Drop-in for the plain dict `AdvisorSession(sampled_cache=...)` /
    the fleet share groups use: same mapping protocol, but capped at
    `maxsize` entries with least-recently-USED eviction (`get` and
    `__getitem__` refresh recency; `__contains__` is a pure peek so
    membership scans don't distort the LRU order).

    Eviction is SAFE for the exact-parity contract: every entry is a
    pure function of (schema content, sample seed, NodeKey, f) over the
    order-independent `SampleManager`, so an evicted entry is simply
    recomputed bit-identically on the next miss.  Hit/miss/eviction
    counters are exposed for `stats()`.
    """

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError("EstimateCache maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._d: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __getitem__(self, key):
        v = self._d[key]           # KeyError propagates on a miss
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def get(self, key, default=None):
        try:
            v = self._d[key]
        except KeyError:
            self.misses += 1
            return default
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def __setitem__(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.evictions += 1

    def __delitem__(self, key) -> None:
        del self._d[key]

    def __contains__(self, key) -> bool:
        # pure membership: no recency touch, no counter — callers use it
        # to SCAN (miss counting, prefetch dedup) without perturbing LRU
        return key in self._d

    def __iter__(self) -> Iterator:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    def items(self):
        # pure peek, like __contains__: snapshotting the cache (session
        # checkpoints) must neither count hits nor touch recency — and
        # the MutableMapping default would move_to_end mid-iteration
        return list(self._d.items())

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._d), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


class SampleManager:
    """Caches per-(table, f) samples so sampling cost is paid once (§4.1).

    Each (table, f) sample is drawn from its own seed-derived RNG stream,
    so the sample content depends only on (seed, table, f) — never on the
    *order* samples were first requested in.  A long-lived manager (the
    online `AdvisorSession`) therefore produces exactly the samples a
    fresh equal-seed manager would, whatever was drawn before.
    """

    def __init__(self, tables: Dict[str, Table], seed: int = 0):
        self.tables = dict(tables)
        self.seed = int(seed)
        self._samples: Dict[Tuple[str, float], Table] = {}
        self.sampling_calls = 0  # how many fresh samples were drawn

    def add_table(self, table: Table) -> None:
        self.tables[table.name] = table

    def _rng_for(self, table_name: str, f: float) -> np.random.Generator:
        # the f quantization MUST match the sample-cache key below: a
        # finer-grained seed would reintroduce draw-order dependence for
        # f values that collide in the cache
        key = (self.seed, zlib.crc32(table_name.encode("utf-8")),
               int(round(round(f, 6) * 1e6)))
        return np.random.default_rng(key)

    def get_sample(self, table_name: str, f: float) -> Table:
        key = (table_name, round(f, 6))
        if key not in self._samples:
            t = self.tables[table_name]
            n = max(2, int(round(t.nrows * f)))
            n = min(n, t.nrows)
            rng = self._rng_for(table_name, f)
            rows = rng.choice(t.nrows, size=n, replace=False)
            self._samples[key] = t.take(np.sort(rows))
            self.sampling_calls += 1
        return self._samples[key]


def full_index_sizes(table: Table, idx: IndexDef) -> Tuple[int, int]:
    """(uncompressed_bytes, compressed_bytes) by building the FULL index.

    Prohibitively expensive in a real tool (this is the paper's point) —
    used here only as ground truth for accuracy experiments.
    """
    data = build_index_data(table, idx)
    widths = [table.col_by_name[c].width for c in idx.cols]
    s = compression.uncompressed_payload_bytes(data.shape[0], widths)
    if idx.compression is None:
        return s, s
    sc = compression.compressed_payload_bytes(idx.compression, data, widths)
    return s, sc


def sample_cf(manager: SampleManager, idx: IndexDef, f: float,
              sample_table: Optional[Table] = None,
              bias_correct: bool = True) -> SizeEstimate:
    """Estimate the compressed size of `idx` via SampleCF.

    `sample_table` overrides the amortized base sample (used for filtered
    samples / join synopses, App. B).  `bias_correct` divides the estimate
    by the fitted E[X] of the method's error model (beyond-paper extension;
    see errors.samplecf_bias).
    """
    table = manager.tables[idx.table]
    sample = sample_table if sample_table is not None else \
        manager.get_sample(idx.table, f)
    widths = [table.col_by_name[c].width for c in idx.cols]

    data = build_index_data(sample, idx)
    n_sample = data.shape[0]
    s = compression.uncompressed_payload_bytes(n_sample, widths)
    # full index cardinality the estimate is scaled to
    if idx.predicate is not None:
        full_rows = int(idx.predicate.mask(table).sum())
    else:
        full_rows = table.nrows
    full_bytes = compression.uncompressed_payload_bytes(full_rows, widths)
    if idx.compression is None:
        cf = 1.0
    elif n_sample == 0 or s == 0:
        cf = 1.0
    elif idx.compression == "GDICT":
        # NDV does not scale with the sample (the dictionary of a small
        # sample is nearly all-distinct), so linear CF scaling
        # over-estimates GDICT; price the full index directly with the
        # App. B Adaptive Estimator instead.
        from . import distinct
        sc = full_rows * compression.ROW_OVERHEAD
        for j, w in enumerate(widths):
            sc = sc + distinct.gdict_estimated_col_bytes(
                data[:, j], w, full_rows)
        cf = sc / full_bytes
        if bias_correct:
            from . import errors
            cf = min(cf / errors.samplecf_bias(idx.compression, f), 1.0)
    else:
        sc = compression.compressed_payload_bytes(idx.compression, data, widths)
        cf = sc / s
        if bias_correct:
            from . import errors
            cf = min(cf / errors.samplecf_bias(idx.compression, f), 1.0)
    cost = uncompressed_pages(n_sample, widths)
    return SizeEstimate(index=idx, est_bytes=cf * full_bytes,
                        method="samplecf", cost_pages=float(cost), cf=cf)


def exact_size(table: Table, idx: IndexDef) -> SizeEstimate:
    """Size of an index that already exists: zero cost, zero error (§5.1)."""
    s, sc = full_index_sizes(table, idx)
    return SizeEstimate(index=idx, est_bytes=float(sc), method="exact",
                        cost_pages=0.0, cf=sc / max(s, 1))

"""Estimation-plan optimization over the index/deduction graph (paper §5).

Given target compressed indexes, a tolerable error e and confidence q, choose
for each index either SampleCF (costly, accurate) or a deduction (free, less
accurate) plus a single sampling fraction f, minimizing total estimation cost
subject to: P(|relative error| within e) >= q for every target.

Implements the paper's greedy algorithm (§5.2 pseudocode) and the exponential
Optimal recursion (Appendix D) used as a quality yardstick in benchmarks.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from . import deduction as ded
from . import errors as err
from .compression import METHODS
from .estimation_engine import EstimationEngine
from .relation import IndexDef, Table, uncompressed_pages
from .samplecf import SampleManager, SizeEstimate, sample_cf

F_GRID = (0.01, 0.025, 0.05, 0.075, 0.10)

# q strictly above any probability: every deduction fails the constraint, so
# greedy degenerates to SampleCF-on-everything (the paper's "All" baseline).
FORCE_ALL_Q = 1.1


class State(enum.Enum):
    NONE = "NONE"
    DEDUCED = "DEDUCED"
    SAMPLED = "SAMPLED"
    EXACT = "EXACT"  # existing index: true size known from catalog (§5.1)


@dataclasses.dataclass(frozen=True)
class NodeKey:
    table: str
    cols: Tuple[str, ...]
    method: str

    def __hash__(self) -> int:
        # NodeKeys are hashed millions of times per greedy run; cache the
        # field-tuple hash on first use (frozen blocks plain assignment).
        # Plain attribute access beats __dict__.get by ~5x and this IS a
        # measured hot path (every dict op on plans/universes lands here).
        try:
            return self._hash
        except AttributeError:
            h = hash((self.table, self.cols, self.method))
            object.__setattr__(self, "_hash", h)
            return h

    def gkey(self) -> Tuple[str, frozenset, str]:
        """ColSet-group key (table, column SET, method), cached — the
        planner engine's per-round group pass would otherwise rebuild
        the frozenset for every target every round."""
        try:
            return self._gkey
        except AttributeError:
            g = (self.table, frozenset(self.cols), self.method)
            object.__setattr__(self, "_gkey", g)
            return g

    def label(self) -> str:
        return f"{self.table}({','.join(self.cols)})^{self.method}"


@dataclasses.dataclass(frozen=True)
class Deduction:
    kind: str                       # "colset" | "colext"
    children: Tuple[NodeKey, ...]
    parts: Tuple[Tuple[str, ...], ...]  # column partition (colext)


@dataclasses.dataclass
class Node:
    key: NodeKey
    state: State = State.NONE
    chosen: Optional[Deduction] = None
    rv: err.ErrorRV = err.EXACT
    exact_bytes: Optional[float] = None


@dataclasses.dataclass
class Plan:
    f: float
    nodes: Dict[NodeKey, Node]
    targets: Tuple[NodeKey, ...]
    total_cost: float
    feasible: bool

    def states(self) -> Dict[NodeKey, State]:
        return {k: n.state for k, n in self.nodes.items()}

    def n_sampled(self) -> int:
        return sum(1 for n in self.nodes.values() if n.state is State.SAMPLED)

    def n_deduced(self) -> int:
        return sum(1 for n in self.nodes.values() if n.state is State.DEDUCED)


def sampling_cost(table: Table, key: NodeKey, f: float) -> float:
    """Cost of SampleCF = pages of the index built on the sample (§5.1)."""
    widths = [table.col_by_name[c].width for c in key.cols]
    n = max(2, int(round(table.nrows * f)))
    return float(uncompressed_pages(n, widths))


def memoized_sampling_cost(tables: Dict[str, Table], memo: Dict,
                           key: NodeKey, f: float) -> float:
    """`sampling_cost` behind a caller-owned (table, cols, f) memo — the
    ONE pricing helper shared by the scalar planner and the batched
    engine (which also share the memo dict), so the §5.1 formula cannot
    drift between the two paths."""
    ck = (key.table, key.cols, f)
    c = memo.get(ck)
    if c is None:
        c = memo[ck] = sampling_cost(tables[key.table], key, f)
    return c


@functools.lru_cache(maxsize=65536)
def _colext_deductions(key: NodeKey) -> Tuple[Deduction, ...]:
    """ColExt partitions of `key` (pure in the key, so cached globally)."""
    cols = key.cols
    if len(cols) < 2:
        return ()
    partitions = {tuple((c,) for c in cols)}
    partitions.add((cols[:-1], (cols[-1],)))
    partitions.add(((cols[0],), cols[1:]))
    return tuple(
        Deduction("colext",
                  tuple(NodeKey(key.table, p, key.method) for p in parts),
                  parts)
        for parts in sorted(partitions))


@functools.lru_cache(maxsize=262144)
def _colset_ded(other: NodeKey) -> Deduction:
    """One shared ColSet Deduction per mate (planner-engine graph build):
    a ColSet group of g nodes yields O(g^2) (target, mate) pairs but only
    g distinct deductions.  The scalar reference below keeps constructing
    its own objects — it is the frozen parity/benchmark baseline."""
    return Deduction("colset", (other,), (other.cols,))


def _colset_deductions(key: NodeKey, mates: Sequence[NodeKey]
                       ) -> List[Deduction]:
    """ColSet deductions from `mates` (same table/column-set/method nodes)."""
    if METHODS[key.method].order_dependent:
        return []
    return [Deduction("colset", (other,), (other.cols,))
            for other in mates if other.cols != key.cols]


def candidate_deductions(key: NodeKey, present: Sequence[NodeKey]
                         ) -> List[Deduction]:
    """Enumerate deductions for `key` (bounded, per §5.2 Figure 3).

    * ColSet: any present node with the same column SET + method (ORD-IND).
    * ColExt partitions: all singletons; (prefix, last); (first, rest).

    The greedy loop maintains a (table, column-set, method) index over its
    node set and calls the two halves directly; this scanning form is kept
    for callers holding a plain node list (`optimal`, tests).
    """
    cs = frozenset(key.cols)
    mates = [o for o in present
             if o.table == key.table and o.method == key.method
             and frozenset(o.cols) == cs]
    return _colset_deductions(key, mates) + list(_colext_deductions(key))


@functools.lru_cache(maxsize=65536)
def _compose_cached(rvs: Tuple[err.ErrorRV, ...]) -> err.ErrorRV:
    # samplecf_error/colext_error are memoized, so the same ErrorRV objects
    # recur across targets and f values; cache their Goodman composition.
    return err.compose(rvs)


def _deduction_rv(key: NodeKey, d: Deduction,
                  nodes: Dict[NodeKey, Node]) -> err.ErrorRV:
    child_rvs = tuple(nodes[c].rv for c in d.children)
    if d.kind == "colset":
        drv = err.colset_error()
    else:
        drv = err.colext_error(key.method, len(d.children))
    return _compose_cached(child_rvs + (drv,))


class EstimationPlanner:
    """Builds the graph and runs the greedy (or optimal) state assignment.

    The greedy runs on the batched `planner_engine.PlannerEngine` by default
    (one pass over a shared deduction graph scores all sampling fractions);
    `greedy_scalar` is the original per-(target, candidate, f) reference
    implementation, kept for plan-identical parity checks.  `use_engine`
    selects the path; `backend` picks the engine's scoring backend
    ("numpy" — the parity reference — or the optional "jax" mirror of
    `CostEngine(backend="jax")`).
    """

    def __init__(self, tables: Dict[str, Table],
                 existing: Optional[Dict[NodeKey, float]] = None,
                 backend: str = "numpy", use_engine: bool = True,
                 record: bool = True, max_nodes: Optional[int] = None,
                 max_replay: Optional[int] = None, faults=None):
        self.tables = tables
        self.existing = dict(existing or {})
        self.backend = backend
        self.use_engine = use_engine
        self.record = record   # False: skip cross-run replay bookkeeping
        # durability knobs, forwarded to the lazily-built PlannerEngine:
        # epoch bounds on the node universe / replay store, and the
        # seeded fault injector (site "planner_replay")
        self.max_nodes = max_nodes
        self.max_replay = max_replay
        self.faults = faults
        self._engine = None
        self._scost: Dict[Tuple[str, Tuple[str, ...], float], float] = {}

    @property
    def engine(self):
        """The batched planner engine (built lazily, shared graph cache).
        The §5.1 sampling-cost memo is shared with the scalar path."""
        if self._engine is None:
            from .planner_engine import PlannerEngine
            self._engine = PlannerEngine(self.tables, self.existing,
                                         backend=self.backend,
                                         scost_memo=self._scost,
                                         record=self.record,
                                         max_nodes=self.max_nodes,
                                         max_replay=self.max_replay,
                                         faults=self.faults)
        return self._engine

    def _sampling_cost(self, key: NodeKey, f: float) -> float:
        return memoized_sampling_cost(self.tables, self._scost, key, f)

    # ------------------------------------------------------------------
    # Greedy algorithm (paper §5.2 pseudocode)
    # ------------------------------------------------------------------
    def greedy(self, targets: Sequence[NodeKey], f: float, e: float,
               q: float) -> Plan:
        """One greedy run at fraction `f` (engine-backed by default)."""
        if not self.use_engine:
            return self.greedy_scalar(targets, f, e, q)
        return self.engine.greedy_batch(targets, e, q, (f,))[0]

    def greedy_scalar(self, targets: Sequence[NodeKey], f: float, e: float,
                      q: float) -> Plan:
        """Scalar §5.2 reference: per-(target, candidate) Python scoring.

        The batched engine (`planner_engine.PlannerEngine.greedy_batch`)
        must stay plan-identical to this — same states, same chosen
        deductions, same total_cost — for every f."""
        nodes: Dict[NodeKey, Node] = {}
        # (table, column set, method) -> nodes, in insertion order: the
        # ColSet mate lookup without scanning the whole node dict.
        by_set: Dict[Tuple[str, frozenset, str], List[NodeKey]] = {}

        def index_key(k: NodeKey) -> None:
            by_set.setdefault((k.table, frozenset(k.cols), k.method),
                              []).append(k)

        # Line 1: existing indexes enter as SAMPLED (zero error / zero cost;
        # we use the dedicated EXACT state).
        for k, size in self.existing.items():
            nodes[k] = Node(k, State.EXACT, rv=err.EXACT, exact_bytes=size)
            index_key(k)
        # Line 2: targets start as NONE.
        for t in targets:
            if t not in nodes:
                nodes[t] = Node(t)
                index_key(t)

        def ensure(k: NodeKey) -> Node:
            n = nodes.get(k)
            if n is None:
                n = nodes[k] = Node(k)
                index_key(k)
            return n

        def known(n: Node) -> bool:
            return n.state in (State.SAMPLED, State.DEDUCED, State.EXACT)

        total_cost = 0.0
        feasible = True
        used_as_child: set = set()
        # Line 3: narrower to wider.
        order = sorted(targets, key=lambda k: (len(k.cols), k.cols))
        for t in order:
            node = nodes[t]
            if known(node):
                continue
            # Lines 4-5: materialize candidate deductions + children.
            mates = by_set.get((t.table, frozenset(t.cols), t.method), ())
            cands = _colset_deductions(t, mates) + list(_colext_deductions(t))
            for d in cands:
                for c in d.children:
                    ensure(c)

            # Line 6-7: an already-enabled deduction that satisfies e,q.
            best_d, best_p = None, -1.0
            for d in cands:
                if all(known(nodes[c]) for c in d.children):
                    rv = _deduction_rv(t, d, nodes)
                    p = err.prob_within(rv, e)
                    if p >= q and p > best_p:
                        best_d, best_p = d, p
            if best_d is not None:
                node.state = State.DEDUCED
                node.chosen = best_d
                node.rv = _deduction_rv(t, best_d, nodes)
                used_as_child.update(best_d.children)
                continue

            # Lines 8-9: enable a deduction by sampling its unknown children
            # if that is cheaper than sampling this node.
            my_cost = self._sampling_cost(t, f)
            best_d, best_cost = None, my_cost
            for d in cands:
                unknown = [c for c in d.children if not known(nodes[c])]
                if not unknown:
                    continue  # handled above (did not satisfy constraint)
                extra = sum(self._sampling_cost(c, f) for c in unknown)
                if extra >= best_cost:
                    continue
                # hypothetical rvs with the unknown children sampled
                trial = {c: err.samplecf_error(c.method, f) for c in unknown}
                child_rvs = tuple(trial.get(c, nodes[c].rv)
                                  for c in d.children)
                drv = (err.colset_error() if d.kind == "colset"
                       else err.colext_error(t.method, len(d.children)))
                rv = _compose_cached(child_rvs + (drv,))
                if err.prob_within(rv, e) >= q:
                    best_d, best_cost = d, extra
            if best_d is not None:
                for c in best_d.children:
                    cn = nodes[c]
                    if not known(cn):
                        cn.state = State.SAMPLED
                        cn.rv = err.samplecf_error(c.method, f)
                        total_cost += self._sampling_cost(c, f)
                node.state = State.DEDUCED
                node.chosen = best_d
                node.rv = _deduction_rv(t, best_d, nodes)
                used_as_child.update(best_d.children)
                continue

            # Lines 10-11: fall back to SampleCF on this node.
            node.state = State.SAMPLED
            node.rv = err.samplecf_error(t.method, f)
            total_cost += my_cost
            if not err.satisfies(node.rv, e, q):
                feasible = False  # even sampling cannot satisfy the bound

        # Lines 13-14: cleanup — drop nodes neither targeted nor used.
        tset = set(targets)
        for k in sorted(list(nodes), key=lambda k: -len(k.cols)):
            n = nodes[k]
            if k in tset or k in used_as_child or n.state is State.EXACT:
                continue
            if n.state is State.SAMPLED:
                total_cost -= self._sampling_cost(k, f)
            del nodes[k]

        for t in targets:
            if not err.satisfies(nodes[t].rv, e, q):
                feasible = False
        return Plan(f=f, nodes=nodes, targets=tuple(targets),
                    total_cost=total_cost, feasible=feasible)

    def plan(self, targets: Sequence[NodeKey], e: float, q: float,
             f_grid: Sequence[float] = F_GRID) -> Plan:
        """Outer loop over sampling fractions (§5.2 last paragraph).

        Engine path: one batched pass over the shared graph scores every
        fraction; only the winning plan is materialized."""
        if self.use_engine:
            return self.engine.plan_batch(targets, e, q, tuple(f_grid))
        best: Optional[Plan] = None
        fallback: Optional[Plan] = None
        for f in f_grid:
            p = self.greedy_scalar(targets, f, e, q)
            if p.feasible and (best is None or p.total_cost < best.total_cost):
                best = p
            if fallback is None or p.total_cost < fallback.total_cost:
                fallback = p
        return best if best is not None else fallback  # type: ignore

    def plan_scalar(self, targets: Sequence[NodeKey], e: float, q: float,
                    f_grid: Sequence[float] = F_GRID) -> Plan:
        """`plan` on the scalar reference greedy (parity/benchmark use)."""
        saved = self.use_engine
        try:
            self.use_engine = False
            return self.plan(targets, e, q, f_grid)
        finally:
            self.use_engine = saved

    def plan_all_sampled(self, targets: Sequence[NodeKey], e: float,
                         q: float, f_grid: Sequence[float] = F_GRID) -> Plan:
        """The paper's "All" baseline: SampleCF on every target, no
        deductions.

        Scans the sampling-fraction grid cheapest-first and returns the
        first all-sampled plan whose per-target SampleCF error satisfies
        the real (e, q) constraint; if no grid fraction does, falls back
        to the cheapest all-sampled plan, flagged infeasible.  (Sampling
        is forced by running greedy with q > 1, under which no deduction
        can satisfy the constraint — feasibility is then re-checked
        against the caller's q.)
        """
        if self.use_engine:
            return self.engine.plan_all_sampled_batch(targets, e, q,
                                                      tuple(f_grid))
        fallback: Optional[Plan] = None
        for f in f_grid:
            p = self.greedy_scalar(targets, f, e, FORCE_ALL_Q)
            feasible = all(err.satisfies(p.nodes[t].rv, e, q)
                           for t in targets)
            p = dataclasses.replace(p, feasible=feasible)
            if feasible:
                return p
            if fallback is None or p.total_cost < fallback.total_cost:
                fallback = p
        return fallback  # type: ignore

    # ------------------------------------------------------------------
    # Optimal exact algorithm (Appendix D) — exponential; benchmarks only.
    # ------------------------------------------------------------------
    def optimal(self, targets: Sequence[NodeKey], f: float, e: float,
                q: float, max_nodes: int = 14) -> Plan:
        targets = list(targets)
        if len(targets) > max_nodes:
            raise ValueError("optimal(): too many targets (exponential)")
        base_nodes: Dict[NodeKey, Node] = {}
        for k, size in self.existing.items():
            base_nodes[k] = Node(k, State.EXACT, rv=err.EXACT, exact_bytes=size)

        # Universe: targets + all their (recursive) potential children.
        universe: Dict[NodeKey, List[Deduction]] = {}
        frontier = list(targets)
        while frontier:
            k = frontier.pop()
            if k in universe:
                continue
            cands = candidate_deductions(
                k, list(universe) + list(base_nodes) + list(targets))
            universe[k] = cands
            for d in cands:
                for c in d.children:
                    if c not in universe:
                        frontier.append(c)

        best: List[Optional[Plan]] = [None]

        def recurse(states: Dict[NodeKey, Tuple[State, Optional[Deduction]]],
                    remaining: List[NodeKey], cost: float) -> None:
            if best[0] is not None and cost >= best[0].total_cost:
                return  # prune
            if not remaining:
                nodes = dict(base_nodes)
                ok = True
                # resolve rvs narrow->wide
                for k in sorted(states, key=lambda k: (len(k.cols), k.cols)):
                    st, d = states[k]
                    n = Node(k, st)
                    if st is State.SAMPLED:
                        n.rv = err.samplecf_error(k.method, f)
                    else:
                        if any(c not in nodes and c not in states
                               for c in d.children):
                            ok = False
                            break
                        n.chosen = d
                        n.rv = _deduction_rv(k, d, {**nodes})
                    nodes[k] = n
                if not ok:
                    return
                for t in targets:
                    if not err.satisfies(nodes[t].rv, e, q):
                        return
                best[0] = Plan(f=f, nodes=nodes, targets=tuple(targets),
                               total_cost=cost, feasible=True)
                return
            # branch on the widest remaining index (App. D line 7)
            remaining = sorted(remaining, key=lambda k: (len(k.cols), k.cols))
            k = remaining[-1]
            rest = remaining[:-1]
            # option 1: SAMPLED (priced via the shared §5.1 cost memo, so
            # optimal() and the greedy paths cannot drift)
            recurse({**states, k: (State.SAMPLED, None)}, rest,
                    cost + self._sampling_cost(k, f))
            # option 2: each deduction; children must be decided too
            for d in universe.get(k, []):
                new_children = [c for c in d.children
                                if c not in states and c not in base_nodes
                                and c not in rest and c != k]
                recurse({**states, k: (State.DEDUCED, d)},
                        rest + new_children, cost)

        recurse({}, list(targets), 0.0)
        if best[0] is None:
            return self.greedy(targets, f, e, q)
        return best[0]

    # ------------------------------------------------------------------
    # Plan execution: run SampleCF / deductions, produce actual sizes.
    # ------------------------------------------------------------------
    def execute(self, plan: Plan, manager: SampleManager,
                engine: Optional[EstimationEngine] = None
                ) -> Dict[NodeKey, SizeEstimate]:
        """Execute `plan` with the batched SampleCF engine (default).

        All SAMPLED nodes are estimated in grouped kernel calls (one batch
        per (table, f) group — byte-identical to the scalar reference,
        see `execute_scalar`); deductions then resolve from those.
        """
        if engine is None:
            engine = EstimationEngine(self.tables, manager)
        # a supplied engine must draw from the caller's sample store, or
        # the byte-identical contract with execute_scalar(manager) breaks
        assert engine.manager is manager, \
            "engine.manager must be the manager passed to execute()"
        sampled = [k for k, n in plan.nodes.items()
                   if n.state is State.SAMPLED]
        pre = engine.estimate_batch(sampled, plan.f)
        return self._resolve_plan(plan, pre.__getitem__)

    def execute_scalar(self, plan: Plan, manager: SampleManager
                       ) -> Dict[NodeKey, SizeEstimate]:
        """Exact-parity reference: one `sample_cf` call per SAMPLED node."""
        return self._resolve_plan(
            plan, lambda k: sample_cf(
                manager, IndexDef(k.table, k.cols, k.method), plan.f))

    def execute_cached(self, plan: Plan, manager: SampleManager,
                       cache: Dict[Tuple[NodeKey, float], SizeEstimate],
                       engine: Optional[EstimationEngine] = None,
                       scalar: bool = False) -> Dict[NodeKey, SizeEstimate]:
        """`execute` with SAMPLED estimates cached by (NodeKey, f) — the
        online-session path.  A SAMPLED node's estimate is a pure function
        of (node, f) given the manager's order-independent samples, so
        only cache misses are estimated (batched by default, or via the
        scalar `sample_cf` reference with `scalar=True`); deductions are
        re-resolved from the plan each call.  Returns estimates identical
        to a fresh `execute`/`execute_scalar` on the same plan.

        The plan is resolved from a LOCAL snapshot of this call's
        estimates, never back through `cache`: with a bounded cache
        (`samplecf.EstimateCache`) an insert may evict an entry this
        same plan still needs, and a smaller-than-the-plan cache must
        degrade to recomputation, not KeyError."""
        sampled = [k for k, n in plan.nodes.items()
                   if n.state is State.SAMPLED]
        local: Dict[NodeKey, SizeEstimate] = {}
        missing = []
        for k in sampled:
            est = cache.get((k, plan.f))
            if est is None:
                missing.append(k)
            else:
                local[k] = est
        if missing:
            if scalar:
                for k in missing:
                    local[k] = cache[(k, plan.f)] = sample_cf(
                        manager, IndexDef(k.table, k.cols, k.method),
                        plan.f)
            else:
                if engine is None:
                    engine = EstimationEngine(self.tables, manager)
                assert engine.manager is manager, \
                    "engine.manager must be the manager passed in"
                for k, est in engine.estimate_batch(missing,
                                                    plan.f).items():
                    local[k] = cache[(k, plan.f)] = est
        return self._resolve_plan(plan, local.__getitem__)

    def _resolve_plan(self, plan: Plan, sampled_est
                      ) -> Dict[NodeKey, SizeEstimate]:
        out: Dict[NodeKey, SizeEstimate] = {}

        def resolve(k: NodeKey) -> SizeEstimate:
            if k in out:
                return out[k]
            node = plan.nodes[k]
            table = self.tables[k.table]
            if node.state is State.EXACT:
                est = SizeEstimate(
                    index=IndexDef(k.table, k.cols, k.method),
                    est_bytes=float(node.exact_bytes), method="exact",
                    cost_pages=0.0, cf=0.0)
            elif node.state is State.SAMPLED:
                est = sampled_est(k)
            else:  # DEDUCED
                d = node.chosen
                assert d is not None
                if d.kind == "colset":
                    size = ded.colset_deduce(resolve(d.children[0]).est_bytes)
                else:
                    parts = [(c.cols, resolve(c).est_bytes)
                             for c in d.children]
                    size = ded.deduce(table, k.method, k.cols, parts)
                est = SizeEstimate(
                    index=IndexDef(k.table, k.cols, k.method),
                    est_bytes=size, method=f"deduction:{d.kind}",
                    cost_pages=0.0,
                    cf=size / max(ded.uncompressed_size(table, k.cols), 1.0))
            out[k] = est
            return est

        for t in plan.targets:
            resolve(t)
        # also resolve intermediate sampled nodes (useful to callers)
        for k, n in plan.nodes.items():
            if n.state is not State.NONE:
                resolve(k)
        return out

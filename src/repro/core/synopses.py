"""Filtered samples, join synopses and MV samples (paper Appendix B).

* Filtered sample: apply a partial index's WHERE to the base sample (B.1).
* Join synopsis [2]: sample the fact table once, join the sample against the
  ORIGINAL dimension tables so every FK finds its match (B.2).
* MV sample with aggregation: GROUP BY on the synopsis, keep COUNT(*) as
  frequency statistics, and estimate the MV cardinality with the Adaptive
  Estimator (B.3) — reproduced in benchmarks as Table 1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from . import compression, distinct
from .estimation_engine import batched_sample_cf
from .relation import ColumnDef, IndexDef, Predicate, Table
from .samplecf import SampleManager, SizeEstimate


@dataclasses.dataclass(frozen=True)
class ForeignKey:
    fact_table: str
    fk_col: str
    dim_table: str
    dim_key: str


@dataclasses.dataclass(frozen=True)
class MVDef:
    """SELECT <cols>[, aggs] FROM fact JOIN dims WHERE pred GROUP BY group_by."""
    name: str
    fact_table: str
    joins: Tuple[ForeignKey, ...] = ()
    cols: Tuple[str, ...] = ()            # projected (pre-aggregation) columns
    predicate: Optional[Predicate] = None
    group_by: Tuple[str, ...] = ()        # empty => no aggregation


class Schema:
    def __init__(self, tables: Dict[str, Table],
                 foreign_keys: Sequence[ForeignKey] = ()):
        self.tables = dict(tables)
        self.foreign_keys = tuple(foreign_keys)

    def fks_of(self, fact: str) -> Tuple[ForeignKey, ...]:
        return tuple(fk for fk in self.foreign_keys if fk.fact_table == fact)


def join_sample_with_dims(sample: Table, schema: Schema,
                          joins: Sequence[ForeignKey]) -> Table:
    """Join a fact-table sample with ORIGINAL dimension tables (join synopsis).

    Dimension keys are assumed unique; FK values always match (B.2).  The
    synopsis indexes dimension keys once for fast lookup (B.4).
    """
    cols = list(sample.columns)
    vals = {c.name: sample.values[c.name] for c in sample.columns}
    for fk in joins:
        dim = schema.tables[fk.dim_table]
        keys = dim.values[fk.dim_key]
        order = np.argsort(keys, kind="stable")       # the "index" of B.4
        pos = np.searchsorted(keys[order], vals[fk.fk_col])
        pos = np.clip(pos, 0, keys.size - 1)
        rows = order[pos]
        matched = keys[rows] == vals[fk.fk_col]
        if not bool(np.all(matched)):
            # keep only matching rows (inner join semantics)
            keep = np.nonzero(matched)[0]
            vals = {k: v[keep] for k, v in vals.items()}
            rows = rows[keep]
        for c in dim.columns:
            if c.name == fk.dim_key or c.name in vals:
                continue
            cols.append(c)
            vals[c.name] = dim.values[c.name][rows]
    return Table(f"{sample.name}#syn", cols, vals)


class SynopsisManager:
    """Maintains join synopses + filtered/MV samples on top of SampleManager."""

    def __init__(self, schema: Schema, samples: SampleManager):
        self.schema = schema
        self.samples = samples
        self._synopses: Dict[Tuple[str, float], Table] = {}

    def join_synopsis(self, fact: str, f: float) -> Table:
        key = (fact, round(f, 6))
        if key not in self._synopses:
            base = self.samples.get_sample(fact, f)
            self._synopses[key] = join_sample_with_dims(
                base, self.schema, self.schema.fks_of(fact))
        return self._synopses[key]

    def filtered_sample(self, table: str, pred: Predicate, f: float) -> Table:
        base = self.samples.get_sample(table, f)
        rows = np.nonzero(pred.mask(base))[0]
        return base.take(rows, name=f"{table}#filt")

    # ------------------------------------------------------------------
    # MV sample + cardinality (Algorithm CreateMVSample, B.3)
    # ------------------------------------------------------------------
    def mv_sample(self, mv: MVDef, f: float) -> Tuple[Table, float]:
        """Returns (sample table of the MV, estimated MV row count)."""
        syn = self.join_synopsis(mv.fact_table, f) if mv.joins else \
            self.samples.get_sample(mv.fact_table, f)
        if mv.predicate is not None:
            rows = np.nonzero(mv.predicate.mask(syn))[0]
            syn = syn.take(rows)
        fact = self.schema.tables[mv.fact_table]
        r = syn.nrows
        if not mv.group_by:
            # no aggregation: cardinality scales with the filter factor
            n_est = fact.nrows * (r / max(self.samples.get_sample(
                mv.fact_table, f).nrows, 1))
            cols = [c for c in syn.columns if c.name in mv.cols]
            vals = {c.name: syn.values[c.name] for c in cols}
            return Table(mv.name + "#sample", cols, vals), float(n_est)

        # GROUP BY: build the grouped sample, keep COUNT(*) as `cnt`
        keys = np.stack([syn.values[c] for c in mv.group_by], axis=1)
        uniq, inv, counts = np.unique(keys, axis=0, return_inverse=True,
                                      return_counts=True)
        out_cols = [ColumnDef(c, syn.col_by_name[c].width)
                    for c in mv.group_by]
        out_vals = {c: uniq[:, i] for i, c in enumerate(mv.group_by)}
        out_cols.append(ColumnDef("cnt", 4))
        out_vals["cnt"] = np.minimum(counts, (1 << 31) - 1)
        smv = Table(mv.name + "#sample", out_cols, out_vals)

        # Adaptive Estimator on the sample's frequency statistics
        hashed = inv  # group id per sample row
        n_est = distinct.estimate_group_count(hashed, fact.nrows, "AE")
        return smv, float(n_est)

    def mv_index_size(self, mv: MVDef, idx_cols: Tuple[str, ...],
                      method: Optional[str], f: float) -> SizeEstimate:
        """SampleCF for an index on an MV, scaled by the AE cardinality."""
        smv, n_est = self.mv_sample(mv, f)
        # the MV sample IS the whole "table" here (f=1): batched core with
        # a single (cols, method) spec, then rescale by the AE cardinality
        est = batched_sample_cf(smv, smv, [(idx_cols, method)], f=1.0)[0]
        widths = [smv.col_by_name[c].width for c in idx_cols]
        full = compression.uncompressed_payload_bytes(int(n_est), widths)
        return SizeEstimate(index=est.index, est_bytes=est.cf * full,
                            method="samplecf:mv", cost_pages=est.cost_pages,
                            cf=est.cf)

"""Compression-aware what-if cost model (paper Appendix A).

    CPUCost_update = BaseCPUCost + alpha * #tuples_written
    CPUCost_read   = BaseCPUCost + beta  * #tuples_read * #columns_read

alpha/beta are per-method constants (larger for PAGE-style methods).  Only
columns actually used by the query are decompressed (A.2).  The I/O model is
unchanged — compression helps purely through the smaller (estimated) size.

Cost unit is abstract "milliseconds"; constants are calibrated so sequential
I/O dominates large scans (the regime the paper targets).
"""
from __future__ import annotations

from .compression import METHODS
from .relation import PAGE_BYTES

# elementary constants (ms).  Calibrated to the paper's hardware (App. D.1:
# 10K RPM HDD + dual-core CPU): sequential 8KB page ~0.08ms (100MB/s), random
# page ~5ms (seek+rotate), per-tuple predicate CPU ~50ns.  Large scans are
# I/O-bound — the regime where compression pays — while decompression CPU
# (beta) and compression-on-write CPU (alpha) can flip the trade-off for
# CPU-bound or update-heavy statements, as in the paper's Examples 1-2.
T_IO_SEQ = 0.08         # per sequential page read/write
T_IO_RAND = 5.0         # per random page access (RID lookup)
CPU_ROW = 0.00005       # base CPU per tuple touched
ALPHA_UNIT = 0.0002     # scales Method.alpha  (compress one tuple)
BETA_UNIT = 0.00002     # scales Method.beta   (decompress one column value)
INDEX_MAINT_CPU = 0.0005  # per tuple B-tree maintenance on insert
SEEK_OVERHEAD = 1.0     # root-to-leaf traversal (upper levels mostly cached)


def pages_of(size_bytes: float) -> float:
    return max(size_bytes, 0.0) / PAGE_BYTES


def alpha(method: str) -> float:
    return METHODS[method].alpha * ALPHA_UNIT


def beta(method: str) -> float:
    return METHODS[method].beta * BETA_UNIT


def scan_cost(size_bytes: float, nrows: float, ncols_used: int,
              compression: str | None) -> float:
    """Sequential scan of `size_bytes` touching `nrows` tuples."""
    io = T_IO_SEQ * pages_of(size_bytes)
    cpu = CPU_ROW * nrows
    if compression is not None:
        cpu += beta(compression) * nrows * ncols_used   # A.2
    return io + cpu


def seek_cost(size_bytes: float, nrows_index: float, selectivity: float,
              ncols_used: int, compression: str | None) -> float:
    """Range seek reading a `selectivity` fraction of the index."""
    rows = nrows_index * selectivity
    io = SEEK_OVERHEAD + T_IO_SEQ * pages_of(size_bytes * selectivity)
    cpu = CPU_ROW * rows
    if compression is not None:
        cpu += beta(compression) * rows * ncols_used
    return io + cpu


def rid_lookup_cost(nrows: float, base_size_bytes: float,
                    base_compression: str | None, ncols_used: int) -> float:
    """Random lookups into the base layout for a non-covering index path."""
    npages = pages_of(base_size_bytes)
    touched = min(nrows, npages)  # cap: can't touch more pages than exist
    io = T_IO_RAND * touched
    cpu = CPU_ROW * nrows
    if base_compression is not None:
        cpu += beta(base_compression) * nrows * ncols_used
    return io + cpu


def update_cost(index_size_bytes: float, index_nrows: float,
                rows_written: float, compression: str | None) -> float:
    """Bulk-insert maintenance cost for ONE index (A.1)."""
    if index_nrows <= 0:
        frac_written = 1.0
    else:
        frac_written = min(rows_written / index_nrows, 1.0)
    io = T_IO_SEQ * pages_of(index_size_bytes * frac_written)
    cpu = (CPU_ROW + INDEX_MAINT_CPU) * rows_written
    if compression is not None:
        cpu += alpha(compression) * rows_written     # A.1
    return io + cpu

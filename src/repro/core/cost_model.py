"""Compression-aware what-if cost model (paper Appendix A).

    CPUCost_update = BaseCPUCost + alpha * #tuples_written
    CPUCost_read   = BaseCPUCost + beta  * #tuples_read * #columns_read

alpha/beta are per-method constants (larger for PAGE-style methods).  Only
columns actually used by the query are decompressed (A.2).  The I/O model is
unchanged — compression helps purely through the smaller (estimated) size.

Cost unit is abstract "milliseconds"; constants are calibrated so sequential
I/O dominates large scans (the regime the paper targets).

Every cost function is ufunc-safe: the numeric arguments may be scalars or
NumPy arrays of any broadcastable shape, and the result has the broadcast
shape.  The batched cost engine (repro.core.cost_engine) relies on this to
score an entire candidate pool per greedy step in a handful of vectorized
ops.  `compression` stays a scalar method name (or None); vectorized callers
that mix methods pass precomputed per-element coefficient arrays via
`alpha_coef` / `beta_coef` instead.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from .compression import METHODS
from .relation import PAGE_BYTES

ArrayLike = Union[float, np.ndarray]

# elementary constants (ms).  Calibrated to the paper's hardware (App. D.1:
# 10K RPM HDD + dual-core CPU): sequential 8KB page ~0.08ms (100MB/s), random
# page ~5ms (seek+rotate), per-tuple predicate CPU ~50ns.  Large scans are
# I/O-bound — the regime where compression pays — while decompression CPU
# (beta) and compression-on-write CPU (alpha) can flip the trade-off for
# CPU-bound or update-heavy statements, as in the paper's Examples 1-2.
T_IO_SEQ = 0.08         # per sequential page read/write
T_IO_RAND = 5.0         # per random page access (RID lookup)
CPU_ROW = 0.00005       # base CPU per tuple touched
ALPHA_UNIT = 0.0002     # scales Method.alpha  (compress one tuple)
BETA_UNIT = 0.00002     # scales Method.beta   (decompress one column value)
INDEX_MAINT_CPU = 0.0005  # per tuple B-tree maintenance on insert
SEEK_OVERHEAD = 1.0     # root-to-leaf traversal (upper levels mostly cached)


def pages_of(size_bytes: ArrayLike) -> ArrayLike:
    return np.maximum(size_bytes, 0.0) / PAGE_BYTES


def alpha(method: str) -> float:
    return METHODS[method].alpha * ALPHA_UNIT


def beta(method: str) -> float:
    return METHODS[method].beta * BETA_UNIT


def alpha_coef_of(compression: Optional[str]) -> float:
    """Per-tuple compress-on-write CPU coefficient (0 when uncompressed)."""
    return 0.0 if compression is None else alpha(compression)


def beta_coef_of(compression: Optional[str]) -> float:
    """Per-column-value decompression CPU coefficient (0 when uncompressed)."""
    return 0.0 if compression is None else beta(compression)


def scan_cost(size_bytes: ArrayLike, nrows: ArrayLike, ncols_used: ArrayLike,
              compression: Optional[str] = None, *,
              beta_coef: Optional[ArrayLike] = None) -> ArrayLike:
    """Sequential scan of `size_bytes` touching `nrows` tuples."""
    if beta_coef is None:
        beta_coef = beta_coef_of(compression)
    io = T_IO_SEQ * pages_of(size_bytes)
    cpu = CPU_ROW * nrows + beta_coef * nrows * ncols_used   # A.2
    return io + cpu


def seek_cost(size_bytes: ArrayLike, nrows_index: ArrayLike,
              selectivity: ArrayLike, ncols_used: ArrayLike,
              compression: Optional[str] = None, *,
              beta_coef: Optional[ArrayLike] = None) -> ArrayLike:
    """Range seek reading a `selectivity` fraction of the index."""
    if beta_coef is None:
        beta_coef = beta_coef_of(compression)
    rows = nrows_index * selectivity
    io = SEEK_OVERHEAD + T_IO_SEQ * pages_of(size_bytes * selectivity)
    cpu = CPU_ROW * rows + beta_coef * rows * ncols_used
    return io + cpu


def rid_lookup_cost(nrows: ArrayLike, base_size_bytes: ArrayLike,
                    base_compression: Optional[str] = None,
                    ncols_used: ArrayLike = 1, *,
                    beta_coef: Optional[ArrayLike] = None) -> ArrayLike:
    """Random lookups into the base layout for a non-covering index path."""
    if beta_coef is None:
        beta_coef = beta_coef_of(base_compression)
    npages = pages_of(base_size_bytes)
    touched = np.minimum(nrows, npages)  # cap: can't touch more pages than exist
    io = T_IO_RAND * touched
    cpu = CPU_ROW * nrows + beta_coef * nrows * ncols_used
    return io + cpu


def update_cost(index_size_bytes: ArrayLike, index_nrows: ArrayLike,
                rows_written: ArrayLike,
                compression: Optional[str] = None, *,
                alpha_coef: Optional[ArrayLike] = None) -> ArrayLike:
    """Bulk-insert maintenance cost for ONE index (A.1)."""
    if alpha_coef is None:
        alpha_coef = alpha_coef_of(compression)
    frac_written = np.where(
        np.asarray(index_nrows) <= 0, 1.0,
        np.minimum(rows_written / np.maximum(index_nrows, 1e-300), 1.0))
    io = T_IO_SEQ * pages_of(index_size_bytes * frac_written)
    cpu = (CPU_ROW + INDEX_MAINT_CPU) * rows_written
    cpu = cpu + alpha_coef * rows_written     # A.1
    return io + cpu

"""Candidate selection (paper §6.1): per-query candidates, top-k vs Skyline.

For each query we generate syntactically relevant indexes, produce compressed
variants for each compression method, cost each single-index configuration
with the what-if optimizer, and then select either:

* top-k   — the k lowest-cost configurations (today's DTA behavior), or
* skyline — the full (size, cost) Pareto frontier (the paper's method),
            keeping slow-but-small compressed candidates that top-k prunes.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from .relation import IndexDef, Table
from .whatif import Configuration, SizeProvider, WhatIfOptimizer
from .workload import Query

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from .cost_engine import CostEngine


@dataclasses.dataclass(frozen=True)
class Candidate:
    index: IndexDef
    size: float
    cost: float  # query cost of base + this index


def syntactically_relevant(query: Query, table: Table,
                           include_clustered: bool = True) -> List[IndexDef]:
    """Indexes worth considering for one query (§6.1).

    - single-column index per filter column,
    - the composite filter-column index (most selective first),
    - the covering index (filters + used columns),
    - compressed clustered-layout variants (include_clustered).
    """
    filters = sorted(query.filters, key=lambda p: p.selectivity(table))
    fcols = [p.col for p in filters]
    out: List[IndexDef] = []
    seen = set()

    def add(cols: Tuple[str, ...], clustered: bool = False):
        if not cols or (cols, clustered) in seen:
            return
        seen.add((cols, clustered))
        out.append(IndexDef(query.table, cols, clustered=clustered))

    for c in fcols:
        add((c,))
    if len(fcols) > 1:
        add(tuple(fcols))
    covering = tuple(dict.fromkeys(fcols + list(query.cols_used)))
    add(covering)
    if include_clustered:
        # clustered layout resorted to lead with the filter columns
        all_cols = tuple(c.name for c in table.columns)
        lead = tuple(dict.fromkeys(list(covering) + list(all_cols)))
        add(lead, clustered=True)
    return out


def expand_with_compression(indexes: Sequence[IndexDef],
                            methods: Sequence[str]) -> List[IndexDef]:
    out: List[IndexDef] = []
    for idx in indexes:
        out.append(idx)
        for m in methods:
            out.append(idx.with_compression(m))
    return out


def cost_candidates(query: Query, cands: Sequence[IndexDef],
                    base: Configuration, optimizer: WhatIfOptimizer,
                    sizes: SizeProvider,
                    engine: Optional["CostEngine"] = None,
                    precomputed=None) -> List[Candidate]:
    """Cost each single-index configuration for `query`.

    With `engine` (a repro.core.cost_engine.CostEngine) the whole candidate
    list is scored in one vectorized pass; without it, the scalar what-if
    optimizer is queried per candidate (the correctness reference).
    `precomputed` (an array aligned with `cands`, e.g. the fleet service's
    cross-tenant cost prefetch) short-circuits the engine call; the caller
    owns the contract that it holds exactly the values the engine would
    return.
    """
    if precomputed is not None:
        costs = precomputed
    else:
        costs = (engine.candidate_query_costs(query, base, cands)
                 if engine is not None else None)
    out = []
    for k, idx in enumerate(cands):
        if idx.clustered:
            old = base.clustered(idx.table)
            # clustered replacement "size" = delta vs uncompressed base layout
            size = sizes.size(idx) - (sizes.size(old) if old else 0.0)
        else:
            size = sizes.size(idx)
        if costs is not None:
            cost = float(costs[k])
        elif idx.clustered:
            old = base.clustered(idx.table)
            cfg = base.replace(old, idx) if old else base.add(idx)
            cost = optimizer.statement_cost(query, cfg)
        else:
            cost = optimizer.statement_cost(query, base.add(idx))
        out.append(Candidate(index=idx, size=size, cost=cost))
    return out


def select_topk(cands: Sequence[Candidate], k: int = 2) -> List[Candidate]:
    """DTA's best-per-query selection (lowest cost wins)."""
    return sorted(cands, key=lambda c: (c.cost, c.size))[:k]


def select_skyline(cands: Sequence[Candidate]) -> List[Candidate]:
    """Pareto frontier of (size, cost) — O(n^2) dominance test (§6.1)."""
    out = []
    for c in cands:
        dominated = False
        for other in cands:
            if other is c:
                continue
            if (other.cost <= c.cost and other.size <= c.size
                    and (other.cost < c.cost or other.size < c.size)):
                dominated = True
                break
        if not dominated:
            out.append(c)
    # deterministic order: small to large
    return sorted(out, key=lambda c: (c.size, c.cost))


def merged_candidates(per_query: Dict[str, List[IndexDef]],
                      max_merges: int = 24) -> List[IndexDef]:
    """Index merging [8] (Figure 1): merge pairs of same-table candidates
    sharing a leading key column into one index serving both queries.
    Compressed variants of merged objects are generated by the caller (§6.2
    last paragraph)."""
    flat: List[IndexDef] = []
    seen = set()
    for cands in per_query.values():
        for idx in cands:
            if idx.clustered or idx.compression is not None:
                continue
            if idx.key not in seen:
                seen.add(idx.key)
                flat.append(idx)
    out: List[IndexDef] = []
    oseen = set()
    for i, a in enumerate(flat):
        for b in flat[i + 1:]:
            if a.table != b.table or a.cols[0] != b.cols[0]:
                continue
            if set(a.cols) == set(b.cols):
                continue
            merged_cols = tuple(dict.fromkeys(list(a.cols) + list(b.cols)))
            m = IndexDef(a.table, merged_cols)
            if m.key not in oseen and m.key not in seen:
                oseen.add(m.key)
                out.append(m)
            if len(out) >= max_merges:
                return out
    return out


def skyline_representatives(skyline: Sequence[Candidate],
                            max_points: int) -> List[Candidate]:
    """Cluster the skyline and keep representatives (§6.1 last paragraph)."""
    if len(skyline) <= max_points:
        return list(skyline)
    pts = sorted(skyline, key=lambda c: c.size)
    step = (len(pts) - 1) / (max_points - 1)
    picked = [pts[int(round(i * step))] for i in range(max_points)]
    uniq: Dict[Tuple, Candidate] = {c.index.key: c for c in picked}
    return list(uniq.values())

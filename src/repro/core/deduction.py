"""Size deduction for compressed indexes (paper §4.2).

Three techniques, dispatched on the compression method's order class:

* ColSet  (ORD-IND): same column SET  => same compressed size.
* ColExt  (ORD-IND): size reductions are per-column additive:
      R(I_AB) = R(I_A) + R(I_B);   Size(I_AB^C) = Size(I_AB) - sum R(parts)
* ColExt  (ORD-DEP): additive with a fragmentation penalty.  With
      T(I_X)    tuples per page of index X
      L(I_X, Y) average run length of Y values in X
                = nrows / ndv(prefix of X's key up to and including Y)
      DV(I_X,Y) = ceil(T / L)                      if L > 1
                  |Y| - |Y|*(1 - 1/|Y|)^T          otherwise (dice throw)
      F(I_X, Y) = (T - DV) / T   (fraction of Y replaced by the dictionary)
  the reduction contributed by column Y known from part P is rescaled:
      R_Y(target) = R_Y(P) * F(target, Y) / F(P, Y)

Deductions cost nothing (no sampling, no index build): they only read
optimizer statistics (ndv / row counts).
"""
from __future__ import annotations

import math
from typing import Dict, Sequence, Tuple

import numpy as np

from .compression import METHODS, uncompressed_payload_bytes
from .relation import Table, rows_per_page


def index_nrows(table: Table, predicate=None) -> int:
    if predicate is None:
        return table.nrows
    return int(predicate.mask(table).sum())


def uncompressed_size(table: Table, cols: Sequence[str]) -> float:
    key = ("ded_usize", tuple(cols))
    got = table._stats_cache.get(key)
    if got is None:
        widths = [table.col_by_name[c].width for c in cols]
        got = float(uncompressed_payload_bytes(table.nrows, widths))
        table._stats_cache[key] = got
    return got


def tuples_per_page(table: Table, cols: Sequence[str]) -> int:
    rw = sum(table.col_by_name[c].width for c in cols)
    return rows_per_page(rw)


def colset_deduce(known_size: float) -> float:
    """ColSet: identical column set under ORD-IND => identical size."""
    return known_size


def colext_ordind_deduce(table: Table, target_cols: Tuple[str, ...],
                         parts: Sequence[Tuple[Tuple[str, ...], float]]) -> float:
    """parts: [(part_cols, compressed_size_of_part)].  Parts partition target.

    R(part) = Size(part) - Size^C(part); reductions are additive (ORD-IND).
    """
    s_target = uncompressed_size(table, target_cols)
    r_total = 0.0
    for part_cols, csize in parts:
        r_total += uncompressed_size(table, part_cols) - csize
    return max(s_target - r_total, 0.0)


def _avg_run_length(table: Table, key_prefix: Tuple[str, ...]) -> float:
    """L = nrows / ndv(prefix incl. the column) — §4.2 ("we do not simply
    divide by |B| because A and B might be correlated")."""
    ndv = table.ndv(list(key_prefix))
    return table.nrows / max(ndv, 1)


def _dv_per_page(table: Table, index_cols: Tuple[str, ...], col: str) -> float:
    """DV(I_X, Y): average distinct values of Y per page of index X."""
    t = tuples_per_page(table, index_cols)
    pos = index_cols.index(col)
    prefix = index_cols[: pos + 1]
    L = _avg_run_length(table, prefix)
    if L > 1.0:
        return min(float(t), math.ceil(t / L))
    y = table.ndv([col])
    return y - y * (1.0 - 1.0 / max(y, 1)) ** t


def replaced_fraction(table: Table, index_cols: Tuple[str, ...],
                      col: str) -> float:
    """F(I_X, Y) = (T - DV) / T.  Pure in optimizer stats, so cached."""
    key = ("ded_rf", index_cols, col)
    got = table._stats_cache.get(key)
    if got is None:
        t = tuples_per_page(table, index_cols)
        dv = _dv_per_page(table, index_cols, col)
        got = table._stats_cache[key] = max((t - dv) / t, 0.0)
    return got


def replaced_fraction_batch(table: Table, index_cols: Tuple[str, ...],
                            cols: Sequence[str]) -> np.ndarray:
    """F(I_X, Y) for every Y in `cols` of index X, in one array pass.

    Gathers the per-prefix run lengths (cached table stats) once and
    evaluates the §4.2 DV formula over column vectors; element-for-element
    identical to `replaced_fraction` (same cache, same float ops), it just
    removes the per-column Python dispatch from the ColExt deduction path.
    """
    missing = [c for c in cols
               if ("ded_rf", index_cols, c) not in table._stats_cache]
    if missing:
        t = tuples_per_page(table, index_cols)
        tf = float(t)
        pos = {c: index_cols.index(c) for c in missing}
        L = np.array([_avg_run_length(table, index_cols[:pos[c] + 1])
                      for c in missing])
        long_runs = L > 1.0
        dv = np.minimum(tf, np.ceil(t / np.where(long_runs, L, 1.0)))
        if not long_runs.all():
            # dice-throw branch stays scalar: numpy's pow detects integral
            # exponents and switches to repeated squaring, which is not
            # bit-identical to CPython's libm pow in `_dv_per_page`
            for i in np.nonzero(~long_runs)[0].tolist():
                y = table.ndv([missing[i]])
                dv[i] = y - y * (1.0 - 1.0 / max(y, 1)) ** t
        frac = np.maximum((t - dv) / t, 0.0)
        for c, v in zip(missing, frac.tolist()):
            table._stats_cache[("ded_rf", index_cols, c)] = v
    return np.array([table._stats_cache[("ded_rf", index_cols, c)]
                     for c in cols])


def colext_orddep_deduce(table: Table, target_cols: Tuple[str, ...],
                         parts: Sequence[Tuple[Tuple[str, ...], float]]) -> float:
    """ORD-DEP ColExt with the fragmentation rescaling of §4.2.

    The reduction of each part is apportioned to its columns by width, then
    rescaled by F(target, Y) / F(part, Y).
    """
    s_target = uncompressed_size(table, target_cols)
    r_total = 0.0
    for part_cols, csize in parts:
        r_part = uncompressed_size(table, part_cols) - csize
        if r_part <= 0:
            continue
        widths = {c: table.col_by_name[c].width for c in part_cols}
        wsum = sum(widths.values())
        # both F vectors in one batched stats pass per part
        f_parts = replaced_fraction_batch(
            table, tuple(part_cols), part_cols).tolist()
        f_targets = replaced_fraction_batch(
            table, tuple(target_cols), part_cols).tolist()
        for i, col in enumerate(part_cols):
            r_col = r_part * widths[col] / max(wsum, 1)
            f_part = f_parts[i]
            f_target = f_targets[i]
            if f_part <= 1e-9:
                # part saw no dictionary benefit for this column; assume the
                # target cannot recover one either
                continue
            ratio = min(f_target / f_part, 1.5)  # guard noisy tiny fractions
            r_total += r_col * ratio
    return max(s_target - r_total, 0.0)


def deduce(table: Table, method: str, target_cols: Tuple[str, ...],
           parts: Sequence[Tuple[Tuple[str, ...], float]]) -> float:
    """Dispatch ColExt on the method's order class."""
    if METHODS[method].order_dependent:
        return colext_orddep_deduce(table, target_cols, parts)
    return colext_ordind_deduce(table, target_cols, parts)

"""Durable crash recovery: per-tenant write-ahead log + atomic snapshots.

PR 7 extended the repo's exact-parity contract over the failure surface
of a long-lived deployment — transient faults, evictions, quarantine,
checkpoint/restore — but every checkpoint lived in process memory: a
process death lost every tenant.  `DurableStore` closes that gap with
the two classic pieces of a storage engine's recovery story, held to
the same contract (a recovered tenant's next recommendation is exactly
`==` a fresh `DesignAdvisor` on the recovered workload):

* **Write-ahead log** (`wal/<tenant>.wal`): one append-only file per
  tenant of length-prefixed, CRC32-checksummed, format-versioned
  records.  The fleet journals every admitted `WorkloadDelta` BEFORE
  applying it; a delta that then fails to apply (validation error or an
  injected pre-mutation fault) is compensated with an ABORT record so
  replay can never apply it.  fsync follows a configurable group-commit
  interval (`group_commit=N` syncs every Nth append); `sync()` forces
  the discipline's hand.

* **Atomic snapshot store** (`snap/<tenant>.snap`): a single framed
  manifest record — serialized `SessionSnapshot` bytes (themselves
  magic+version+CRC framed), opaque caller metadata, and the WAL
  sequence number the snapshot covers — written via write-temp +
  `os.replace` rotation, so a crash mid-checkpoint leaves the previous
  snapshot intact.  When the WAL suffix since the last snapshot exceeds
  `compact_after` records the store compacts: new manifest, WAL
  truncated to empty.

* **Adversarial recovery** (`recover()`): per tenant, parse the WAL's
  valid prefix record by record.  Invalid bytes at the physical tail —
  an interrupted append — are a *torn tail*: truncated at the last
  valid record and counted, never an error.  Invalid bytes FOLLOWED by
  a parseable record — silent media corruption inside acknowledged
  history — poison only that tenant: `RecoveredTenant.error` carries a
  `LogCorrupt` and the fleet quarantines the tenant (on its last valid
  prefix) instead of failing the whole recovery.  Replay applies only
  delta records with sequence numbers beyond the manifest's and not
  compensated by an ABORT.

Deterministic disk faults (`FaultInjector` sites, composing with the
PR 7 storm sites without moving a single draw of their schedules —
streams are seeded per site):

* ``disk_write`` — torn append: a prefix of the record reaches the
  file, `FaultError` raised; the next append truncates back to the
  last good offset (and recovery would truncate the same way).
* ``fsync``      — group-commit sync failure: the record is complete
  but durability is unconfirmed, so the store appends an ABORT for it
  and raises; the retry journals a fresh sequence number.
* ``bit_flip``   — one payload bit flipped before the write, silently;
  only recovery's CRC scan can catch it.

The store is deliberately engine-agnostic: it journals pickled deltas
and opaque snapshot/meta bytes.  The fleet wiring — journal-before-
apply, compaction after successful deltas, `AdvisorFleetService.
recover(dir)` rebuilding every tenant — lives in
serve/advisor_service.py; the crash-point harness killing the store at
every record boundary lives in tests/test_durability.py.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import struct
import zlib
from pathlib import Path
from typing import Dict, IO, List, Optional, Tuple
from urllib.parse import quote, unquote

from .faults import FaultError, FaultInjector
from .workload import WorkloadDelta

#: WAL/manifest record framing: magic, format version, record type,
#: payload length, CRC32(payload) — then the payload bytes.
WAL_MAGIC = b"DWAL"
WAL_FORMAT_VERSION = 1
_HEADER = struct.Struct("<4sHBII")

REC_DELTA = 1      # payload: pickle((seq, WorkloadDelta))
REC_ABORT = 2      # payload: pickle(seq) — compensates an unapplied DELTA
REC_MANIFEST = 3   # payload: pickle({tenant_id, snapshot, meta, seq})


class LogCorrupt(RuntimeError):
    """A WAL or manifest record failed validation MID-LOG — bytes that
    were acknowledged as durable no longer parse, with valid records
    after them (so this is media corruption, not a torn tail)."""

    def __init__(self, path, offset: int, detail: str):
        super().__init__(f"{path}: corrupt record at byte {offset}: "
                         f"{detail}")
        self.path = str(path)
        self.offset = offset
        self.detail = detail


def frame_record(rtype: int, payload: bytes) -> bytes:
    """Wrap a payload in the length-prefixed, checksummed record header."""
    return _HEADER.pack(WAL_MAGIC, WAL_FORMAT_VERSION, rtype,
                        len(payload), zlib.crc32(payload)) + payload


def _try_parse(data: bytes, off: int
               ) -> Optional[Tuple[int, bytes, int]]:
    """Parse one record at `off`; None when the bytes there are not a
    complete, checksum-valid record of this format version."""
    if len(data) - off < _HEADER.size:
        return None
    magic, version, rtype, length, crc = _HEADER.unpack_from(data, off)
    if magic != WAL_MAGIC or version != WAL_FORMAT_VERSION:
        return None
    end = off + _HEADER.size + length
    if end > len(data):
        return None
    payload = bytes(data[off + _HEADER.size:end])
    if zlib.crc32(payload) != crc:
        return None
    return rtype, payload, end


@dataclasses.dataclass
class WalScan:
    """Result of scanning a log: the valid record prefix, where it ends,
    and how the remainder (if any) failed."""
    records: List[Tuple[int, bytes]]
    good_end: int                 # byte offset just past the last valid record
    torn_tail: bool               # trailing bytes are an interrupted write
    corrupt_at: Optional[int]     # mid-log corruption offset (quarantine)


def scan_records(data: bytes) -> WalScan:
    """Walk the log record by record.  At the first invalid byte run,
    decide torn tail vs mid-log corruption by looking for ANY parseable
    record later in the file: the framing magic lets the scan resync,
    so a valid record after the damage proves the damage sits inside
    acknowledged history (corruption), while damage with nothing valid
    after it is the interrupted tail of the final append (torn)."""
    records: List[Tuple[int, bytes]] = []
    off = 0
    while off < len(data):
        got = _try_parse(data, off)
        if got is not None:
            rtype, payload, off2 = got
            records.append((rtype, payload))
            off = off2
            continue
        probe = data.find(WAL_MAGIC, off + 1)
        while probe != -1:
            if _try_parse(data, probe) is not None:
                return WalScan(records, off, False, off)
            probe = data.find(WAL_MAGIC, probe + 1)
        return WalScan(records, off, True, None)
    return WalScan(records, off, False, None)


def _flip_bit(record: bytes, n: int) -> bytes:
    """Deterministic payload bit flip for the `bit_flip` fault site:
    position derived purely from the site's check index `n`, so the
    corruption schedule is as reproducible as the fire schedule."""
    body = bytearray(record)
    payload_len = len(record) - _HEADER.size
    pos = _HEADER.size + (n * 131) % max(1, payload_len)
    body[pos] ^= 1 << (n % 8)
    return bytes(body)


@dataclasses.dataclass
class RecoveredTenant:
    """One tenant's recovery outcome: the latest manifest's snapshot
    bytes + caller metadata, the replayable WAL suffix, and (for
    mid-log corruption) the error that should quarantine the tenant.
    `snapshot_bytes`/`deltas` always describe the last VALID state —
    even a corrupt tenant keeps its valid prefix so readmission has
    something to restore."""
    tenant_id: str
    snapshot_bytes: Optional[bytes]
    meta: object
    deltas: List[WorkloadDelta]
    last_seq: int
    wal_records: int
    torn_tail: bool
    error: Optional[BaseException]


class DurableStore:
    """Per-tenant WAL + atomic snapshot store under one directory.

    Usage (the fleet service drives this; see AdvisorFleetService)::

        store = DurableStore(dir, group_commit=4, compact_after=64)
        store.register("t0", snapshot_bytes, meta=budget)
        seq = store.log_delta("t0", delta)     # journal BEFORE applying
        ...apply fails -> store.log_abort("t0", seq)
        store.maybe_compact("t0", lambda: fresh_snapshot_bytes)

        recovered = DurableStore(dir).recover()   # after process death
    """

    def __init__(self, root, group_commit: int = 1,
                 compact_after: Optional[int] = 64,
                 use_fsync: bool = True,
                 faults: Optional[FaultInjector] = None):
        self.root = Path(root)
        (self.root / "wal").mkdir(parents=True, exist_ok=True)
        (self.root / "snap").mkdir(parents=True, exist_ok=True)
        if group_commit < 1:
            raise ValueError("group_commit must be >= 1")
        if compact_after is not None and compact_after < 1:
            raise ValueError("compact_after must be >= 1 or None")
        self.group_commit = int(group_commit)
        self.compact_after = compact_after
        self.use_fsync = use_fsync
        self.faults = faults
        # per-tenant live state
        self._files: Dict[str, IO[bytes]] = {}
        self._seq: Dict[str, int] = {}          # last assigned delta seq
        self._end: Dict[str, int] = {}          # logical good end offset
        self._unsynced: Dict[str, int] = {}     # appends since last fsync
        self._since_compact: Dict[str, int] = {}
        # counters (surfaced through the fleet's stats())
        self.wal_appends = 0
        self.wal_aborts = 0
        self.fsyncs = 0
        self.compactions = 0
        self.recoveries = 0
        self.torn_tail_truncations = 0
        self.bit_flips_injected = 0
        self.short_writes_injected = 0

    # ------------------------------------------------------------------
    # Paths / files
    # ------------------------------------------------------------------
    def _wal_path(self, tenant_id: str) -> Path:
        return self.root / "wal" / (quote(tenant_id, safe="") + ".wal")

    def _snap_path(self, tenant_id: str) -> Path:
        return self.root / "snap" / (quote(tenant_id, safe="") + ".snap")

    def _wal_file(self, tenant_id: str) -> IO[bytes]:
        f = self._files.get(tenant_id)
        if f is None or f.closed:
            p = self._wal_path(tenant_id)
            f = open(p, "r+b" if p.exists() else "w+b")
            self._files[tenant_id] = f
        return f

    def _seek_end(self, tenant_id: str, f: IO[bytes]) -> None:
        """Position at the logical end, truncating any torn bytes a
        short write left past it."""
        end = self._end[tenant_id]
        f.seek(0, os.SEEK_END)
        if f.tell() > end:
            f.truncate(end)
        f.seek(end)

    def _fsync_file(self, f: IO[bytes]) -> None:
        f.flush()
        if self.use_fsync:
            os.fsync(f.fileno())
        self.fsyncs += 1

    def _sync_dir(self, path: Path) -> None:
        if not self.use_fsync:
            return
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:          # pragma: no cover - platform-dependent
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _known(self, tenant_id: str) -> None:
        if tenant_id not in self._seq:
            raise KeyError(f"tenant {tenant_id!r} is not registered with "
                           "this store (register() or recover() first)")

    # ------------------------------------------------------------------
    # Write path
    # ------------------------------------------------------------------
    def register(self, tenant_id: str, snapshot_bytes: bytes,
                 meta: object = None) -> None:
        """Admit a tenant: write its initial manifest (seq 0) and reset
        its WAL.  Re-registering an already-known tenant is an error —
        recovery owns re-attachment."""
        if tenant_id in self._seq:
            raise ValueError(f"tenant {tenant_id!r} already registered "
                             "in this store")
        self._seq[tenant_id] = 0
        self._end[tenant_id] = 0
        self._unsynced[tenant_id] = 0
        self._since_compact[tenant_id] = 0
        self._write_manifest(tenant_id, snapshot_bytes, meta, seq=0)
        f = self._wal_file(tenant_id)
        f.seek(0)
        f.truncate()
        self._fsync_file(f)

    def _write_manifest(self, tenant_id: str, snapshot_bytes: bytes,
                        meta: object, seq: int) -> None:
        """Atomic snapshot rotation: frame, write-temp, fsync, rename.
        A crash at any point leaves either the old or the new manifest
        fully intact — never a mix."""
        payload = pickle.dumps({"tenant_id": tenant_id,
                                "snapshot": bytes(snapshot_bytes),
                                "meta": meta, "seq": int(seq)})
        path = self._snap_path(tenant_id)
        tmp = path.parent / (path.name + ".tmp")
        with open(tmp, "wb") as f:
            f.write(frame_record(REC_MANIFEST, payload))
            self._fsync_file(f)
        os.replace(tmp, path)
        self._sync_dir(path.parent)

    def log_delta(self, tenant_id: str, delta: WorkloadDelta) -> int:
        """Append one admitted delta to the tenant's WAL and return its
        sequence number.  MUST be called before the delta is applied;
        on any failure here the delta has not reached the session, and
        the WAL is left replay-consistent (short writes roll back the
        logical end; an unconfirmed fsync is compensated with an ABORT
        before the error propagates)."""
        self._known(tenant_id)
        seq = self._seq[tenant_id] + 1
        record = frame_record(REC_DELTA, pickle.dumps((seq, delta)))
        if self.faults is not None and self.faults.fires("bit_flip"):
            record = _flip_bit(record, self.faults.checks["bit_flip"] - 1)
            self.bit_flips_injected += 1
        f = self._wal_file(tenant_id)
        self._seek_end(tenant_id, f)
        if self.faults is not None and self.faults.fires("disk_write"):
            # torn append: a strict prefix reaches the file; the logical
            # end stays put, so the next append truncates the garbage
            f.write(record[:_HEADER.size
                           + (len(record) - _HEADER.size) // 2])
            f.flush()
            self.short_writes_injected += 1
            raise FaultError(
                "disk_write", self.faults.checks["disk_write"] - 1,
                f"short write of delta seq {seq} for tenant "
                f"{tenant_id!r}")
        f.write(record)
        f.flush()
        self._end[tenant_id] = f.tell()
        self._seq[tenant_id] = seq
        self.wal_appends += 1
        self._since_compact[tenant_id] += 1
        self._unsynced[tenant_id] += 1
        if self._unsynced[tenant_id] >= self.group_commit:
            try:
                self._wal_sync(tenant_id, f)
            except FaultError:
                # durability of the record is unconfirmed: compensate it
                # so a crash-now replay and the caller's retry (which
                # re-journals under a fresh seq) can never double-apply
                self._append_plain(tenant_id, f,
                                   frame_record(REC_ABORT,
                                                pickle.dumps(seq)))
                self.wal_aborts += 1
                raise
        return seq

    def _wal_sync(self, tenant_id: str, f: IO[bytes]) -> None:
        if self.faults is not None:
            self.faults.check("fsync", f"wal group-commit for "
                              f"{tenant_id!r}")
        self._fsync_file(f)
        self._unsynced[tenant_id] = 0

    def _append_plain(self, tenant_id: str, f: IO[bytes],
                      record: bytes) -> None:
        """Append without fault sites (compensation records must land)."""
        self._seek_end(tenant_id, f)
        f.write(record)
        f.flush()
        self._end[tenant_id] = f.tell()
        self._unsynced[tenant_id] += 1

    def log_abort(self, tenant_id: str, seq: int) -> None:
        """Compensate a journaled delta that was never applied (the
        apply raised after `log_delta` succeeded): replay skips the
        aborted sequence number."""
        self._known(tenant_id)
        self._append_plain(tenant_id, self._wal_file(tenant_id),
                           frame_record(REC_ABORT, pickle.dumps(int(seq))))
        self.wal_aborts += 1

    def checkpoint(self, tenant_id: str, snapshot_bytes: bytes,
                   meta: object = None) -> None:
        """Compaction: rotate a manifest covering everything journaled
        so far, then truncate the WAL to empty.  Ordering makes the
        crash windows safe — manifest-then-truncate means a crash in
        between replays deltas the manifest already covers, and the
        per-record sequence numbers make that replay a no-op."""
        self._known(tenant_id)
        self._write_manifest(tenant_id, snapshot_bytes, meta,
                             seq=self._seq[tenant_id])
        f = self._wal_file(tenant_id)
        f.seek(0)
        f.truncate()
        self._fsync_file(f)
        self._end[tenant_id] = 0
        self._unsynced[tenant_id] = 0
        self._since_compact[tenant_id] = 0
        self.compactions += 1

    def maybe_compact(self, tenant_id: str, snapshot_bytes_fn,
                      meta: object = None) -> bool:
        """Compact when the WAL suffix since the last snapshot exceeds
        the threshold.  `snapshot_bytes_fn` is called only when
        compaction actually runs (serializing a snapshot is the
        expensive part)."""
        self._known(tenant_id)
        if self.compact_after is None or \
                self._since_compact[tenant_id] < self.compact_after:
            return False
        self.checkpoint(tenant_id, snapshot_bytes_fn(), meta)
        return True

    def sync(self, tenant_id: Optional[str] = None) -> None:
        """Force the group-commit hand: fsync one tenant's WAL (or all)."""
        tids = [tenant_id] if tenant_id is not None else list(self._files)
        for tid in tids:
            self._known(tid)
            if self._unsynced.get(tid, 0) > 0:
                self._wal_sync(tid, self._wal_file(tid))

    def close(self) -> None:
        """Flush + fsync + close every WAL handle (no fault sites: close
        is the orderly-shutdown path)."""
        for tid, f in list(self._files.items()):
            if not f.closed:
                if self._unsynced.get(tid, 0) > 0:
                    self._fsync_file(f)
                    self._unsynced[tid] = 0
                f.close()
        self._files.clear()

    def __enter__(self) -> "DurableStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # Recovery
    # ------------------------------------------------------------------
    def recover(self) -> Dict[str, RecoveredTenant]:
        """Scan the directory and rebuild every tenant's durable state:
        latest valid manifest + replayable WAL suffix.  Torn tails are
        physically truncated at the last valid record (counted); mid-log
        corruption marks only that tenant (`RecoveredTenant.error`).
        The store's in-memory state is primed so journaling can continue
        through the same instance after recovery."""
        out: Dict[str, RecoveredTenant] = {}
        for path in sorted((self.root / "snap").glob("*.snap")):
            rt = self._recover_tenant(path)
            out[rt.tenant_id] = rt
            self.recoveries += 1
        return out

    def _recover_tenant(self, snap_path: Path) -> RecoveredTenant:
        tenant_id = unquote(snap_path.stem)
        error: Optional[BaseException] = None
        snapshot_bytes: Optional[bytes] = None
        meta: object = None
        manifest_seq = 0
        scan = scan_records(snap_path.read_bytes())
        manifest = next((p for rtype, p in scan.records
                         if rtype == REC_MANIFEST), None)
        if manifest is None:
            error = LogCorrupt(snap_path, scan.corrupt_at or scan.good_end,
                               "no valid manifest record")
        else:
            try:
                m = pickle.loads(manifest)
                tenant_id = m["tenant_id"]
                snapshot_bytes = m["snapshot"]
                meta = m["meta"]
                manifest_seq = int(m["seq"])
            except Exception as e:
                error = LogCorrupt(snap_path, 0,
                                   f"manifest unreadable: {e!r}")

        wal_path = self._wal_path(tenant_id)
        deltas: List[WorkloadDelta] = []
        last_seq = manifest_seq
        wal_records = 0
        torn = False
        wscan = scan_records(wal_path.read_bytes()
                             if wal_path.exists() else b"")
        wal_records = len(wscan.records)
        if wscan.corrupt_at is not None and error is None:
            error = LogCorrupt(wal_path, wscan.corrupt_at,
                               "checksum mismatch inside acknowledged "
                               "history (valid records follow)")
        if wscan.torn_tail:
            torn = True
            with open(wal_path, "r+b") as f:
                f.truncate(wscan.good_end)
                self._fsync_file(f)
            self.torn_tail_truncations += 1
        try:
            aborted = {pickle.loads(p) for rtype, p in wscan.records
                       if rtype == REC_ABORT}
            for rtype, payload in wscan.records:
                if rtype != REC_DELTA:
                    continue
                seq, delta = pickle.loads(payload)
                last_seq = max(last_seq, int(seq))
                if seq <= manifest_seq or seq in aborted:
                    continue
                deltas.append(delta)
        except Exception as e:      # CRC-valid but unreadable payload
            if error is None:
                error = LogCorrupt(wal_path, wscan.good_end,
                                   f"record payload unreadable: {e!r}")
            deltas = []

        # prime live state so this instance can keep journaling
        self._seq[tenant_id] = last_seq
        self._end[tenant_id] = wscan.good_end
        self._unsynced[tenant_id] = 0
        self._since_compact[tenant_id] = wal_records
        return RecoveredTenant(
            tenant_id=tenant_id, snapshot_bytes=snapshot_bytes, meta=meta,
            deltas=deltas, last_seq=last_seq, wal_records=wal_records,
            torn_tail=torn, error=error)

    # ------------------------------------------------------------------
    def wal_record_boundaries(self, tenant_id: str) -> List[int]:
        """Byte offsets of every record boundary in the tenant's WAL
        (including 0 and the end) — the crash-point harness's kill
        sites."""
        data = self._wal_path(tenant_id).read_bytes() \
            if self._wal_path(tenant_id).exists() else b""
        bounds = [0]
        off = 0
        while True:
            got = _try_parse(data, off)
            if got is None:
                break
            off = got[2]
            bounds.append(off)
        return bounds

    def stats(self) -> Dict[str, int]:
        return {
            "wal_appends": self.wal_appends,
            "wal_aborts": self.wal_aborts,
            "fsyncs": self.fsyncs,
            "compactions": self.compactions,
            "recoveries": self.recoveries,
            "torn_tail_truncations": self.torn_tail_truncations,
            "bit_flips_injected": self.bit_flips_injected,
            "short_writes_injected": self.short_writes_injected,
        }

"""Stochastic error model for size estimation (paper §5.1 + Appendix C).

Every estimator's result, divided by the true size, is a random variable X
(X=1 is perfect).  We track (E[X], Std[X]) per estimate:

* SampleCF errors follow the c*ln(f) fits of Table 2.
* Deduction errors follow the linear-in-a fits of Table 3 (a = number of
  indexes extrapolated from).
* Deduced estimates compose as products of RVs; the variance of a product of
  independent RVs is Goodman's formula [9]:
      V(prod X_i) = prod(V_i + E_i^2) - prod(E_i^2).
* The accuracy constraint holds if P(1/(1+e) <= X <= 1+e) >= q under a
  normal approximation (App. C observed near-normal error distributions).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Tuple

import numpy as np

from .compression import METHODS, ORD_DEP


@dataclasses.dataclass(frozen=True)
class ErrorRV:
    mean: float  # E[X]
    std: float   # Std[X]

    @property
    def var(self) -> float:
        return self.std * self.std


EXACT = ErrorRV(1.0, 0.0)

# Appendix-C style fits (bias/stddev = c * (-ln f)); NS bias is ~0
# ("unbiased", [11]).  The ORD-IND constants match the paper's Table 2.
# The ORD-DEP constants are RE-FIT on our substrate (benchmarks/fig9): our
# tables are ~100x smaller than TPC-H SF1, so a sample of fraction f shrinks
# value run lengths below 1 and local-dictionary sizes are overestimated much
# more than in the paper (bias ~0.08*(-ln f) raw).  The framework only needs
# errors to be *characterizable* (App. C last paragraph), so we carry our own
# constants — and additionally BIAS-CORRECT the ORD-DEP estimate by the
# fitted E[X] (a beyond-paper extension; see EXPERIMENTS.md).
_SAMPLECF_FITS = {
    "ORD-IND": {"bias": 0.0, "std": 0.0062},
    "ORD-DEP": {"bias": 0.08, "std": 0.055},
}

# Table 3 fits for deductions. a = number of extrapolated indexes.
_COLSET = ErrorRV(1.0, 0.0003)
_COLEXT = {
    "ORD-IND": {"bias": +0.01, "std": 0.002},
    "ORD-DEP": {"bias": -0.03, "std": 0.01},
}


@functools.lru_cache(maxsize=None)
def samplecf_bias(method: str, f: float) -> float:
    """Fitted E[X] of a raw SampleCF estimate (used for bias correction)."""
    fit = _SAMPLECF_FITS[METHODS[method].kind]
    lf = -math.log(max(min(f, 1.0), 1e-9))
    return 1.0 + fit["bias"] * lf


@functools.lru_cache(maxsize=None)
def samplecf_error(method: str, f: float, corrected: bool = True) -> ErrorRV:
    """Error RV of SampleCF.  With `corrected` (the default), the estimate is
    divided by the fitted E[X], leaving mean 1 and a shrunk std."""
    kind = METHODS[method].kind
    fit = _SAMPLECF_FITS[kind]
    lf = -math.log(max(min(f, 1.0), 1e-9))  # -ln f  >= 0
    mean = 1.0 + fit["bias"] * lf
    std = fit["std"] * lf
    if corrected:
        return ErrorRV(1.0, std / mean)
    return ErrorRV(mean, std)


def colset_error() -> ErrorRV:
    return _COLSET


@functools.lru_cache(maxsize=None)
def colext_error(method: str, a: int) -> ErrorRV:
    kind = METHODS[method].kind
    fit = _COLEXT[kind]
    return ErrorRV(1.0 + fit["bias"] * a, fit["std"] * a)


def compose(rvs: Iterable[ErrorRV]) -> ErrorRV:
    """Product of independent RVs: E = prod E_i; V per Goodman [9]."""
    e_prod = 1.0
    v_term = 1.0
    e2_term = 1.0
    for rv in rvs:
        e_prod *= rv.mean
        v_term *= rv.var + rv.mean * rv.mean
        e2_term *= rv.mean * rv.mean
    var = max(v_term - e2_term, 0.0)
    return ErrorRV(e_prod, math.sqrt(var))


def goodman_fold(means: np.ndarray, stds: np.ndarray, axis: int = -1
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The raw Goodman accumulators (E-product, V-term, E^2-term) along
    `axis`, bit-identical to folding `compose` over the factors in axis
    order: `np.multiply.reduce` is a strict sequential left-fold (numpy
    pairwise blocking applies to additive reductions only), so every
    float op matches the scalar loop exactly.  A factor of (1, 0) is the
    exact multiplicative identity, which is what makes EXACT-padding
    ragged candidate stacks safe — and the fold can be *continued* with
    further factors (the planner engine appends the deduction-error term
    this way) without losing bit-parity, which `compose_batch`'s rounded
    std cannot do.
    """
    means = np.asarray(means, dtype=np.float64)
    stds = np.asarray(stds, dtype=np.float64)
    msq = means * means
    e_prod = np.multiply.reduce(means, axis=axis)
    v_term = np.multiply.reduce(stds * stds + msq, axis=axis)
    e2_term = np.multiply.reduce(msq, axis=axis)
    return e_prod, v_term, e2_term


def compose_batch(means: np.ndarray, stds: np.ndarray, axis: int = -1
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """`compose` over stacks: Goodman's formula along `axis`, bit-identical
    to folding the scalar `compose` over the factors in axis order."""
    e_prod, v_term, e2_term = goodman_fold(means, stds, axis)
    var = np.maximum(v_term - e2_term, 0.0)
    return e_prod, np.sqrt(var)


def _phi(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


_SQRT2 = math.sqrt(2.0)
# np.frompyfunc(math.erf) rather than scipy's erf: the batched planner's
# decisions must be bit-identical to the scalar reference, and only calling
# the SAME libm erf guarantees that.  The per-element call overhead is paid
# only on the (mask-compressed) candidate entries the scalar path would
# score anyway.
_ERF_VEC = np.frompyfunc(math.erf, 1, 1)


def _erf_exact(x: np.ndarray) -> np.ndarray:
    return _ERF_VEC(x).astype(np.float64)


def prob_within_batch(means: np.ndarray, stds: np.ndarray, e: float,
                      erf=None) -> np.ndarray:
    """Vectorized `prob_within` over (mean, std) stacks of any shape.

    Same deterministic branch (std <= 1e-12 -> indicator) and the same
    `_phi` evaluation order as the scalar, so results are bit-identical
    with the default erf.  `erf` may be swapped for an accelerator-backed
    implementation (the planner engine's jax scoring backend) at the price
    of bit-parity.
    """
    means = np.asarray(means, dtype=np.float64)
    stds = np.asarray(stds, dtype=np.float64)
    lo, hi = 1.0 / (1.0 + e), 1.0 + e
    out = np.where((lo <= means) & (means <= hi), 1.0, 0.0)
    big = stds > 1e-12
    if np.any(big):
        m = means[big]
        s = stds[big]
        erf_fn = _erf_exact if erf is None else erf
        phi_hi = 0.5 * (1.0 + np.asarray(erf_fn((hi - m) / s / _SQRT2),
                                         dtype=np.float64))
        phi_lo = 0.5 * (1.0 + np.asarray(erf_fn((lo - m) / s / _SQRT2),
                                         dtype=np.float64))
        out[big] = phi_hi - phi_lo
    return out


@functools.lru_cache(maxsize=65536)
def prob_within(rv: ErrorRV, e: float) -> float:
    """P(1/(1+e) <= X <= 1+e) under N(mean, std^2)."""
    lo, hi = 1.0 / (1.0 + e), 1.0 + e
    if rv.std <= 1e-12:
        return 1.0 if lo <= rv.mean <= hi else 0.0
    return _phi((hi - rv.mean) / rv.std) - _phi((lo - rv.mean) / rv.std)


def satisfies(rv: ErrorRV, e: float, q: float) -> bool:
    return prob_within(rv, e) >= q

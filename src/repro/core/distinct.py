"""Distinct-value estimation from sample frequency statistics (App. B.3).

Implements the Adaptive Estimator (AE) of Charikar et al. [6] plus the two
baselines the paper compares against in Table 1:

  * Optimizer  — per-column NDV stats with an independence assumption.
  * Multiply   — scale sample distinct count by 1/f.
  * AE         — frequency-statistics-based estimator (paper reports 6% err).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def frequency_stats(sample_keys: np.ndarray) -> Dict[int, int]:
    """f_k = number of distinct values appearing exactly k times in the sample.

    sample_keys: 1-D array of group identifiers (pre-hashed combos are fine).
    """
    _, counts = np.unique(sample_keys, return_counts=True)
    ks, fk = np.unique(counts, return_counts=True)
    return {int(k): int(v) for k, v in zip(ks, fk)}


def estimate_multiply(d_sample: int, f: float) -> float:
    """Baseline: scale the sample distinct count by the sampling ratio."""
    return d_sample / max(f, 1e-12)


def estimate_optimizer(per_col_ndv: Sequence[int], n_rows: int) -> float:
    """Baseline: single-column stats + independence assumption, capped by n."""
    prod = 1.0
    for d in per_col_ndv:
        prod *= float(d)
    return min(prod, float(n_rows))


def adaptive_estimator(freq: Dict[int, int], d: int, r: int, n: int) -> float:
    """Adaptive Estimator [6] (the "AE" of Table 1).

    freq: f_k frequency statistics from the sample
    d:    distinct values in the sample
    r:    sample size (rows)
    n:    table size (rows)

    Model (Charikar et al. [6]): values seen once or twice are "rare" and
    share a common true frequency c, estimated from the f1/f2 ratio under
    Bernoulli(p) sampling:

        E[f1]/E[f2] = 2(1-p) / ((c-1) p)   =>   c = 1 + 2(1-p) f2 / (p f1)

    A rare value goes entirely unseen with probability (1-p)^c, so the
    observed rare distinct count f1+f2 is inflated by 1/(1-(1-p)^c); values
    seen >= 3 times are assumed fully represented.
    """
    if r <= 0 or d <= 0:
        return 0.0
    if r >= n:
        return float(d)
    p = r / n
    f1 = freq.get(1, 0)
    f2 = freq.get(2, 0)
    if f1 == 0:
        return float(d)
    d_rare = f1 + f2
    d_high = d - d_rare
    if f2 == 0:
        # all singletons: no duplication evidence => scale like Multiply
        return float(min(d_high + f1 / p, n))
    c = 1.0 + 2.0 * (1.0 - p) * f2 / (p * f1)
    p_seen = 1.0 - (1.0 - p) ** c
    est = d_high + d_rare / max(p_seen, p)
    return float(min(est, float(n)))


def ae_ndv(col: np.ndarray, n_full: int) -> float:
    """Full-table NDV of one column from a sample, via the Adaptive
    Estimator.  Shared by the scalar and batched GDICT SampleCF paths, so
    both produce bit-identical estimates."""
    r = int(col.shape[0])
    _, counts = np.unique(col, return_counts=True)
    d = int(counts.size)
    ks, fk = np.unique(counts, return_counts=True)
    freq = {int(k): int(v) for k, v in zip(ks, fk)}
    return adaptive_estimator(freq, d, r, n_full)


def gdict_estimated_col_bytes(col: np.ndarray, width: int,
                              n_full: int) -> float:
    """Estimated FULL-index GDICT payload bytes of one column.

    GDICT is the known exception to linear CF scaling: a small sample's
    dictionary is nearly all-distinct, so scaling the sample's compressed
    fraction overestimates the full dictionary (NDV does not scale with
    the sample).  Instead, estimate the full-table NDV with the App. B
    Adaptive Estimator and price the dictionary + pointers at full
    cardinality directly.
    """
    ndv = ae_ndv(col, n_full)
    ptr = 1 if ndv <= 256 else (2 if ndv <= 65536 else 3)
    return ndv * width + n_full * ptr


def estimate_group_count(sample_keys: np.ndarray, n_rows: int,
                         method: str = "AE") -> float:
    """Estimate #groups of a GROUP-BY over the full table from a sample."""
    r = int(sample_keys.shape[0])
    d = int(np.unique(sample_keys).size)
    if method == "multiply":
        return estimate_multiply(d, r / max(n_rows, 1))
    if method == "AE":
        return adaptive_estimator(frequency_stats(sample_keys), d, r, n_rows)
    raise ValueError(method)

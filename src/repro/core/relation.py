"""Columnar relation substrate for the faithful paper reproduction.

The paper operates on tables, (composite, ordered) indexes, and a page model.
We mirror that with integer-valued NumPy columns (strings/dates are encoded as
ints; a column carries a logical byte *width* used by every compression method
and by the page model).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

PAGE_BYTES = 8192
# Per-row bookkeeping overhead (slot array entry + record header), as in
# SQL Server's page layout. Kept small and constant.
ROW_OVERHEAD = 4


@dataclasses.dataclass(frozen=True)
class ColumnDef:
    name: str
    width: int  # logical fixed byte width (1..8)

    def __post_init__(self):
        if not (1 <= self.width <= 8):
            raise ValueError(f"column width must be in [1,8], got {self.width}")


class Table:
    """An in-memory columnar table.

    values[c] is an int64 array; each column has a fixed logical byte width.
    """

    def __init__(self, name: str, columns: Sequence[ColumnDef],
                 values: Mapping[str, np.ndarray]):
        self.name = name
        self.columns: Tuple[ColumnDef, ...] = tuple(columns)
        self.col_by_name = {c.name: c for c in self.columns}
        if set(values) != {c.name for c in self.columns}:
            raise ValueError("values keys must match column defs")
        n = None
        self.values = {}
        for c in self.columns:
            v = np.asarray(values[c.name], dtype=np.int64)
            if n is None:
                n = v.shape[0]
            elif v.shape[0] != n:
                raise ValueError("ragged columns")
            maxv = int(v.max(initial=0))
            minv = int(v.min(initial=0))
            if minv < 0:
                raise ValueError(f"column {c.name}: negative values unsupported")
            if maxv >= (1 << (8 * c.width)):
                raise ValueError(f"column {c.name}: value exceeds width {c.width}")
            self.values[c.name] = v
        self.nrows = int(n or 0)
        self._stats_cache: dict = {}

    # ---- statistics the "query optimizer" maintains (paper §2.2) ----
    def ndv(self, cols: Sequence[str]) -> int:
        """Number of distinct value combinations of `cols` (cached)."""
        key = ("ndv", tuple(cols))
        if key not in self._stats_cache:
            if len(cols) == 1:
                n = int(np.unique(self.values[cols[0]]).size)
            else:
                stacked = np.stack([self.values[c] for c in cols], axis=1)
                n = int(np.unique(stacked, axis=0).shape[0])
            self._stats_cache[key] = n
        return self._stats_cache[key]

    def minmax(self, col: str) -> Tuple[int, int]:
        key = ("minmax", col)
        if key not in self._stats_cache:
            v = self.values[col]
            self._stats_cache[key] = (int(v.min()), int(v.max()))
        return self._stats_cache[key]

    def width_of(self, cols: Sequence[str]) -> int:
        return sum(self.col_by_name[c].width for c in cols)

    def take(self, rows: np.ndarray, name: Optional[str] = None) -> "Table":
        vals = {c.name: self.values[c.name][rows] for c in self.columns}
        return Table(name or f"{self.name}#sample", self.columns, vals)


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Range predicate lo <= col <= hi (equality when lo == hi)."""
    col: str
    lo: int
    hi: int

    def mask(self, table: Table) -> np.ndarray:
        v = table.values[self.col]
        return (v >= self.lo) & (v <= self.hi)

    def selectivity(self, table: Table) -> float:
        """Optimizer-style estimate from min/max stats (uniform assumption)."""
        mn, mx = table.minmax(self.col)
        if mx <= mn:
            return 1.0
        frac = (min(self.hi, mx) - max(self.lo, mn) + 1) / (mx - mn + 1)
        return float(min(1.0, max(0.0, frac)))


@dataclasses.dataclass(frozen=True)
class IndexDef:
    """A (possibly partial) ordered composite index.

    `cols` is the full ordered column list stored in the index (key columns
    first).  `compression` is None (uncompressed) or a method name registered
    in repro.core.compression.  `clustered` marks the table's primary layout.
    """
    table: str
    cols: Tuple[str, ...]
    compression: Optional[str] = None
    clustered: bool = False
    predicate: Optional[Predicate] = None  # partial index

    @property
    def key(self) -> Tuple:
        return (self.table, self.cols, self.compression, self.clustered,
                self.predicate)

    def uncompressed(self) -> "IndexDef":
        return dataclasses.replace(self, compression=None)

    def with_compression(self, method: Optional[str]) -> "IndexDef":
        return dataclasses.replace(self, compression=method)

    def label(self) -> str:
        c = f"^{self.compression}" if self.compression else ""
        p = f"|{self.predicate.col}" if self.predicate else ""
        cl = "*" if self.clustered else ""
        return f"{self.table}({','.join(self.cols)}){c}{p}{cl}"


def rows_per_page(row_width: int) -> int:
    return max(1, PAGE_BYTES // (row_width + ROW_OVERHEAD))


def build_index_data(table: Table, idx: IndexDef) -> np.ndarray:
    """Materialize index rows: filter (partial), sort by key cols.

    Returns an (nrows, ncols) int64 matrix in index order.
    """
    if idx.predicate is not None:
        rows = np.nonzero(idx.predicate.mask(table))[0]
        sub = {c: table.values[c][rows] for c in idx.cols}
    else:
        sub = {c: table.values[c] for c in idx.cols}
    # lexicographic sort by key columns (np.lexsort: last key is primary)
    keys = [sub[c] for c in reversed(idx.cols)]
    order = np.lexsort(keys) if keys else np.arange(table.nrows)
    return np.stack([sub[c][order] for c in idx.cols], axis=1)


def uncompressed_bytes(nrows: int, widths: Sequence[int]) -> int:
    """Size of an uncompressed index with the page model."""
    rw = sum(widths)
    rpp = rows_per_page(rw)
    npages = -(-nrows // rpp) if nrows else 0
    return npages * PAGE_BYTES


def uncompressed_pages(nrows: int, widths: Sequence[int]) -> int:
    rw = sum(widths)
    rpp = rows_per_page(rw)
    return -(-nrows // rpp) if nrows else 0

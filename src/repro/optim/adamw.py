"""AdamW with optionally COMPRESSED (blockwise-int8) first/second moments.

The optimizer state is the largest persistent tensor class in training —
the direct analogue of the paper's clustered index.  The physical-design
advisor (repro.design) decides per tensor class whether moments are stored
f32 (fast, 8 bytes/param) or q8 (2 bytes/param + scales, paying quant/
dequant VPU cost per step — the alpha/beta of Appendix A).

The q8 codec is kernels/ops.quantize_blockwise; v (second moment) is
quantized in sqrt-space to preserve dynamic range.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops, ref

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_codec: str = "f32"      # "f32" | "q8"
    q_block: int = 128
    use_pallas: bool = False      # ref codec by default (jnp; fuses in XLA)


def _q(x, cfg: AdamWConfig):
    fn = ops.quantize_blockwise if cfg.use_pallas else ref.quantize_blockwise
    return fn(x, cfg.q_block)


def _dq(q, s, cfg: AdamWConfig):
    fn = (ops.dequantize_blockwise if cfg.use_pallas
          else ref.dequantize_blockwise)
    return fn(q, s, cfg.q_block)


def adamw_init(params: Params, cfg: AdamWConfig) -> Dict[str, Any]:
    if cfg.state_codec == "q8":
        def zero_q(p):
            nb = -(-p.shape[-1] // cfg.q_block)
            return {
                "m_q": jnp.zeros(p.shape, jnp.int8),
                "m_s": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
                "v_q": jnp.zeros(p.shape, jnp.int8),
                "v_s": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
            }
        moments = jax.tree.map(zero_q, params)
    else:
        moments = jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                       "v": jnp.zeros(p.shape, jnp.float32)}, params)
    return {"step": jnp.zeros((), jnp.int32), "moments": moments}


def adamw_update(params: Params, grads: Params, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Params, Dict[str, Any]]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd_f32(p, g, mom):
        g = g.astype(jnp.float32)
        m = cfg.b1 * mom["m"] + (1 - cfg.b1) * g
        v = cfg.b2 * mom["v"] + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p - cfg.lr * (update + cfg.weight_decay * p.astype(jnp.float32)
                              ).astype(p.dtype)
        return new_p.astype(p.dtype), {"m": m, "v": v}

    def upd_q8(p, g, mom):
        g = g.astype(jnp.float32)
        m = _dq(mom["m_q"], mom["m_s"], cfg)                 # decompress
        v_sqrt = _dq(mom["v_q"], mom["v_s"], cfg)
        v = v_sqrt * v_sqrt
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p - cfg.lr * (update + cfg.weight_decay *
                              p.astype(jnp.float32)).astype(p.dtype)
        m_q, m_s = _q(m, cfg)                                # compress
        v_q, v_s = _q(jnp.sqrt(v), cfg)
        return new_p.astype(p.dtype), {"m_q": m_q, "m_s": m_s,
                                       "v_q": v_q, "v_s": v_s}

    upd = upd_q8 if cfg.state_codec == "q8" else upd_f32
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["moments"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_moments = treedef.unflatten([o[1] for o in out])
    return new_params, {"step": step, "moments": new_moments}

"""Sharding rules: parameter + activation PartitionSpecs for every arch.

Parallelism mapping (DESIGN.md §5):
* DP   — batch over ("pod", "data") when both exist, else ("data",).
* FSDP — parameter d_model/d_ff rows sharded over "data" (ZeRO-style); the
         "pod" axis stays pure DP by default (gradient all-reduce across
         pods) — configurable via DistConfig.fsdp_over_pod.
* TP   — heads / ff / vocab / experts over "model".
* EP   — MoE expert dim over "model".
* SP   — long-context serving (batch smaller than the DP axes): KV-cache
         sequence dim sharded over "data".

Rules are PATH-BASED: a table keyed by parameter name (with its subtree
context) gives the spec of the *base* (unstacked) array; leading scan-stack
dims (layers / groups / per-group stacks) are prepended as None.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig
from ..models import model as MD


@dataclasses.dataclass(frozen=True)
class DistConfig:
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None       # set for the multi-pod mesh
    fsdp: bool = True                    # shard params over data axis
    fsdp_over_pod: bool = False          # ZeRO across pods too (beyond-paper)
    # "tp": model axis = tensor parallel (baseline).
    # "fsdp": NO tensor parallelism — the model axis joins data for pure
    #         ZeRO-3 sharding (the train_4k hillclimb: kills the per-layer
    #         activation all-reduces that dominate the collective term).
    parallel_mode: str = "tp"
    # shard the KV-cache SEQUENCE dim over the model axis instead of kv
    # heads (decode hillclimb: removes the kv-head padding waste for
    # GQA models with kv_heads < 16)
    kv_seq_shard: bool = False

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        base = ((self.pod_axis,) if self.pod_axis else ()) + (self.data_axis,)
        if self.parallel_mode == "fsdp":
            return base + (self.model_axis,)
        return base

    @property
    def tp_axis(self) -> Optional[str]:
        return self.model_axis if self.parallel_mode == "tp" else None

    @property
    def fsdp_axes(self):
        if not self.fsdp:
            return None
        axes = [self.data_axis]
        if self.fsdp_over_pod and self.pod_axis:
            axes.insert(0, self.pod_axis)
        if self.parallel_mode == "fsdp":
            axes.append(self.model_axis)
        return tuple(axes) if len(axes) > 1 else axes[0]


def _divisible(dim: int, mesh_axes, mesh) -> bool:
    if mesh_axes is None:
        return False
    axes = mesh_axes if isinstance(mesh_axes, tuple) else (mesh_axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0


# Base spec table: name -> builder(dist) returning a tuple spec for the
# UNSTACKED parameter.  F = fsdp axes, T = model axis.
def _base_rules(dist: DistConfig):
    F, T = dist.fsdp_axes, dist.tp_axis
    return {
        # top level
        "embed": (T, F),
        "lm_head": (F, T),
        # norms (any)
        "scale": (None,),
        "ln_scale": (None,),
        # attention
        "wq": (F, T, None),
        "wk": (F, T, None),
        "wv": (F, T, None),
        "wo_attn": (T, None, F),
        # dense mlp
        "wi": (F, T),
        "wg": (F, T),
        "wo_mlp": (T, F),
        # moe
        "router": (F, None),
        "moe_wi": (T, F, None),
        "moe_wg": (T, F, None),
        "moe_wo": (T, None, F),
        # rwkv time-mix
        "mu_x": (None,), "mu": (None, None),
        "ts_w1": (F, None), "ts_w2": (None, None, F),
        "w0": (None,), "w1": (F, None), "w2": (None, F),
        "u": (T, None),
        "rwkv_wr": (F, T), "rwkv_wk": (F, T), "rwkv_wv": (F, T),
        "rwkv_wg": (F, T), "rwkv_wo": (T, F),
        # rwkv channel-mix
        "mu_k": (None,), "mu_r": (None,),
        "cm_wk": (F, T), "cm_wv": (T, F), "cm_wr": (F, T),
        # mamba
        "in_proj": (F, T),
        "conv_w": (None, T), "conv_b": (T,),
        "x_proj": (T, None),
        "dt_w": (None, T), "dt_b": (T,),
        "a_log": (T, None), "d_skip": (T,),
        "out_proj": (T, F),
    }


def _rule_key(path) -> str:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1]
    ctx = names[-2] if len(names) >= 2 else ""
    if name == "wo":
        if ctx in ("attn",):
            return "wo_attn"
        if ctx == "moe":
            return "moe_wo"
        return "wo_mlp"
    if ctx == "moe" and name in ("wi", "wg"):
        return "moe_" + name
    if ctx == "tm" and name in ("wr", "wk", "wv", "wg"):
        return "rwkv_" + name
    if ctx == "cm" and name in ("wk", "wv", "wr"):
        return "cm_" + name
    return name


def param_specs(params_shape, cfg: ModelConfig, dist: DistConfig, mesh):
    """PartitionSpec pytree matching the params pytree.

    Any spec entry whose dim does not divide the mesh axes falls back to
    None (replicated) — checked per-leaf so odd dims never break lowering.
    """
    rules = _base_rules(dist)

    def spec_for(path, leaf):
        key = _rule_key(path)
        base = rules[key]
        pad = leaf.ndim - len(base)
        assert pad >= 0, f"{key}: leaf ndim {leaf.ndim} < base {len(base)}"
        full = (None,) * pad + tuple(base)
        safe = []
        for dim, ax in zip(leaf.shape, full):
            safe.append(ax if ax is not None and _divisible(dim, ax, mesh)
                        else None)
        return P(*safe)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def activation_specs(dist: DistConfig):
    """Specs for (tokens, labels, embeds, logits, hidden)."""
    dp = dist.dp_axes
    dp_spec = dp if len(dp) > 1 else dp[0]
    return {
        "tokens": P(dp_spec, None),
        "labels": P(dp_spec, None),
        "embeds": P(dp_spec, None, None),
        "logits": P(dp_spec, None, dist.tp_axis),
        "hidden": P(dp_spec, None, None),
    }


def serve_state_specs(state_shape, cfg: ModelConfig, dist: DistConfig, mesh,
                      batch: int):
    """Specs for the serving state (KV caches / SSM states).

    If the batch divides the DP axes, shard batch over DP; otherwise (the
    long_500k single-request cell) shard the KV **sequence** dim over "data"
    (sequence parallelism) and leave batch unsharded.
    """
    dp = dist.dp_axes
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_sharded = batch % dp_size == 0
    dp_spec = dp if len(dp) > 1 else dp[0]
    T = dist.tp_axis

    def spec_for(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1]
        if name == "pos":
            spec = (dp_spec if batch_sharded else None,)
            safe = [ax if ax is not None and _divisible(d, ax, mesh) else None
                    for d, ax in zip(leaf.shape, spec)]
            return P(*safe)
        if names[0] == "kv":  # (L, B, S, KvH, Dh)
            if dist.kv_seq_shard and dist.parallel_mode == "tp":
                # seq over the model axis; kv heads UNSHARDED (no padding
                # waste reads); batch over dp when divisible
                spec = ((None, dp_spec if batch_sharded else None,
                         dist.model_axis, None, None))
            elif batch_sharded:
                spec = (None, dp_spec, None, T, None)
            else:
                spec = (None, None, dist.data_axis, T, None)
            safe = [ax if ax is not None and _divisible(d, ax, mesh) else None
                    for d, ax in zip(leaf.shape, spec)]
            return P(*safe)
        if names[0] == "rwkv":
            # tm/cm shift: (L,B,D); wkv: (L,B,H,hs,hs)
            if name in ("tm_shift", "cm_shift"):
                spec = (None, dp_spec if batch_sharded else None, None)
            else:
                spec = (None, dp_spec if batch_sharded else None, T, None,
                        None)
        elif names[0] == "mamba":
            # conv: (G,M,B,K-1,Din); ssm: (G,M,B,Din,ds)
            if name == "conv":
                spec = (None, None, dp_spec if batch_sharded else None, None,
                        T)
            else:
                spec = (None, None, dp_spec if batch_sharded else None, T,
                        None)
        else:
            spec = (None,) * leaf.ndim
        safe = [ax if ax is not None and _divisible(d, ax, mesh) else None
                for d, ax in zip(leaf.shape, spec)]
        return P(*safe)

    return jax.tree_util.tree_map_with_path(spec_for, state_shape)

from .sharding import (activation_specs, param_specs, serve_state_specs,
                       DistConfig)

__all__ = ["activation_specs", "param_specs", "serve_state_specs",
           "DistConfig"]

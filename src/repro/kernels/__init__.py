# Pallas TPU kernels for the compression hot-spots (compress / decompress /
# decompress-on-read), each with a pure-jnp oracle in ref.py:
#   quantize_blockwise.py — blockwise int8 quantize + dequantize kernels
#   dequant_matmul.py     — fused int8-weight matmul (dequant in VMEM)
#   ops.py                — jit'd wrappers (auto interpret=True on CPU)
from . import ops, ref

__all__ = ["ops", "ref"]

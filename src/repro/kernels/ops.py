"""jit'd public wrappers around the Pallas kernels.

* Auto-select interpret mode on CPU (the kernels TARGET TPU; interpret=True
  executes the kernel body in Python for correctness validation).
* Handle arbitrary-rank inputs by flattening leading dims and padding the
  last dim to tile multiples, so the optimizer / KV cache / checkpoint
  codecs can quantize any parameter tensor.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import ref
from .dequant_matmul import dequant_matmul as _dequant_matmul_pallas
from .quantize_blockwise import (dequantize_blockwise_2d,
                                 quantize_blockwise_2d)
from .ref import DEFAULT_BLOCK


def _use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _to_2d(x: jnp.ndarray, block: int):
    """Flatten to (M, N) with N a multiple of block; M padded to tile rows."""
    n = x.shape[-1]
    lead = 1
    for d in x.shape[:-1]:
        lead *= d
    flat = x.reshape(lead, n)
    pad_n = (-n) % block
    if pad_n:
        flat = jnp.pad(flat, ((0, 0), (0, pad_n)))
    return flat, lead, n


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def quantize_blockwise(x: jnp.ndarray, block: int = DEFAULT_BLOCK,
                       use_pallas: bool = True
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Any-rank blockwise int8 quantization.

    Returns (q int8, same shape as x; scales f32, shape
    x.shape[:-1] + (ceil(N/block),)).
    """
    n = x.shape[-1]
    nb = -(-n // block)
    if not use_pallas:
        return ref.quantize_blockwise(x, block)
    flat, lead, _ = _to_2d(x, block)
    # pick a row tile that divides the (padded) row count
    tile_m = 1
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if lead % cand == 0:
            tile_m = cand
            break
    tile_n = flat.shape[1]
    for cand in (512, 256, 128):
        if flat.shape[1] % cand == 0 and cand % block == 0:
            tile_n = cand
            break
    q, s = quantize_blockwise_2d(flat, block, interpret=_use_interpret(),
                                 tile_m=tile_m, tile_n=tile_n)
    q = q[:, :n].reshape(x.shape)
    s = s[:, :nb].reshape(x.shape[:-1] + (nb,))
    return q, s


@functools.partial(jax.jit,
                   static_argnames=("block", "dtype", "use_pallas"))
def dequantize_blockwise(q: jnp.ndarray, scales: jnp.ndarray,
                         block: int = DEFAULT_BLOCK, dtype=jnp.float32,
                         use_pallas: bool = True) -> jnp.ndarray:
    if not use_pallas:
        return ref.dequantize_blockwise(q, scales, block, dtype)
    n = q.shape[-1]
    nb = -(-n // block)
    flat, lead, _ = _to_2d(q, block)
    sflat = scales.reshape(lead, nb)
    pad_b = flat.shape[1] // block - nb
    if pad_b:
        sflat = jnp.pad(sflat, ((0, 0), (0, pad_b)))
    tile_m = 1
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if lead % cand == 0:
            tile_m = cand
            break
    tile_n = flat.shape[1]
    for cand in (512, 256, 128):
        if flat.shape[1] % cand == 0 and cand % block == 0:
            tile_n = cand
            break
    out = dequantize_blockwise_2d(flat, sflat, block, dtype,
                                  interpret=_use_interpret(),
                                  tile_m=tile_m, tile_n=tile_n)
    return out[:, :n].reshape(q.shape)


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def dequant_matmul(a: jnp.ndarray, qw: jnp.ndarray, scales: jnp.ndarray,
                   block: int = DEFAULT_BLOCK,
                   use_pallas: bool = True) -> jnp.ndarray:
    """a (M, K) @ dequant(qw (K, N)) with per-(K-block, N) scales."""
    if not use_pallas:
        return ref.dequant_matmul(a, qw, scales, block)
    m, k = a.shape
    _, n = qw.shape
    tile_m = 1
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % cand == 0:
            tile_m = cand
            break
    tile_n = n
    for cand in (256, 128):
        if n % cand == 0:
            tile_n = cand
            break
    return _dequant_matmul_pallas(a, qw, scales, block,
                                  interpret=_use_interpret(),
                                  tile_m=tile_m, tile_n=tile_n)

"""Pallas TPU kernel: fused dequantize-matmul (decompress-on-read).

    out (M, N) = a (M, K) @ dequant(qw (K, N) int8, scale (K/block, N))

The int8 weight never materializes in HBM as floats: each grid step loads an
(TK, TN) int8 tile into VMEM, dequantizes on the VPU, and feeds the MXU.
This is the TPU rendering of the paper's A.2 rule — "decompress only the
columns the query uses", fused into the consumer.

Tiling: grid (M/TM, N/TN, K/TK), K innermost for accumulation; TK equals the
quantization block so each k-step uses exactly one scale row.  MXU-aligned
tiles (128 multiples).  VMEM/step: a 128KB + qw 32KB + acc 128KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import DEFAULT_BLOCK

TILE_M = 256
TILE_N = 256


def _dequant_matmul_kernel(a_ref, qw_ref, s_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)              # (TM, TK)
    w = qw_ref[...].astype(jnp.float32)             # (TK, TN)
    w = w * s_ref[...]                              # scale row (1, TN)
    acc_ref[...] += jax.lax.dot(a, w,
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dequant_matmul(a: jnp.ndarray, qw: jnp.ndarray, scale: jnp.ndarray,
                   block: int = DEFAULT_BLOCK, interpret: bool = False,
                   tile_m: int = TILE_M, tile_n: int = TILE_N,
                   out_dtype=jnp.float32) -> jnp.ndarray:
    """a: (M, K); qw: (K, N) int8; scale: (K // block, N) f32."""
    m, k = a.shape
    k2, n = qw.shape
    assert k == k2 and k % block == 0
    assert scale.shape == (k // block, n), scale.shape
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    assert m % tile_m == 0 and n % tile_n == 0
    n_k = k // block
    grid = (m // tile_m, n // tile_n, n_k)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, tile_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m, n), out_dtype)],
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        interpret=interpret,
    )(a, qw, scale)[0]

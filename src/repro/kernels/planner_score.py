"""Pallas (node x f) scoring kernels for the batched §5.2 planner.

Two kernels behind `PlannerEngine(backend="jax")`:

* `prob_within` — the accuracy-probability stage
  ``P(1/(1+e) <= X <= 1+e)`` over (mean, std) stacks, mirroring
  `errors.prob_within_batch` (same std<=1e-12 indicator branch, same phi
  evaluation order) in float32 on the VPU.

* `fused_score` — the whole candidate-scoring step of one §5.2 target
  fused into one kernel: the sequential Goodman fold over the (candidate,
  child, f) RV stack, continued with the deduction-error factor, the
  composed std, the masked accuracy probability, and the lines-6-9 winner
  selection (first-argmax of p over eligible candidates, first-argmin of
  the extra sampling cost) per fraction.

Consistency contract (this is what keeps replay and session-vs-fresh
plan equality exact under the jax backend): both kernels evaluate the
probability through the SAME `_prob_expr` op sequence, so a probability
recomputed later from a stored (mean, std) pair — planner buf values are
float32-exact once written — is bit-identical to the fused kernel's
in-line value.  The engine consumes the fused kernel's cm/cs/p and keeps
winner selection on the float64 side (p is float32-exact so the argmax
agrees; the lines-8-9 extra-cost argmin stays on the engine's float64
sampling costs, which the in-kernel float32 argmin mirrors except on
sub-ulp ties).  The kernels are NOT bit-parity with the float64 NumPy
backend (a different erf and float32 arithmetic); the NumPy backend
remains the parity reference against the scalar planner.

Parity suite: tests/test_pallas_parity.py asserts `prob_within` against
`errors.prob_within_batch` within float32 tolerance (exactly on the
indicator branch) and asserts `fused_score`'s staged outputs (cm/cs/p)
and winners against a NumPy re-expression of the same fold.
"""
from __future__ import annotations

import functools
import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_SQRT2_F32 = np.float32(math.sqrt(2.0))
_BIG = np.int32(2 ** 31 - 1)  # "no winner" sentinel for the argmin outputs


def _use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _prob_expr(cm, cs, e: float):
    """float32 accuracy probability, one op sequence shared by BOTH kernels
    (the engine's replay consistency depends on this being identical)."""
    lo = jnp.float32(1.0 / (1.0 + e))
    hi = jnp.float32(1.0 + e)
    small = cs <= jnp.float32(1e-12)
    s = jnp.where(small, jnp.float32(1.0), cs)
    phi_hi = jnp.float32(0.5) * (jnp.float32(1.0)
                                 + jax.lax.erf((hi - cm) / s / _SQRT2_F32))
    phi_lo = jnp.float32(0.5) * (jnp.float32(1.0)
                                 + jax.lax.erf((lo - cm) / s / _SQRT2_F32))
    ind = ((cm >= lo) & (cm <= hi)).astype(jnp.float32)
    return jnp.where(small, ind, phi_hi - phi_lo)


def _compose_expr(m, s, dm, vt, mq):
    """Sequential Goodman fold over the child axis of (nc, K, nf) stacks,
    continued with the (nc, 1) deduction-error factors — the float32 twin
    of errors.goodman_fold + the engine's deduction continuation.  A
    (mean=1, std=0) EXACT pad is the exact multiplicative identity in
    float32 too, so folds of different padded K agree bitwise."""
    k = m.shape[1]
    e_prod = m[:, 0, :]
    v_term = s[:, 0, :] * s[:, 0, :] + e_prod * e_prod
    e2_term = e_prod * e_prod
    for kk in range(1, k):
        mk = m[:, kk, :]
        sk = s[:, kk, :]
        msq = mk * mk
        e_prod = e_prod * mk
        v_term = v_term * (sk * sk + msq)
        e2_term = e2_term * msq
    cm = e_prod * dm
    v = v_term * vt
    e2 = e2_term * mq
    cs = jnp.sqrt(jnp.maximum(v - e2, jnp.float32(0.0)))
    return cm, cs


# ---------------------------------------------------------------------------
# prob_within: 1-D probability stage
# ---------------------------------------------------------------------------

def _prob_kernel(m_ref, s_ref, o_ref, *, e: float):
    o_ref[...] = _prob_expr(m_ref[...], s_ref[...], e)


@functools.partial(jax.jit, static_argnames=("e", "interpret"))
def _prob_call(m, s, *, e: float, interpret: bool):
    return pl.pallas_call(
        functools.partial(_prob_kernel, e=e),
        grid=(1,),
        in_specs=[pl.BlockSpec(m.shape, lambda i: (0, 0)),
                  pl.BlockSpec(m.shape, lambda i: (0, 0))],
        out_specs=[pl.BlockSpec(m.shape, lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(m.shape, jnp.float32)],
        interpret=interpret,
    )(m, s)[0]


def prob_within(means: np.ndarray, stds: np.ndarray, e: float) -> np.ndarray:
    """Pallas twin of errors.prob_within_batch (float32).  Accepts any
    shape; pads to pow2 lane multiples to bound the compiled-shape count
    (same idiom as the retired jitted-erf backend)."""
    means = np.asarray(means, dtype=np.float64)
    stds = np.asarray(stds, dtype=np.float64)
    n = means.size
    if n == 0:
        return np.zeros(means.shape)
    n_pad = max(_LANES, 1 << int(n - 1).bit_length())
    mp = np.ones((1, n_pad), dtype=np.float32)
    sp = np.zeros((1, n_pad), dtype=np.float32)
    mp[0, :n] = means.ravel()
    sp[0, :n] = stds.ravel()
    out = _prob_call(jnp.asarray(mp), jnp.asarray(sp), e=float(e),
                     interpret=_use_interpret())
    return np.asarray(out, dtype=np.float64)[0, :n].reshape(means.shape)


# ---------------------------------------------------------------------------
# fused_score: compose + prob + winner selection for one target record
# ---------------------------------------------------------------------------

def _fused_kernel(m_ref, s_ref, dm_ref, vt_ref, mq_ref, m67_ref, p9_ref,
                  ex_ref, cm_ref, cs_ref, p_ref, w6_ref, w9_ref,
                  *, k: int, nf: int, e: float, q: float):
    nc = m_ref.shape[0]
    m = m_ref[...].reshape(nc, k, nf)
    s = s_ref[...].reshape(nc, k, nf)
    cm, cs = _compose_expr(m, s, dm_ref[...], vt_ref[...], mq_ref[...])
    m67 = m67_ref[...] != 0
    p9 = p9_ref[...] != 0
    p = jnp.where(m67 | p9, _prob_expr(cm, cs, e), jnp.float32(0.0))
    sat = p >= jnp.float32(q)
    iota = jax.lax.broadcasted_iota(jnp.int32, p.shape, 0)
    # lines 6-7: first argmax of p over eligible (enabled & satisfying)
    elig = m67 & sat
    pe = jnp.where(elig, p, jnp.float32(-1.0))
    best = jnp.max(pe, axis=0, keepdims=True)
    w6 = jnp.min(jnp.where(elig & (pe == best), iota, _BIG), axis=0,
                 keepdims=True)
    # lines 8-9: first argmin of extra sampling cost where no line-6 winner
    has6 = jnp.any(elig, axis=0, keepdims=True)
    ok9 = p9 & sat & ~has6
    xe = jnp.where(ok9, ex_ref[...], jnp.float32(np.inf))
    bx = jnp.min(xe, axis=0, keepdims=True)
    w9 = jnp.min(jnp.where(ok9 & (xe == bx), iota, _BIG), axis=0,
                 keepdims=True)
    cm_ref[...] = cm
    cs_ref[...] = cs
    p_ref[...] = p
    w6_ref[...] = w6
    w9_ref[...] = w9


@functools.partial(jax.jit, static_argnames=("k", "e", "q", "interpret"))
def _fused_call(m, s, dm, vt, mq, m67, p9, ex, *, k: int, e: float,
                q: float, interpret: bool):
    nc, knf = m.shape
    nf = knf // k
    full = lambda i: (0, 0)  # noqa: E731 - single-block grid
    spec = lambda shape: pl.BlockSpec(shape, full)  # noqa: E731
    return pl.pallas_call(
        functools.partial(_fused_kernel, k=k, nf=nf, e=e, q=q),
        grid=(1,),
        in_specs=[spec(m.shape), spec(s.shape), spec(dm.shape),
                  spec(vt.shape), spec(mq.shape), spec(m67.shape),
                  spec(p9.shape), spec(ex.shape)],
        out_specs=[spec((nc, nf)), spec((nc, nf)), spec((nc, nf)),
                   spec((1, nf)), spec((1, nf))],
        out_shape=[jax.ShapeDtypeStruct((nc, nf), jnp.float32),
                   jax.ShapeDtypeStruct((nc, nf), jnp.float32),
                   jax.ShapeDtypeStruct((nc, nf), jnp.float32),
                   jax.ShapeDtypeStruct((1, nf), jnp.int32),
                   jax.ShapeDtypeStruct((1, nf), jnp.int32)],
        interpret=interpret,
    )(m, s, dm, vt, mq, m67, p9, ex)


def _pad_axis(a: np.ndarray, axis: int, size: int, fill) -> np.ndarray:
    if a.shape[axis] == size:
        return a
    shape = list(a.shape)
    shape[axis] = size - a.shape[axis]
    return np.concatenate([a, np.full(shape, fill, dtype=a.dtype)], axis=axis)


def fused_score(m: np.ndarray, s: np.ndarray, dm: np.ndarray,
                vt: np.ndarray, mq: np.ndarray, mask67: np.ndarray,
                pre9, extra, e: float, q: float):
    """One fused pass over a target's (nc, K, nf) candidate stack.

    m/s are child RV means/stds (EXACT-padded along K), dm/vt/mq the
    (nc, 1) deduction-error continuation factors, mask67/pre9 the
    lines-6-7 / lines-8-9 eligibility masks, extra the summed sampling
    cost of unknown children (lines 8-9 tie-break axis).  Returns
    (cm, cs, p, w6, w9): composed mean/std, masked probability — all
    float32 values in float64 arrays — and the per-f winner indices
    (int64; meaningless where the respective mask column is empty).
    """
    nc, k, nf = m.shape
    nc_pad = -(-nc // 8) * 8
    nf_pad = -(-nf // _LANES) * _LANES
    z = np.zeros((nc, nf)) if pre9 is None else pre9
    x = np.zeros((nc, nf)) if extra is None else extra

    def prep(a, fill, dtype):
        a = _pad_axis(np.asarray(a, dtype=dtype), 0, nc_pad, fill)
        return _pad_axis(a, a.ndim - 1, nf_pad, fill)

    mp = prep(m, 1.0, np.float32).reshape(nc_pad, k * nf_pad)
    sp = prep(s, 0.0, np.float32).reshape(nc_pad, k * nf_pad)
    dmp = _pad_axis(np.asarray(dm, dtype=np.float32), 0, nc_pad, 1.0)
    vtp = _pad_axis(np.asarray(vt, dtype=np.float32), 0, nc_pad, 1.0)
    mqp = _pad_axis(np.asarray(mq, dtype=np.float32), 0, nc_pad, 1.0)
    m67p = prep(mask67, 0, np.int32)
    p9p = prep(z, 0, np.int32)
    exp_ = prep(x, 0.0, np.float32)

    cm, cs, p, w6, w9 = _fused_call(
        jnp.asarray(mp), jnp.asarray(sp), jnp.asarray(dmp), jnp.asarray(vtp),
        jnp.asarray(mqp), jnp.asarray(m67p), jnp.asarray(p9p),
        jnp.asarray(exp_), k=k, e=float(e), q=float(q),
        interpret=_use_interpret())
    return (np.asarray(cm, dtype=np.float64)[:nc, :nf],
            np.asarray(cs, dtype=np.float64)[:nc, :nf],
            np.asarray(p, dtype=np.float64)[:nc, :nf],
            np.asarray(w6, dtype=np.int64)[0, :nf],
            np.asarray(w9, dtype=np.int64)[0, :nf])

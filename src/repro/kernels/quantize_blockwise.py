"""Pallas TPU kernels: blockwise int8 quantize (compress) and dequantize
(decompress).

Tiling: grid over (M // TILE_M, N // TILE_N) with TILE_N a multiple of the
quantization block.  Each kernel instance loads a (TILE_M, TILE_N) VMEM tile
(MXU/VPU-aligned: multiples of 8x128), computes per-(row, block) absmax
scales on the VPU, and writes the int8 tile + f32 scales.

VMEM budget per instance (defaults): in 256*512*4B = 512KB, out 128KB,
scales 4KB — comfortably under the ~16MB/core VMEM of v5e.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_BLOCK, Q_MAX

TILE_M = 256
TILE_N = 512


def _quantize_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)            # (TM, TN)
    tm, tn = x.shape
    blocks = x.reshape(tm, tn // block, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)    # (TM, TN/block)
    scale = jnp.maximum(absmax, 1e-12) / Q_MAX
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -Q_MAX, Q_MAX)
    q_ref[...] = q.reshape(tm, tn).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequantize_kernel(q_ref, s_ref, o_ref, *, block: int, dtype):
    q = q_ref[...].astype(jnp.float32)
    tm, tn = q.shape
    blocks = q.reshape(tm, tn // block, block)
    out = blocks * s_ref[...][..., None]
    o_ref[...] = out.reshape(tm, tn).astype(dtype)


def quantize_blockwise_2d(x: jnp.ndarray, block: int = DEFAULT_BLOCK,
                          interpret: bool = False,
                          tile_m: int = TILE_M, tile_n: int = TILE_N
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (M, N) with M % tile_m == 0, N % tile_n == 0, tile_n % block == 0."""
    m, n = x.shape
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    assert m % tile_m == 0 and n % tile_n == 0 and tile_n % block == 0, \
        (m, n, tile_m, tile_n, block)
    grid = (m // tile_m, n // tile_n)
    sb = tile_n // block
    return pl.pallas_call(
        functools.partial(_quantize_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
            pl.BlockSpec((tile_m, sb), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n // block), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_blockwise_2d(q: jnp.ndarray, scale: jnp.ndarray,
                            block: int = DEFAULT_BLOCK,
                            dtype=jnp.float32, interpret: bool = False,
                            tile_m: int = TILE_M, tile_n: int = TILE_N
                            ) -> jnp.ndarray:
    m, n = q.shape
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    assert m % tile_m == 0 and n % tile_n == 0 and tile_n % block == 0
    grid = (m // tile_m, n // tile_n)
    sb = tile_n // block
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, block=block, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
            pl.BlockSpec((tile_m, sb), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m, n), dtype)],
        interpret=interpret,
    )(q, scale)[0]

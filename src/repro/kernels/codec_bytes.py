"""Pallas segment-reduce kernels for the batched codec-size estimators.

These kernels accelerate `repro.core.compression.batched_bytes` — the
(targets x rows) column stacks that SampleCF and the estimation engine feed
through the five codec size formulas (NS / GDICT / LDICT / PREFIX / RLE).
Each kernel is a segment reduce: per target row, reduce the row (NS, GDICT)
or the (npages, rows_per_page) page grid (LDICT, PREFIX, RLE) down to one
payload-byte count.

int32-safe rescaling (the old jax path was gated on x64 being enabled;
these kernels remove that gate):

* Values are split into two uint32 planes ``hi = v >> 32``, ``lo = v & M32``
  of the uint64 view of the input.  The split is a bijection, so every
  primitive the codecs need factors exactly through the planes:
  - equality / adjacent-difference: ``a == b  <=>  a_hi == b_hi and
    a_lo == b_lo`` (GDICT/LDICT ndv counts, RLE run counts);
  - unsigned order: lexicographic (hi, lo) order equals uint64 order, so
    ``jax.lax.sort((hi, lo), num_keys=2)`` sorts exactly like the NumPy
    reference's int64 sort for non-negative inputs, and the PREFIX page
    min/max decompose as ``mn_hi = min(hi)``,
    ``mn_lo = min(lo where hi == mn_hi)`` (dually for max, xor per plane);
  - significant_bytes: ``sig(v) = 4 + sig32(hi)`` if ``hi != 0`` else
    ``sig32(lo)`` with ``sig32(u) = 1 + [u>=2^8] + [u>=2^16] + [u>=2^24]``.
* All byte-count arithmetic is then small-integer: with widths <= 8 every
  per-row/per-page term is <= ``rows * (width + 3) + PAGE_META``, so the
  final int32 accumulators stay below 2^31 whenever ``n <= 2^25`` rows.
  Inputs outside the proven envelope (negative values — the signed PREFIX
  min/max would diverge — more rows, or wider columns) fall back to the
  NumPy reference kernels, so `batched_codec_bytes` is exact for every
  input.

Parity contract: bit-identical to `compression.BATCH_KERNELS[method]` —
asserted by tests/test_pallas_parity.py.  Kernels run under
``interpret=True`` on CPU (same idiom as kernels/ops.py) and compile for
TPU unchanged.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# mirror repro.core.compression.PAGE_META / _ptr_bytes thresholds; imported
# lazily in the fallback path to avoid a kernels -> core import at load time
_PAGE_META = 16
_LANES = 128
_M32 = np.uint64(0xFFFFFFFF)

# envelope of the int32 exactness proof (see module docstring)
_MAX_ROWS = 1 << 25
_MAX_WIDTH = 8

ORD_IND_METHODS = ("NS", "GDICT")
ORD_DEP_METHODS = ("LDICT", "PREFIX", "RLE")


def _use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _sig32(u):
    """Significant bytes (1..4) of a uint32 plane."""
    return (jnp.int32(1)
            + (u >= jnp.uint32(1 << 8)).astype(jnp.int32)
            + (u >= jnp.uint32(1 << 16)).astype(jnp.int32)
            + (u >= jnp.uint32(1 << 24)).astype(jnp.int32))


def _sig64(hi, lo):
    """significant_bytes of the uint64 value represented by (hi, lo)."""
    return jnp.where(hi > jnp.uint32(0), 4 + _sig32(hi), _sig32(lo))


def _ptr(ndv):
    """Dictionary pointer bytes for ndv entries (== compression._ptr_bytes)."""
    return jnp.where(ndv <= 256, 1, jnp.where(ndv <= 65536, 2, 3))


def _page_rows(shape, npages: int, rpp: int, last_rows: int):
    """(TM, npages) int32 rows actually stored in each page."""
    pg = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return jnp.where(pg == npages - 1, jnp.int32(last_rows), jnp.int32(rpp))


# ---------------------------------------------------------------------------
# Kernel bodies.  hi/lo are (TILE_M, n_pad) uint32 planes, w is (TILE_M, 1)
# int32, out is (TILE_M, 1) int32.
# ---------------------------------------------------------------------------

def _ns_kernel(hi_ref, lo_ref, w_ref, out_ref, *, n: int):
    hi, lo, w = hi_ref[...], lo_ref[...], w_ref[...]
    sig = jnp.minimum(_sig64(hi, lo), w)
    half = jnp.minimum(2 * sig + 1, 2 * w)
    col = jax.lax.broadcasted_iota(jnp.int32, half.shape, 1)
    half = jnp.where(col < n, half, 0)  # zero-padded lanes contribute nothing
    out_ref[...] = (jnp.sum(half, axis=1, keepdims=True) + 1) // 2


def _gdict_kernel(hi_ref, lo_ref, w_ref, out_ref, *, n: int):
    # rows arrive sorted and edge-padded with their own max, so padding lanes
    # never add a distinct value and no mask is needed
    hi, lo, w = hi_ref[...], lo_ref[...], w_ref[...]
    neq = (hi[:, 1:] != hi[:, :-1]) | (lo[:, 1:] != lo[:, :-1])
    ndv = 1 + jnp.sum(neq.astype(jnp.int32), axis=1, keepdims=True)
    out_ref[...] = ndv * w + n * _ptr(ndv)


def _ldict_kernel(hi_ref, lo_ref, w_ref, out_ref, *,
                  npages: int, rpp: int, last_rows: int):
    tm = hi_ref.shape[0]
    # rows arrive page-sorted; adjacent inequality within a page counts ndv
    hi = hi_ref[...].reshape(tm, npages, rpp)
    lo = lo_ref[...].reshape(tm, npages, rpp)
    w = w_ref[...]
    neq = (hi[:, :, 1:] != hi[:, :, :-1]) | (lo[:, :, 1:] != lo[:, :, :-1])
    ndv = 1 + jnp.sum(neq.astype(jnp.int32), axis=2)        # (TM, npages)
    rows = _page_rows(ndv.shape, npages, rpp, last_rows)
    per_page = ndv * w + rows * _ptr(ndv) + _PAGE_META
    cap = rows * w + _PAGE_META
    out_ref[...] = jnp.sum(jnp.minimum(per_page, cap), axis=1, keepdims=True)


def _prefix_kernel(hi_ref, lo_ref, w_ref, out_ref, *,
                   npages: int, rpp: int, last_rows: int):
    tm = hi_ref.shape[0]
    hi = hi_ref[...].reshape(tm, npages, rpp)
    lo = lo_ref[...].reshape(tm, npages, rpp)
    w = w_ref[...]
    # 64-bit unsigned page min/max through the planes (lexicographic)
    mnh = jnp.min(hi, axis=2)
    mxh = jnp.max(hi, axis=2)
    mnl = jnp.min(jnp.where(hi == mnh[:, :, None], lo,
                            jnp.uint32(0xFFFFFFFF)), axis=2)
    mxl = jnp.max(jnp.where(hi == mxh[:, :, None], lo, jnp.uint32(0)), axis=2)
    xh, xl = mnh ^ mxh, mnl ^ mxl
    diff = jnp.where((xh | xl) == jnp.uint32(0), 0, _sig64(xh, xl))
    common = jnp.maximum(w - diff, 0)
    rows = _page_rows(diff.shape, npages, rpp, last_rows)
    per_page = common + rows * (1 + w - common) + _PAGE_META
    cap = rows * w + _PAGE_META
    out_ref[...] = jnp.sum(jnp.minimum(per_page, cap), axis=1, keepdims=True)


def _rle_kernel(hi_ref, lo_ref, w_ref, out_ref, *,
                npages: int, rpp: int, last_rows: int):
    tm = hi_ref.shape[0]
    # unsorted pages: adjacent inequality counts runs; the edge padding
    # repeats the row's last value so padded lanes never start a run
    hi = hi_ref[...].reshape(tm, npages, rpp)
    lo = lo_ref[...].reshape(tm, npages, rpp)
    w = w_ref[...]
    neq = (hi[:, :, 1:] != hi[:, :, :-1]) | (lo[:, :, 1:] != lo[:, :, :-1])
    runs = 1 + jnp.sum(neq.astype(jnp.int32), axis=2)
    rows = _page_rows(runs.shape, npages, rpp, last_rows)
    per_page = runs * (w + 2) + _PAGE_META
    cap = rows * w + _PAGE_META
    out_ref[...] = jnp.sum(jnp.minimum(per_page, cap), axis=1, keepdims=True)


_KERNELS = {
    "NS": _ns_kernel,
    "GDICT": _gdict_kernel,
    "LDICT": _ldict_kernel,
    "PREFIX": _prefix_kernel,
    "RLE": _rle_kernel,
}


@functools.partial(jax.jit, static_argnames=(
    "method", "n", "rpp", "tile_m", "interpret"))
def _codec_call(hi, lo, w, *, method: str, n: int, rpp: int,
                tile_m: int, interpret: bool):
    m_pad, n_pad = hi.shape
    if method == "GDICT":
        hi, lo = jax.lax.sort((hi, lo), dimension=1, num_keys=2)
        body = functools.partial(_gdict_kernel, n=n)
    elif method == "NS":
        body = functools.partial(_ns_kernel, n=n)
    else:
        npages = n_pad // rpp
        last_rows = n - (npages - 1) * rpp
        if method == "LDICT":
            h3 = hi.reshape(m_pad, npages, rpp)
            l3 = lo.reshape(m_pad, npages, rpp)
            h3, l3 = jax.lax.sort((h3, l3), dimension=2, num_keys=2)
            hi, lo = h3.reshape(m_pad, n_pad), l3.reshape(m_pad, n_pad)
        body = functools.partial(_KERNELS[method], npages=npages, rpp=rpp,
                                 last_rows=last_rows)
    grid = (m_pad // tile_m,)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((tile_m, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m_pad, 1), jnp.int32)],
        interpret=interpret,
    )(hi, lo, w)[0]


def _pad_rows(a: np.ndarray, m_pad: int, fill) -> np.ndarray:
    m = a.shape[0]
    if m_pad == m:
        return a
    pad = np.full((m_pad - m,) + a.shape[1:], fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=0)


def in_envelope(cols: np.ndarray, widths: np.ndarray) -> bool:
    """True when the int32 exactness proof covers this stack."""
    m, n = cols.shape
    return (n <= _MAX_ROWS and int(widths.max(initial=0)) <= _MAX_WIDTH
            and (m == 0 or n == 0 or int(cols.min()) >= 0))


def batched_codec_bytes(method: str, cols: np.ndarray, widths: np.ndarray,
                        rpp: int) -> np.ndarray:
    """Pallas twin of compression.BATCH_KERNELS[method] — bit-identical.

    cols is an (ntargets, nrows) int64 stack, widths (ntargets,), rpp the
    shared rows-per-page.  Inputs outside the int32 exactness envelope are
    routed to the NumPy reference so the result is exact unconditionally.
    """
    cols = np.asarray(cols, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    m, n = cols.shape
    if m == 0 or n == 0:
        return np.zeros(m, dtype=np.int64)
    if not in_envelope(cols, widths):
        from ..core import compression as _comp
        return _comp.BATCH_KERNELS[method](cols, widths, rpp)

    # pad the rows axis for the kernel's needs, then split uint32 planes
    if method == "NS":
        n_pad = -(-n // _LANES) * _LANES
        if n_pad != n:
            cols = np.concatenate(
                [cols, np.zeros((m, n_pad - n), dtype=np.int64)], axis=1)
    elif method == "GDICT":
        n_pad = -(-n // _LANES) * _LANES
        if n_pad != n:
            cols = np.concatenate(
                [cols, np.repeat(cols[:, -1:], n_pad - n, axis=1)], axis=1)
    else:  # paged: edge-pad to a whole number of pages (== _pages_batch)
        npages = -(-n // rpp)
        n_pad = npages * rpp
        if n_pad != n:
            cols = np.concatenate(
                [cols, np.repeat(cols[:, -1:], n_pad - n, axis=1)], axis=1)

    u = cols.astype(np.uint64)
    hi = (u >> np.uint64(32)).astype(np.uint32)
    lo = (u & _M32).astype(np.uint32)

    m_pad = -(-m // 8) * 8
    tile_m = next(t for t in (64, 32, 16, 8) if m_pad % t == 0)
    hi = _pad_rows(hi, m_pad, 0)
    lo = _pad_rows(lo, m_pad, 0)
    w = _pad_rows(widths.astype(np.int32)[:, None], m_pad, 1)

    out = _codec_call(jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(w),
                      method=method, n=n, rpp=int(rpp), tile_m=tile_m,
                      interpret=_use_interpret())
    return np.asarray(out, dtype=np.int64)[:m, 0]

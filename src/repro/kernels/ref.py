"""Pure-jnp oracles for every Pallas kernel (the allclose reference).

Blockwise int8 quantization ("compression"): blocks of `block` consecutive
elements along the last dim share one f32 scale = absmax/127.  This is the
TPU-native analogue of the paper's page-local dictionary: the page becomes
the quantization block, the dictionary becomes the scale.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

DEFAULT_BLOCK = 128
Q_MAX = 127.0


def _pad_last(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


def quantize_blockwise(x: jnp.ndarray, block: int = DEFAULT_BLOCK
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., N) -> (q int8 (..., N), scales f32 (..., ceil(N/block)))."""
    xp, n = _pad_last(x.astype(jnp.float32), block)
    shape = xp.shape[:-1] + (xp.shape[-1] // block, block)
    blocks = xp.reshape(shape)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / Q_MAX
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -Q_MAX, Q_MAX)
    q = q.astype(jnp.int8).reshape(xp.shape)[..., :n]
    return q, scale.astype(jnp.float32)


def dequantize_blockwise(q: jnp.ndarray, scale: jnp.ndarray,
                         block: int = DEFAULT_BLOCK,
                         dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of quantize_blockwise."""
    qp, n = _pad_last(q, block)
    shape = qp.shape[:-1] + (qp.shape[-1] // block, block)
    blocks = qp.reshape(shape).astype(jnp.float32)
    out = blocks * scale[..., None]
    return out.reshape(qp.shape)[..., :n].astype(dtype)


def dequant_matmul(a: jnp.ndarray, qw: jnp.ndarray, scale: jnp.ndarray,
                   block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """a (M, K) @ dequant(qw (K, N), scale (K/block, N)) -> (M, N) f32.

    The weight stays int8 in memory; scales are per (K-block, output-col) —
    dequantization happens inside the matmul ("decompress only what the
    query reads", paper A.2).
    """
    k = qw.shape[0]
    assert k % block == 0, "K must be a multiple of block"
    w = qw.astype(jnp.float32).reshape(k // block, block, -1)
    w = w * scale[:, None, :]
    w = w.reshape(k, -1)
    return a.astype(jnp.float32) @ w


def quantize_kv(x: jnp.ndarray, block: int = DEFAULT_BLOCK):
    """KV-cache quantization: same scheme over the head dim."""
    return quantize_blockwise(x, block)

"""Batched serving engine: slot-based continuous batching over decode_step.

Requests carry a prompt; the engine prefills them into free slots of a
fixed-size batch, decodes all active slots each step, and retires slots on
EOS/max_tokens.  The KV cache codec (bf16 / q8) comes from the design
advisor's LayoutPlan — the paper's compression decision applied to the
serving "index".

q8 KV is simulated functionally on CPU: the cache stores quantized values
and the engine dequantizes on read via the kernels' ref codec (on TPU the
fused Pallas path applies).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as MD
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4
    max_len: int = 256
    kv_dtype: str = "bf16"   # "bf16" | "f32"
    greedy: bool = True


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig):
        self.cfg = cfg
        self.ec = ec
        self.params = params
        kv_dt = jnp.float32 if ec.kv_dtype == "f32" else jnp.bfloat16
        self.state = MD.init_serve_state(cfg, ec.batch_slots, ec.max_len,
                                         kv_dtype=kv_dt)
        self.slots: List[Optional[Request]] = [None] * ec.batch_slots
        self.slot_pos = np.zeros(ec.batch_slots, np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, s, t: MD.decode_step(p, s, cfg, t))
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Prefill queued requests into free slots, token by token (slot-
        isolated prefill through the shared batch decode step)."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self.slots[i] = req
            # feed the prompt through decode steps for this slot only;
            # other slots get a pad token and their outputs are ignored.
            for tok in req.prompt[:-1]:
                self._step_token(i, tok, record=False)
            self._last_token = req.prompt[-1]
            self.slot_pos[i] = len(req.prompt) - 1
            req._pending = req.prompt[-1]  # type: ignore

    def _step_token(self, slot: int, token: int, record: bool) -> int:
        toks = np.zeros((self.ec.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks))
        nxt = int(jnp.argmax(logits[slot, 0, : self.cfg.vocab]))
        return nxt

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit, decode all active slots, retire."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.ec.batch_slots, 1), np.int32)
        for i in active:
            req = self.slots[i]
            pending = getattr(req, "_pending", None)
            toks[i, 0] = pending if pending is not None else \
                req.out_tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks))
        self.steps += 1
        for i in active:
            req = self.slots[i]
            req._pending = None  # type: ignore
            nxt = int(jnp.argmax(logits[i, 0, : self.cfg.vocab]))
            req.out_tokens.append(nxt)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.finished[req.uid] = req
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            self.step()

"""Batched serving engine: slot-based continuous batching over decode_step.

Requests carry a prompt; the engine prefills them into free slots of a
fixed-size batch, decodes all active slots each step, and retires slots on
EOS (when `EngineConfig.eos_id` is set), on `max_new_tokens`, or on context
overflow (the slot's position reaching `max_len`).  The KV cache codec
(bf16 / q8) comes from the design advisor's LayoutPlan — the paper's
compression decision applied to the serving "index".

Slot isolation is the engine's core invariant: every decode — including
the per-token prefill of a newly admitted request — passes an `active`
mask to `decode_step`, so slots that are not really stepping neither
advance their KV position nor mutate recurrent state.  A request therefore
produces exactly the same tokens whether it runs alone or with requests
admitted mid-flight into neighboring slots (asserted by the regression
suite in tests/test_serve_engine.py).  Retired slots are reset before reuse
so
a new occupant never attends over its predecessor's KV entries.

q8 KV is simulated functionally on CPU: the cache stores quantized values
and the engine dequantizes on read via the kernels' ref codec (on TPU the
fused Pallas path applies).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as MD
from ..models.config import ModelConfig


class QueueFull(RuntimeError):
    """submit() on an engine whose bounded request queue is at capacity."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False  # retired on context overflow, not EOS/max_tokens
    # last prompt token, carried from prefill into the first decode step
    _pending: Optional[int] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4
    max_len: int = 256
    kv_dtype: str = "bf16"   # "bf16" | "f32"
    greedy: bool = True
    eos_id: Optional[int] = None    # retire a slot when it emits this token
    max_queue: Optional[int] = None  # submit() raises QueueFull beyond this


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig):
        self.cfg = cfg
        self.ec = ec
        self.params = params
        kv_dt = jnp.float32 if ec.kv_dtype == "f32" else jnp.bfloat16
        self.state = MD.init_serve_state(cfg, ec.batch_slots, ec.max_len,
                                         kv_dtype=kv_dt)
        self.slots: List[Optional[Request]] = [None] * ec.batch_slots
        # per-slot sequence position (== state["pos"] on the device): the
        # KV index the slot's NEXT token will be written to.  Drives the
        # context-overflow retirement check without a device readback.
        self.slot_pos = np.zeros(ec.batch_slots, np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, s, t, a: MD.decode_step(p, s, cfg, t, a))
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.ec.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens cannot fit a "
                f"max_len={self.ec.max_len} KV cache")
        if self.ec.max_queue is not None and \
                len(self.queue) >= self.ec.max_queue:
            raise QueueFull(
                f"request queue at capacity ({self.ec.max_queue})")
        self.queue.append(req)

    def _admit(self) -> None:
        """Prefill queued requests into free slots, token by token.

        Prefill runs through the shared batch decode step with an
        `active` mask naming ONLY the admitted slot, so concurrently
        decoding slots neither advance their positions nor write
        pad-token KV — admission is invisible to in-flight requests."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self.slots[i] = req
            if self.slot_pos[i]:
                # slot reuse: zero the retired occupant's position and
                # recurrent state so the new prompt starts at position 0
                # and never attends over its predecessor's KV entries
                self.state = MD.reset_slot(self.state, self.cfg, i)
                self.slot_pos[i] = 0
            for tok in req.prompt[:-1]:
                self._step_token(i, tok)
            self.slot_pos[i] = len(req.prompt) - 1
            req._pending = req.prompt[-1]

    def _step_token(self, slot: int, token: int) -> None:
        """One single-slot decode step (prefill): only `slot` is active."""
        toks = np.zeros((self.ec.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        mask = np.zeros(self.ec.batch_slots, bool)
        mask[slot] = True
        _, self.state = self._decode(self.params, self.state,
                                     jnp.asarray(toks), jnp.asarray(mask))

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit, decode all active slots, retire.

        Retirement: EOS (`ec.eos_id`, when set), `max_new_tokens`, or
        context overflow — the slot's position reaching `max_len`, where
        the next KV write would fall off the cache; overflow retirement
        marks the request `truncated`."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.ec.batch_slots, 1), np.int32)
        mask = np.zeros(self.ec.batch_slots, bool)
        for i in active:
            req = self.slots[i]
            mask[i] = True
            toks[i, 0] = req._pending if req._pending is not None else \
                req.out_tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks),
                                          jnp.asarray(mask))
        self.steps += 1
        for i in active:
            req = self.slots[i]
            req._pending = None
            self.slot_pos[i] += 1
            nxt = int(jnp.argmax(logits[i, 0, : self.cfg.vocab]))
            req.out_tokens.append(nxt)
            hit_eos = self.ec.eos_id is not None and nxt == self.ec.eos_id
            overflow = int(self.slot_pos[i]) >= self.ec.max_len
            if hit_eos or overflow or \
                    len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                req.truncated = (overflow and not hit_eos
                                 and len(req.out_tokens) < req.max_new_tokens)
                self.finished[req.uid] = req
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            self.step()

"""Multi-tenant advisor fleet service: continuous batching for sessions.

`ServeEngine` multiplexes decode slots over one model; this service
multiplexes request slots over many tenant `AdvisorSession`s.  Each
tenant owns a workload and a stream of requests (workload deltas and
`recommend` calls) submitted through an async-style queue of
Future-backed `FleetTicket`s; the service loop mirrors the repaired
serve-engine step — admit queued requests into free slots, run the
batched shared work, execute each slot, retire — with the same
admission-control surface (`QueueFull` on a bounded queue).

Cross-tenant amortization, the reason a fleet beats N independent
advisors:

* **Shared samples** — tenants are grouped by
  `samplecf.schema_fingerprint` (schema content + sample seed) and an
  estimation backend; each group owns ONE `SampleManager`, so the §4.1
  per-(table, f) sampling cost is paid once per group, not per tenant.
  Sample draws are seed-derived and order-independent (PR 4), which
  makes the sharing invisible to any single tenant.
* **Shared SampleCF cache** — each group owns one (NodeKey, f) ->
  `SizeEstimate` mapping handed to every member session
  (`AdvisorSession(sampled_cache=...)`): an index variant sized for one
  tenant is a cache hit for every other tenant on the same schema.
  With `FleetConfig.cache_entries` the mapping is a bounded LRU
  (`samplecf.EstimateCache`) — eviction only discards recomputable
  state, so long-lived fleets stay bounded without losing parity.
* **Cross-tenant batched prefetch** — before executing a step's slots,
  the service peeks every admitted recommend's estimation plan
  (`AdvisorSession.peek_estimation_plan`, memoized so the peek is free
  at recommend time), unions the group's missing (NodeKey, f) targets,
  and sizes them in one `EstimationEngine.estimate_batch` call per
  (group, f) — many tenants' targets stacked into the engine's grouped
  (ntargets, nrows) kernel batches (vmapped jax kernels on the jax
  backend, chunked NumPy otherwise).  `estimate_batch` results are
  byte-identical to the scalar `sample_cf` per target, and therefore
  independent of WHICH tenants' targets share a batch — union-batching
  is bit-exact.
* **Cross-tenant batched COST phase** (PR 8) — after the estimation
  prefetch, the service collects every admitted recommend's stale
  (query, candidates) cost jobs (`AdvisorSession.peek_cost_jobs`),
  stacks them per engine backend into padded (jobs x candidates)
  arrays, and evaluates all tenants' candidate costs in ONE
  `batched_candidate_costs` call (`backend="jax"` runs the stacked
  jit kernel).  Results are handed back via
  `AdvisorSession.accept_cost_results` (keyed by workload_version so
  stale prefetches are dropped) and consumed verbatim by the slot's
  recommend.  Bit-identical to per-slot costing on both backends:
  against a secondary-free session base every per-candidate cost is
  purely elementwise, so stacking cannot change a single bit.

Durability (the fleet's failure surface, driven by a seeded
`faults.FaultInjector` in tests and benchmarks/fault_recovery.py):

* **Deadlines** — every request carries a deadline in service STEPS
  (never wall-clock, so schedules are deterministic); an expired queued
  request resolves with `TicketTimeout`, except a recommend at the
  head of its tenant's FIFO when `degraded_budget` is set: that one
  DEGRADES instead — it runs immediately at the smaller workload-
  compression budget and returns a `Recommendation` carrying the PR 5
  error certificate (`ticket.degraded` is True) rather than failing.
* **Retries** — a request failing with a transient `FaultError` is
  requeued at the front of the queue (preserving its tenant's FIFO)
  with a deterministic step-based backoff (`retry_backoff`); retries
  are bit-exact because every faulted call fails BEFORE mutating
  session state.
* **Circuit breaker + checkpoint restore** — `quarantine_after`
  consecutive final failures quarantine the tenant: its session is
  dropped, queued tickets resolve with `TenantQuarantined`, submits are
  rejected.  After `quarantine_steps` (or `readmit_tenant`) the tenant
  is restored from its last checkpoint (`AdvisorSession.restore`; a
  snapshot is taken after every successful delta, so the checkpoint
  always equals the tenant's current workload) and its next
  recommendation is exactly `==` a fresh `DesignAdvisor` — the parity
  contract extended to crash recovery.  `crash_tenant` simulates
  process loss for tests/benchmarks.
* **Durable crash recovery** (PR 10) — construct the fleet with
  `store=DurableStore(dir)` and every admitted delta is journaled to
  the tenant's write-ahead log BEFORE it touches the session (a delta
  that then fails to apply is compensated with an ABORT record, so
  replay can never apply it), with the store compacting the WAL into an
  atomically-rotated snapshot manifest when the log suffix exceeds its
  threshold.  After real process death,
  `AdvisorFleetService.recover(dir)` rebuilds the entire fleet — per
  tenant: latest valid snapshot, replay of the WAL suffix — and every
  recovered tenant's next recommendation is exactly `==` a fresh
  `DesignAdvisor` on the recovered workload.  Torn WAL tails are
  truncated at the last valid record; mid-log corruption (e.g. an
  injected `bit_flip`) quarantines only that tenant, on its last valid
  prefix, via the same `TenantQuarantined` path — recovery itself never
  fails the fleet.  Recovery errors are kept in
  `fleet.recovery_errors`, and the store's durability counters
  (`wal_appends`/`fsyncs`/`compactions`/`recoveries`/
  `torn_tail_truncations`) surface through `stats`.

Correctness contract (asserted in tests/test_fleet_service.py and every
round of benchmarks/fleet_scaling.py + fault_recovery.py): after any
interleaved sequence of per-tenant deltas and recommends — including
injected faults, evictions, timeouts and crash/restore cycles — each
tenant's successful recommendation is exactly `==` — config, cost,
used_bytes — a fresh `DesignAdvisor` built on that tenant's current
workload.

Budget isolation: every tenant carries a `TenantBudget` — a workload
size cap enforced before any delta is applied, a pending-request cap
enforced at submit time, and an optional per-tenant workload-compression
budget overriding the shared options — so one noisy tenant can neither
starve the queue nor grow without bound.  Request failures (bad deltas,
budget violations) resolve that tenant's ticket with the exception and
leave every other slot untouched.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, List, Optional, Tuple

from ..core.advisor import AdvisorOptions
from ..core.cost_engine import batched_candidate_costs
from ..core.durability import DurableStore, RecoveredTenant
from ..core.estimation_engine import EstimationEngine
from ..core.estimation_graph import NodeKey, State
from ..core.faults import FaultError, FaultInjector
from ..core.samplecf import (EstimateCache, SampleManager, SizeEstimate,
                             schema_fingerprint)
from ..core.session import AdvisorSession, SessionSnapshot
from ..core.whatif import base_configuration
from ..core.workload import Workload, WorkloadDelta
from .engine import QueueFull


class TenantBudgetExceeded(RuntimeError):
    """A delta would grow a tenant's workload past its budget cap."""


class TicketTimeout(RuntimeError):
    """A request exceeded its deadline (service steps) or a ticket's
    `result()` wait exceeded its wall-clock timeout."""


class TenantQuarantined(RuntimeError):
    """The tenant is quarantined by the circuit breaker: queued tickets
    resolve with this, and new submits are rejected until readmission."""


class SessionLost(RuntimeError):
    """The tenant's session is gone (crashed) and not yet restored."""


class DrainStalled(RuntimeError):
    """`run_until_drained` hit its step budget with work still queued.

    Carries `queued` (total undrained requests) and `pending_by_tenant`
    (tenant id -> queued request count) so callers can see WHO is stuck
    instead of silently losing work."""

    def __init__(self, msg: str, queued: int,
                 pending_by_tenant: Dict[str, int]):
        super().__init__(msg)
        self.queued = queued
        self.pending_by_tenant = dict(pending_by_tenant)


@dataclasses.dataclass
class TenantBudget:
    """Per-tenant isolation limits.

    `max_statements` caps the tenant's workload size — checked against
    the post-delta size BEFORE the delta touches the session, so a
    violating delta fails cleanly and leaves the workload unchanged.
    `max_pending` caps the tenant's queued + in-flight requests at
    submit time (`QueueFull`).  `compression_budget` overrides the
    tenant options' workload-compression budget (outer-mode sessions).
    """
    max_statements: Optional[int] = None
    max_pending: Optional[int] = None
    compression_budget: Optional[int] = None


@dataclasses.dataclass
class FleetConfig:
    slots: int = 8                    # tenant requests executed per step
    max_queue: Optional[int] = None   # global bound; submit raises QueueFull
    prefetch: bool = True             # cross-tenant batched SampleCF prefetch
    backend: str = "numpy"            # prefetch engine backend
    # --- durability ---------------------------------------------------
    cache_entries: Optional[int] = None   # bound each group's SampleCF cache
    deadline_steps: Optional[int] = None  # default per-request deadline
    retry_backoff: Tuple[int, ...] = (1, 2, 4)  # step delays; len = retries
    quarantine_after: Optional[int] = 3   # consecutive final failures
    quarantine_steps: Optional[int] = None  # auto-readmit cooldown (steps)
    degraded_budget: Optional[int] = None  # deadline-pressure fallback


class FleetTicket:
    """Future-backed handle for one submitted request.

    `result()` blocks until the service loop retires the request; for a
    recommend it returns the `Recommendation`, for a delta a small
    summary dict.  Failures (invalid delta, `TenantBudgetExceeded`,
    `TicketTimeout`, `TenantQuarantined`) surface through
    `exception()` / a raising `result()`.  `result()` defaults to a
    `DEFAULT_TIMEOUT`-second deadline so a stopped service loop shows
    up as a clear `TicketTimeout` naming the tenant and request kind,
    not a forever-blocked caller; pass an explicit timeout (or None
    via `result(timeout=float("inf"))`) to override."""

    DEFAULT_TIMEOUT: float = 300.0

    def __init__(self, tenant_id: str, kind: str):
        self.tenant_id = tenant_id
        self.kind = kind              # "delta" | "recommend"
        self.submitted_at = time.perf_counter()
        self.resolved_at: Optional[float] = None
        self.degraded = False         # resolved via the degraded path
        self.attempts = 0             # execution attempts (retries + 1)
        self.prefetch_error: Optional[BaseException] = None
        self._future: Future = Future()

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None):
        t = self.DEFAULT_TIMEOUT if timeout is None else timeout
        try:
            return self._future.result(t)
        except FutureTimeout:
            raise TicketTimeout(
                f"tenant {self.tenant_id!r} {self.kind} ticket unresolved "
                f"after {t}s — is the service loop (step() / "
                f"run_until_drained()) still running?") from None

    def exception(self, timeout: Optional[float] = None):
        t = self.DEFAULT_TIMEOUT if timeout is None else timeout
        try:
            return self._future.exception(t)
        except FutureTimeout:
            raise TicketTimeout(
                f"tenant {self.tenant_id!r} {self.kind} ticket unresolved "
                f"after {t}s — is the service loop (step() / "
                f"run_until_drained()) still running?") from None

    @property
    def latency(self) -> Optional[float]:
        """submit -> resolve wall seconds (None while pending)."""
        if self.resolved_at is None:
            return None
        return self.resolved_at - self.submitted_at

    def _resolve(self, value=None, error: Optional[BaseException] = None
                 ) -> None:
        self.resolved_at = time.perf_counter()
        if error is not None:
            self._future.set_exception(error)
        else:
            self._future.set_result(value)


@dataclasses.dataclass
class _FleetRequest:
    tenant_id: str
    kind: str                             # "delta" | "recommend"
    ticket: FleetTicket
    delta: Optional[WorkloadDelta] = None
    budget_bytes: Optional[float] = None
    submitted_step: int = 0               # service step at submit
    deadline_steps: Optional[int] = None  # None: no deadline
    attempts: int = 0                     # failed transient attempts so far
    not_before: int = 0                   # retry backoff: earliest step


class _ShareGroup:
    """One (schema fingerprint, backend) equivalence class of tenants:
    a shared order-independent SampleManager, a shared (NodeKey, f)
    SampleCF cache (bounded LRU when the fleet config asks), and the
    batched estimation engine the prefetch stacks the group's targets
    into."""

    def __init__(self, key: Tuple[str, str], tables: Dict, seed: int,
                 backend: str, cache_entries: Optional[int] = None):
        self.key = key
        self.samples = SampleManager(tables, seed=seed)
        self.cache: Dict[Tuple[NodeKey, float], SizeEstimate] = (
            EstimateCache(cache_entries) if cache_entries is not None
            else {})
        self.engine = EstimationEngine(tables, self.samples,
                                       backend=backend)
        self.n_tenants = 0


@dataclasses.dataclass
class _Tenant:
    tenant_id: str
    session: Optional[AdvisorSession]
    budget: TenantBudget
    # None only for a recovered "husk": the durable snapshot itself was
    # unreadable, so there is no schema to attach a share group to
    group: Optional[_ShareGroup]
    snapshot: Optional[SessionSnapshot] = None  # last good checkpoint
    in_flight: Optional[_FleetRequest] = None
    n_pending: int = 0                # queued + in-flight requests
    deltas_applied: int = 0
    recommends: int = 0
    consecutive_failures: int = 0     # final (post-retry) failures in a row
    quarantined_at: Optional[int] = None  # step of quarantine, None: healthy
    quarantines: int = 0
    restores: int = 0


class AdvisorFleetService:
    """Slot-based continuous batching over many tenant AdvisorSessions.

    Usage::

        fleet = AdvisorFleetService(FleetConfig(slots=16))
        fleet.register_tenant("t0", workload0, options)
        fleet.register_tenant("t1", workload1, options)   # same schema:
                                                          # shares samples
        fleet.submit_delta("t0", WorkloadDelta(added=(...,)))
        t = fleet.submit_recommend("t0", budget_bytes=2e6)
        fleet.run_until_drained()
        rec = t.result()          # == fresh DesignAdvisor on t0's workload
    """

    def __init__(self, fc: Optional[FleetConfig] = None,
                 faults: Optional[FaultInjector] = None,
                 store: Optional[DurableStore] = None):
        self.fc = fc or FleetConfig()
        if self.fc.slots < 1:
            raise ValueError("need at least one slot")
        # one injector threads the whole stack: sessions check
        # "apply_delta"/"estimation"/"costing" (and their planners
        # "planner_replay"); the service itself checks "prefetch"; the
        # durable store checks "disk_write"/"fsync"/"bit_flip"
        self.faults = faults
        self.store = store
        if store is not None and store.faults is None:
            store.faults = faults
        # tenant id -> the exception that degraded its recovery (mid-log
        # corruption, unreadable snapshot, replay failure); such tenants
        # come back quarantined on their last valid durable prefix
        self.recovery_errors: Dict[str, BaseException] = {}
        self.tenants: Dict[str, _Tenant] = {}
        self.groups: Dict[Tuple[str, str], _ShareGroup] = {}
        self.queue: List[_FleetRequest] = []          # global arrival order
        self.slots: List[Optional[_FleetRequest]] = [None] * self.fc.slots
        self.steps = 0
        self.retired = 0
        self.prefetch_batches = 0     # (group, f) batched prefetch calls
        self.prefetch_targets = 0     # targets sized by the prefetch
        self.prefetch_hits = 0        # peeked targets already cached
        self.prefetch_failures = 0    # peeks/batches that raised
        self.cost_prefetch_batches = 0  # cross-tenant stacked COST batches
        self.cost_prefetch_jobs = 0     # (tenant, query) jobs so scored
        self.retries = 0              # transient failures requeued
        self.timeouts = 0             # requests expired by their deadline
        self.degraded_recommends = 0  # deadline recommends served degraded
        self.failures = 0             # final (post-retry) request failures
        self.quarantines = 0
        self.restores = 0
        self.restore_seconds: List[float] = []  # per-restore wall time

    # ------------------------------------------------------------------
    # Tenants
    # ------------------------------------------------------------------
    def register_tenant(self, tenant_id: str, workload: Workload,
                        options: Optional[AdvisorOptions] = None,
                        budget: Optional[TenantBudget] = None) -> None:
        if tenant_id in self.tenants:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        opt = options or AdvisorOptions()
        budget = budget or TenantBudget()
        if budget.compression_budget is not None:
            opt = dataclasses.replace(
                opt, compression_budget=budget.compression_budget)
        if budget.max_statements is not None and \
                len(workload.statements) > budget.max_statements:
            raise TenantBudgetExceeded(
                f"tenant {tenant_id!r}: initial workload of "
                f"{len(workload.statements)} statements exceeds "
                f"max_statements={budget.max_statements}")
        group = self._group_for(workload.schema, opt)
        group.n_tenants += 1
        session = AdvisorSession(workload, opt, samples=group.samples,
                                 sampled_cache=group.cache,
                                 faults=self.faults)
        t = _Tenant(tenant_id, session, budget, group)
        # checkpoint from birth: a tenant crashing before its first
        # successful delta still restores to its registered workload.
        # Estimates are excluded — restore re-attaches the share-group
        # cache, which survives the session (copying it per tenant per
        # checkpoint would duplicate the whole shared cache).
        t.snapshot = session.snapshot(include_estimates=False)
        if self.store is not None:
            self.store.register(tenant_id, t.snapshot.to_bytes(),
                                meta=budget)
        self.tenants[tenant_id] = t

    def _group_for(self, schema, opt: AdvisorOptions) -> _ShareGroup:
        """The tenant's share group — one per (schema fingerprint,
        estimation backend), created on first use."""
        key = (schema_fingerprint(schema, opt.sample_seed),
               opt.estimation_backend)
        group = self.groups.get(key)
        if group is None:
            group = self.groups[key] = _ShareGroup(
                key, schema.tables, opt.sample_seed,
                self.fc.backend, self.fc.cache_entries)
        return group

    def crash_tenant(self, tenant_id: str) -> None:
        """Simulate process loss of one tenant's session: the session is
        dropped and the tenant quarantined (queued tickets resolve with
        `TenantQuarantined`).  Recovery is the normal readmission path —
        checkpoint restore via `readmit_tenant` or the
        `quarantine_steps` cooldown."""
        t = self.tenants[tenant_id]
        if t.quarantined_at is None:
            self._quarantine(t, "session crashed (injected)")

    def readmit_tenant(self, tenant_id: str) -> None:
        """Restore a quarantined tenant from its last checkpoint.  The
        restored session re-attaches the share group's SampleManager and
        SampleCF cache; its next recommendation is exactly `==` a fresh
        `DesignAdvisor` on the checkpoint workload."""
        t = self.tenants[tenant_id]
        if t.quarantined_at is None:
            raise ValueError(f"tenant {tenant_id!r} is not quarantined")
        if t.snapshot is None or t.group is None:
            raise SessionLost(
                f"tenant {tenant_id!r} has no restorable checkpoint "
                "(its durable snapshot was unreadable at recovery); "
                "re-register it with a fresh workload")
        t0 = time.perf_counter()
        t.session = AdvisorSession.restore(
            t.snapshot, samples=t.group.samples,
            sampled_cache=t.group.cache, faults=self.faults)
        self.restore_seconds.append(time.perf_counter() - t0)
        if self.store is not None:
            # realign the durable state with the checkpoint we just
            # restored to: a corrupt/poisoned WAL suffix must not be
            # replayed on top of it at the next recovery
            self.store.checkpoint(tenant_id, t.snapshot.to_bytes(),
                                  meta=t.budget)
        self.recovery_errors.pop(tenant_id, None)
        t.quarantined_at = None
        t.consecutive_failures = 0
        t.restores += 1
        self.restores += 1

    # ------------------------------------------------------------------
    # Durable recovery (after real process death)
    # ------------------------------------------------------------------
    @classmethod
    def recover(cls, store_or_dir,
                fc: Optional[FleetConfig] = None,
                faults: Optional[FaultInjector] = None
                ) -> "AdvisorFleetService":
        """Rebuild a fleet from a durable store directory: per tenant,
        restore the latest valid snapshot manifest and replay the WAL
        suffix of journaled-but-uncheckpointed deltas.  Every cleanly
        recovered tenant's next recommendation is exactly `==` a fresh
        `DesignAdvisor` on the recovered workload.  Degraded tenants —
        mid-log corruption, unreadable snapshot, a replay failure —
        come back QUARANTINED on their last valid durable prefix
        (`recovery_errors[tenant_id]` holds why) instead of failing the
        fleet; `readmit_tenant` restores them from that prefix."""
        store = (store_or_dir if isinstance(store_or_dir, DurableStore)
                 else DurableStore(store_or_dir))
        fleet = cls(fc=fc, faults=faults, store=store)
        recovered = store.recover()
        for tid in sorted(recovered):
            fleet._recover_tenant(recovered[tid])
        return fleet

    def _recover_tenant(self, rt: RecoveredTenant) -> None:
        tid = rt.tenant_id
        budget = (rt.meta if isinstance(rt.meta, TenantBudget)
                  else TenantBudget())
        error: Optional[BaseException] = rt.error
        snap: Optional[SessionSnapshot] = None
        if rt.snapshot_bytes is not None:
            try:
                snap = SessionSnapshot.from_bytes(rt.snapshot_bytes)
            except Exception as e:
                error = error or e
        if snap is None:
            # unrecoverable husk: with no readable snapshot there is no
            # schema, no share group, nothing to replay onto — keep the
            # tenant visible (quarantined, submits rejected) so the
            # loss is observable rather than silent
            t = _Tenant(tid, None, budget, None)
            self.tenants[tid] = t
            err = error or SessionLost(
                f"tenant {tid!r}: no readable durable snapshot")
            self.recovery_errors[tid] = err
            self._quarantine(t, f"recovery failed: {err}")
            return
        t0 = time.perf_counter()
        group = self._group_for(snap.workload.schema, snap.options)
        session: Optional[AdvisorSession] = None
        try:
            # replay with fault injection OFF: recovery re-applies
            # already-admitted work, and a storm firing mid-replay would
            # turn deterministic history into a coin flip
            session = AdvisorSession.restore(
                snap, samples=group.samples, sampled_cache=group.cache,
                faults=None)
            for delta in rt.deltas:
                try:
                    session.apply(delta)
                except Exception as e:
                    # almost always the final record: a delta journaled
                    # by the write-ahead rule but never validated by an
                    # apply before the crash.  Keep the state up to it.
                    error = error or e
                    break
        except Exception as e:
            error = error or e
        self.restore_seconds.append(time.perf_counter() - t0)
        if session is None:
            t = _Tenant(tid, None, budget, None)
            self.tenants[tid] = t
            self.recovery_errors[tid] = error
            self._quarantine(t, f"recovery failed: {error}")
            return
        group.n_tenants += 1
        t = _Tenant(tid, session, budget, group)
        t.snapshot = session.snapshot(include_estimates=False)
        self.tenants[tid] = t
        if error is not None:
            self.recovery_errors[tid] = error
            # the durable log is poisoned past this prefix — realign it
            # with the recovered state so the next crash replays cleanly
            self.store.checkpoint(tid, t.snapshot.to_bytes(), meta=budget)
            self._quarantine(t, f"recovery degraded: {error}")
            return
        session.faults = self.faults

    # ------------------------------------------------------------------
    # Submission (admission control)
    # ------------------------------------------------------------------
    def _submit(self, req: _FleetRequest,
                deadline_steps: Optional[int]) -> FleetTicket:
        t = self.tenants[req.tenant_id]
        if t.quarantined_at is not None:
            raise TenantQuarantined(
                f"tenant {req.tenant_id!r} is quarantined (since step "
                f"{t.quarantined_at}); readmit_tenant() or wait for the "
                "cooldown")
        if self.fc.max_queue is not None and \
                len(self.queue) >= self.fc.max_queue:
            raise QueueFull(
                f"fleet queue at capacity ({self.fc.max_queue})")
        if t.budget.max_pending is not None and \
                t.n_pending >= t.budget.max_pending:
            raise QueueFull(
                f"tenant {req.tenant_id!r} at max_pending="
                f"{t.budget.max_pending}")
        req.submitted_step = self.steps
        req.deadline_steps = (deadline_steps if deadline_steps is not None
                              else self.fc.deadline_steps)
        t.n_pending += 1
        self.queue.append(req)
        return req.ticket

    def submit_delta(self, tenant_id: str, delta: WorkloadDelta,
                     deadline_steps: Optional[int] = None) -> FleetTicket:
        return self._submit(_FleetRequest(
            tenant_id, "delta", FleetTicket(tenant_id, "delta"),
            delta=delta), deadline_steps)

    def submit_recommend(self, tenant_id: str, budget_bytes: float,
                         deadline_steps: Optional[int] = None
                         ) -> FleetTicket:
        return self._submit(_FleetRequest(
            tenant_id, "recommend", FleetTicket(tenant_id, "recommend"),
            budget_bytes=float(budget_bytes)), deadline_steps)

    # ------------------------------------------------------------------
    # Service loop (mirrors ServeEngine: admit -> batch -> execute ->
    # retire)
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Fill free slots from the queue in arrival order, at most one
        in-flight request per tenant so each tenant's requests execute
        in its own submission order (per-tenant FIFO).  Requests backing
        off after a transient failure (`not_before`) are skipped until
        their step comes up — and BLOCK their tenant's later requests
        meanwhile, or the backoff would reorder that tenant's stream."""
        for i in range(len(self.slots)):
            if self.slots[i] is not None:
                continue
            blocked = {tid for tid, t in self.tenants.items()
                       if t.in_flight is not None}
            for qi, req in enumerate(self.queue):
                if req.tenant_id in blocked:
                    continue
                if req.not_before > self.steps:
                    blocked.add(req.tenant_id)
                    continue
                self.queue.pop(qi)
                self.slots[i] = req
                self.tenants[req.tenant_id].in_flight = req
                break
            else:
                break  # nothing admissible for this (or any later) slot

    def _expire(self) -> None:
        """Resolve queued requests that outlived their deadline.

        Deadlines are measured in service STEPS since submission (the
        retry backoff shares the clock), so expiry is deterministic.  An
        expired recommend at the head of its tenant's FIFO degrades when
        `degraded_budget` is configured; everything else resolves with
        `TicketTimeout`."""
        if not any(r.deadline_steps is not None for r in self.queue):
            return
        kept: List[_FleetRequest] = []
        has_earlier = set()   # tenants with a surviving earlier request
        for req in self.queue:
            dl = req.deadline_steps
            waited = self.steps - req.submitted_step
            if dl is None or waited < dl:
                kept.append(req)
                has_earlier.add(req.tenant_id)
                continue
            t = self.tenants[req.tenant_id]
            if (req.kind == "recommend"
                    and self.fc.degraded_budget is not None
                    and req.tenant_id not in has_earlier
                    and t.session is not None):
                self._execute_degraded(req, t)
            else:
                req.ticket._resolve(error=TicketTimeout(
                    f"tenant {req.tenant_id!r} {req.kind} request "
                    f"exceeded its deadline of {dl} service steps "
                    f"(waited {waited})"))
                self.timeouts += 1
            t.n_pending -= 1
            self.retired += 1
        self.queue = kept

    def _execute_degraded(self, req: _FleetRequest, t: _Tenant) -> None:
        """Deadline-pressure fallback: serve the recommend NOW from a
        one-shot session at the smaller `degraded_budget` workload-
        compression budget.  The result is exact for that budget (`==` a
        fresh DesignAdvisor with the same option) and carries the PR 5
        error certificate quantifying the approximation to the
        full-budget answer; `ticket.degraded` marks it."""
        assert t.session is not None and req.budget_bytes is not None
        try:
            opt = dataclasses.replace(
                t.session.opt, compression_budget=self.fc.degraded_budget)
            deg = AdvisorSession(t.session.workload, opt,
                                 samples=t.group.samples,
                                 sampled_cache=t.group.cache)
            rec = deg.recommend(req.budget_bytes)
            req.ticket.degraded = True
            t.recommends += 1
            t.consecutive_failures = 0
            self.degraded_recommends += 1
            req.ticket._resolve(rec)
        except BaseException as e:
            self._final_failure(req, t, e)

    def _prefetch(self) -> None:
        """Union-batch the admitted recommends' missing SampleCF targets.

        For every admitted recommend, peek the tenant's estimation plan
        (memoized — the subsequent recommend reuses it verbatim), take
        its SAMPLED nodes not yet in the group cache, and size each
        (group, f) union in ONE `estimate_batch` call.  Per-target
        results are byte-identical to the scalar path, so cache content
        does not depend on which tenants were batched together.

        A failed peek or batch is counted in `prefetch_failures` and
        attached to the affected tickets (`ticket.prefetch_error`) —
        never swallowed silently.  It is NOT fatal: the prefetch is a
        pure warm-up, so the slot's recommend recomputes (or re-raises,
        for session faults) on its own."""
        missing: Dict[Tuple[Tuple[str, str], float], List[NodeKey]] = {}
        seen: Dict[Tuple[Tuple[str, str], float], set] = {}
        contributors: Dict[Tuple[Tuple[str, str], float],
                           List[FleetTicket]] = {}
        for req in self.slots:
            if req is None or req.kind != "recommend":
                continue
            t = self.tenants[req.tenant_id]
            if t.session is None:
                continue
            try:
                plan = t.session.peek_estimation_plan()
            except Exception as e:
                self.prefetch_failures += 1
                req.ticket.prefetch_error = e
                continue  # the slot's recommend surfaces/retries it
            if plan is None:
                continue
            gk = (t.group.key, plan.f)
            contributors.setdefault(gk, []).append(req.ticket)
            got = seen.setdefault(gk, set())
            for k, node in plan.nodes.items():
                if node.state is not State.SAMPLED or k in got:
                    continue
                got.add(k)
                if (k, plan.f) in t.group.cache:
                    self.prefetch_hits += 1
                else:
                    missing.setdefault(gk, []).append(k)
        for (group_key, f), keys in missing.items():
            group = self.groups[group_key]
            try:
                if self.faults is not None:
                    self.faults.check(
                        "prefetch", f"batch of {len(keys)} at f={f}")
                ests = group.engine.estimate_batch(keys, f)
            except Exception as e:
                self.prefetch_failures += 1
                for tk in contributors.get((group_key, f), ()):
                    tk.prefetch_error = e
                continue  # recommends fall back to per-session estimation
            for k, est in ests.items():
                group.cache[(k, f)] = est
            self.prefetch_batches += 1
            self.prefetch_targets += len(keys)

    def _cost_prefetch(self) -> None:
        """Stack the admitted recommends' stale per-query costing jobs
        into cross-tenant (tenant x statement x candidate) batches, one
        per engine backend — the fleet COST phase.

        Each tenant's `peek_cost_jobs()` runs its estimation stage once
        (memoized by workload version; the slot's recommend reuses it
        verbatim) and exposes the queries whose §6.1 selections need
        re-costing; `batched_candidate_costs` then scores every tenant's
        jobs in one stacked pass with exactly the per-job arithmetic
        (bitwise on numpy, the same jit'd float32 kernel on jax), and
        results flow back through `accept_cost_results`, keyed by
        workload version so a stale batch is simply dropped.  Like
        `_prefetch`, a failure is counted and attached to the ticket but
        never fatal — the recommend recomputes on its own."""
        by_backend: Dict[str, List] = {}
        for req in self.slots:
            if req is None or req.kind != "recommend":
                continue
            t = self.tenants[req.tenant_id]
            s = t.session
            if s is None:
                continue
            try:
                jobs = s.peek_cost_jobs()
                if not jobs:
                    continue
                base = base_configuration(s.schema)
                rows = [(q.name, s.engine.cost_job_arrays(q, base, cands))
                        for q, cands in jobs]
            except Exception as e:
                self.prefetch_failures += 1
                req.ticket.prefetch_error = e
                continue  # the slot's recommend surfaces/retries it
            by_backend.setdefault(s.engine.backend, []).append(
                (s, s.workload_version, rows, req.ticket))
        for backend, entries in by_backend.items():
            flat = [arrays for (_, _, rows, _) in entries
                    for (_, arrays) in rows]
            try:
                costs = batched_candidate_costs(flat, backend=backend)
            except Exception as e:
                self.prefetch_failures += 1
                for (_, _, _, tk) in entries:
                    tk.prefetch_error = e
                continue
            k = 0
            for s, ver, rows, _ in entries:
                res = {}
                for qname, arrays in rows:
                    res[qname] = costs[k, :len(arrays["cov"])]
                    k += 1
                s.accept_cost_results(ver, res)
                self.cost_prefetch_jobs += len(rows)
            self.cost_prefetch_batches += 1

    def _final_failure(self, req: _FleetRequest, t: _Tenant,
                       e: BaseException) -> None:
        """Resolve a request with its (post-retry) error and feed the
        tenant's circuit breaker."""
        req.ticket._resolve(error=e)
        t.consecutive_failures += 1
        self.failures += 1
        if (t.quarantined_at is None
                and self.fc.quarantine_after is not None
                and t.consecutive_failures >= self.fc.quarantine_after):
            self._quarantine(
                t, f"{t.consecutive_failures} consecutive failures "
                f"(last: {type(e).__name__}: {e})")

    def _quarantine(self, t: _Tenant, reason: str) -> None:
        """Circuit breaker: isolate the tenant from its share group —
        drop the (possibly poisoned) session, flush its queued requests
        with `TenantQuarantined`, reject new submits — until checkpoint
        restore readmits it."""
        t.quarantined_at = self.steps
        t.quarantines += 1
        self.quarantines += 1
        t.session = None
        mine = [r for r in self.queue if r.tenant_id == t.tenant_id]
        self.queue = [r for r in self.queue if r.tenant_id != t.tenant_id]
        for r in mine:
            r.ticket._resolve(error=TenantQuarantined(
                f"tenant {t.tenant_id!r} quarantined at step "
                f"{t.quarantined_at}: {reason}"))
            t.n_pending -= 1
            self.retired += 1

    def _execute(self, req: _FleetRequest) -> bool:
        """Run one slot's request.  Returns True when the request is
        retired (resolved either way), False when it was requeued for a
        deterministic-backoff retry after a transient `FaultError`."""
        t = self.tenants[req.tenant_id]
        req.attempts += 1
        req.ticket.attempts = req.attempts
        try:
            if t.session is None:
                raise SessionLost(
                    f"tenant {req.tenant_id!r} has no live session")
            if req.kind == "delta":
                assert req.delta is not None
                cap = t.budget.max_statements
                if cap is not None:
                    projected = (len(t.session.workload.statements)
                                 + len(req.delta.added)
                                 - len(req.delta.removed))
                    if projected > cap:
                        raise TenantBudgetExceeded(
                            f"tenant {req.tenant_id!r}: delta would grow "
                            f"the workload to {projected} statements "
                            f"(max_statements={cap})")
                if self.store is None:
                    t.session.apply(req.delta)
                else:
                    # write-ahead: journal the admitted delta BEFORE it
                    # touches the session.  A failed apply is
                    # compensated with an ABORT record so recovery can
                    # never replay a delta the live fleet rejected.
                    seq = self.store.log_delta(req.tenant_id, req.delta)
                    try:
                        t.session.apply(req.delta)
                    except BaseException:
                        self.store.log_abort(req.tenant_id, seq)
                        raise
                t.deltas_applied += 1
                # checkpoint AFTER every successful delta: the snapshot
                # always equals the live workload (failed deltas never
                # mutate), so a later crash restores to current state
                t.snapshot = t.session.snapshot(include_estimates=False)
                if self.store is not None:
                    self.store.maybe_compact(
                        req.tenant_id, t.snapshot.to_bytes,
                        meta=t.budget)
                t.consecutive_failures = 0
                req.ticket._resolve({
                    "applied": True,
                    "workload_version": t.session.workload_version,
                    "n_statements": len(t.session.workload.statements)})
            else:
                assert req.budget_bytes is not None
                rec = t.session.recommend(req.budget_bytes)
                t.recommends += 1
                t.consecutive_failures = 0
                req.ticket._resolve(rec)
        except BaseException as e:      # isolate failures to this tenant
            if isinstance(e, FaultError) and \
                    req.attempts <= len(self.fc.retry_backoff):
                # transient: requeue at the FRONT (this is the tenant's
                # oldest request, so front-insertion preserves both its
                # own FIFO and fairness to other tenants' older work)
                req.not_before = (self.steps + 1
                                  + self.fc.retry_backoff[req.attempts - 1])
                self.queue.insert(0, req)
                self.retries += 1
                return False
            self._final_failure(req, t, e)
        return True

    def step(self) -> None:
        """One service iteration: readmit cooled-down tenants, expire
        overdue requests, admit queued requests into free slots, run the
        cross-tenant batched prefetch over the admitted recommends,
        execute every slot, retire (a request is one unit of work, so
        slots turn over every step).  `steps` advances every call —
        also on idle ticks — because the retry backoff and quarantine
        cooldown measure time in steps."""
        if self.fc.quarantine_steps is not None:
            for t in self.tenants.values():
                if t.quarantined_at is not None and \
                        self.steps - t.quarantined_at >= \
                        self.fc.quarantine_steps:
                    self.readmit_tenant(t.tenant_id)
        self._expire()
        self._admit()
        if any(s is not None for s in self.slots):
            if self.fc.prefetch:
                self._prefetch()
                self._cost_prefetch()
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                retired = self._execute(req)
                t = self.tenants[req.tenant_id]
                t.in_flight = None
                self.slots[i] = None
                if retired:
                    t.n_pending -= 1
                    self.retired += 1
        self.steps += 1

    def run_until_drained(self, max_steps: int = 1_000_000) -> None:
        """Step until the queue is empty, or raise `DrainStalled` after
        `max_steps` steps THIS CALL (never silently return with work
        still queued)."""
        for _ in range(max_steps):
            if not self.queue:
                return
            self.step()
        if self.queue:
            pending: Dict[str, int] = {}
            for r in self.queue:
                pending[r.tenant_id] = pending.get(r.tenant_id, 0) + 1
            raise DrainStalled(
                f"drain stalled after {max_steps} steps with "
                f"{len(self.queue)} requests queued "
                f"(per tenant: {pending})", len(self.queue), pending)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, float]:
        out = {
            "tenants": len(self.tenants),
            "groups": len(self.groups),
            "queued": len(self.queue),
            "steps": self.steps,
            "retired": self.retired,
            "prefetch_batches": self.prefetch_batches,
            "prefetch_targets": self.prefetch_targets,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_failures": self.prefetch_failures,
            "cost_prefetch_batches": self.cost_prefetch_batches,
            "cost_prefetch_jobs": self.cost_prefetch_jobs,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "degraded_recommends": self.degraded_recommends,
            "failures": self.failures,
            "quarantines": self.quarantines,
            "restores": self.restores,
            "quarantined_tenants": sum(
                1 for t in self.tenants.values()
                if t.quarantined_at is not None),
        }
        # durability counters (all zero for a store-less fleet)
        ds = self.store.stats() if self.store is not None else {}
        for k in ("wal_appends", "wal_aborts", "fsyncs", "compactions",
                  "recoveries", "torn_tail_truncations"):
            out[k] = ds.get(k, 0)
        out["recovery_errors"] = len(self.recovery_errors)
        out["shared_cache_entries"] = sum(
            len(g.cache) for g in self.groups.values())
        out["shared_cache_evictions"] = sum(
            g.cache.evictions for g in self.groups.values()
            if isinstance(g.cache, EstimateCache))
        out["sampling_calls"] = sum(
            g.samples.sampling_calls for g in self.groups.values())
        return out

    def tenant_stats(self, tenant_id: str) -> Dict[str, float]:
        t = self.tenants[tenant_id]
        out = dict(t.session.stats) if t.session is not None else {}
        out.update(deltas_applied=t.deltas_applied,
                   recommends=t.recommends,
                   consecutive_failures=t.consecutive_failures,
                   quarantined=t.quarantined_at is not None,
                   quarantines=t.quarantines,
                   restores=t.restores,
                   group_tenants=(t.group.n_tenants
                                  if t.group is not None else 0))
        if t.session is not None:
            out["n_statements"] = len(t.session.workload.statements)
        return out

"""Multi-tenant advisor fleet service: continuous batching for sessions.

`ServeEngine` multiplexes decode slots over one model; this service
multiplexes request slots over many tenant `AdvisorSession`s.  Each
tenant owns a workload and a stream of requests (workload deltas and
`recommend` calls) submitted through an async-style queue of
Future-backed `FleetTicket`s; the service loop mirrors the repaired
serve-engine step — admit queued requests into free slots, run the
batched shared work, execute each slot, retire — with the same
admission-control surface (`QueueFull` on a bounded queue).

Cross-tenant amortization, the reason a fleet beats N independent
advisors:

* **Shared samples** — tenants are grouped by
  `samplecf.schema_fingerprint` (schema content + sample seed) and an
  estimation backend; each group owns ONE `SampleManager`, so the §4.1
  per-(table, f) sampling cost is paid once per group, not per tenant.
  Sample draws are seed-derived and order-independent (PR 4), which
  makes the sharing invisible to any single tenant.
* **Shared SampleCF cache** — each group owns one (NodeKey, f) ->
  `SizeEstimate` dict handed to every member session
  (`AdvisorSession(sampled_cache=...)`): an index variant sized for one
  tenant is a cache hit for every other tenant on the same schema.
* **Cross-tenant batched prefetch** — before executing a step's slots,
  the service peeks every admitted recommend's estimation plan
  (`AdvisorSession.peek_estimation_plan`, memoized so the peek is free
  at recommend time), unions the group's missing (NodeKey, f) targets,
  and sizes them in one `EstimationEngine.estimate_batch` call per
  (group, f) — many tenants' targets stacked into the engine's grouped
  (ntargets, nrows) kernel batches (vmapped jax kernels on the jax
  backend, chunked NumPy otherwise).  `estimate_batch` results are
  byte-identical to the scalar `sample_cf` per target, and therefore
  independent of WHICH tenants' targets share a batch — union-batching
  is bit-exact.

Correctness contract (asserted in tests/test_fleet_service.py and every
round of benchmarks/fleet_scaling.py): after any interleaved sequence of
per-tenant deltas and recommends, each tenant's recommendation is
exactly `==` — config, cost, used_bytes — a fresh `DesignAdvisor` built
on that tenant's current workload.

Budget isolation: every tenant carries a `TenantBudget` — a workload
size cap enforced before any delta is applied, a pending-request cap
enforced at submit time, and an optional per-tenant workload-compression
budget overriding the shared options — so one noisy tenant can neither
starve the queue nor grow without bound.  Request failures (bad deltas,
budget violations) resolve that tenant's ticket with the exception and
leave every other slot untouched.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

from ..core.advisor import AdvisorOptions
from ..core.estimation_engine import EstimationEngine
from ..core.estimation_graph import NodeKey, State
from ..core.samplecf import SampleManager, SizeEstimate, schema_fingerprint
from ..core.session import AdvisorSession
from ..core.workload import Workload, WorkloadDelta
from .engine import QueueFull


class TenantBudgetExceeded(RuntimeError):
    """A delta would grow a tenant's workload past its budget cap."""


@dataclasses.dataclass
class TenantBudget:
    """Per-tenant isolation limits.

    `max_statements` caps the tenant's workload size — checked against
    the post-delta size BEFORE the delta touches the session, so a
    violating delta fails cleanly and leaves the workload unchanged.
    `max_pending` caps the tenant's queued + in-flight requests at
    submit time (`QueueFull`).  `compression_budget` overrides the
    tenant options' workload-compression budget (outer-mode sessions).
    """
    max_statements: Optional[int] = None
    max_pending: Optional[int] = None
    compression_budget: Optional[int] = None


@dataclasses.dataclass
class FleetConfig:
    slots: int = 8                    # tenant requests executed per step
    max_queue: Optional[int] = None   # global bound; submit raises QueueFull
    prefetch: bool = True             # cross-tenant batched SampleCF prefetch
    backend: str = "numpy"            # prefetch engine backend


class FleetTicket:
    """Future-backed handle for one submitted request.

    `result()` blocks until the service loop retires the request; for a
    recommend it returns the `Recommendation`, for a delta a small
    summary dict.  Failures (invalid delta, `TenantBudgetExceeded`)
    surface through `exception()` / a raising `result()`."""

    def __init__(self, tenant_id: str, kind: str):
        self.tenant_id = tenant_id
        self.kind = kind              # "delta" | "recommend"
        self.submitted_at = time.perf_counter()
        self.resolved_at: Optional[float] = None
        self._future: Future = Future()

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None):
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    @property
    def latency(self) -> Optional[float]:
        """submit -> resolve wall seconds (None while pending)."""
        if self.resolved_at is None:
            return None
        return self.resolved_at - self.submitted_at

    def _resolve(self, value=None, error: Optional[BaseException] = None
                 ) -> None:
        self.resolved_at = time.perf_counter()
        if error is not None:
            self._future.set_exception(error)
        else:
            self._future.set_result(value)


@dataclasses.dataclass
class _FleetRequest:
    tenant_id: str
    kind: str                             # "delta" | "recommend"
    ticket: FleetTicket
    delta: Optional[WorkloadDelta] = None
    budget_bytes: Optional[float] = None


class _ShareGroup:
    """One (schema fingerprint, backend) equivalence class of tenants:
    a shared order-independent SampleManager, a shared (NodeKey, f)
    SampleCF cache, and the batched estimation engine the prefetch
    stacks the group's targets into."""

    def __init__(self, key: Tuple[str, str], tables: Dict, seed: int,
                 backend: str):
        self.key = key
        self.samples = SampleManager(tables, seed=seed)
        self.cache: Dict[Tuple[NodeKey, float], SizeEstimate] = {}
        self.engine = EstimationEngine(tables, self.samples,
                                       backend=backend)
        self.n_tenants = 0


@dataclasses.dataclass
class _Tenant:
    tenant_id: str
    session: AdvisorSession
    budget: TenantBudget
    group: _ShareGroup
    in_flight: Optional[_FleetRequest] = None
    n_pending: int = 0                # queued + in-flight requests
    deltas_applied: int = 0
    recommends: int = 0


class AdvisorFleetService:
    """Slot-based continuous batching over many tenant AdvisorSessions.

    Usage::

        fleet = AdvisorFleetService(FleetConfig(slots=16))
        fleet.register_tenant("t0", workload0, options)
        fleet.register_tenant("t1", workload1, options)   # same schema:
                                                          # shares samples
        fleet.submit_delta("t0", WorkloadDelta(added=(...,)))
        t = fleet.submit_recommend("t0", budget_bytes=2e6)
        fleet.run_until_drained()
        rec = t.result()          # == fresh DesignAdvisor on t0's workload
    """

    def __init__(self, fc: Optional[FleetConfig] = None):
        self.fc = fc or FleetConfig()
        if self.fc.slots < 1:
            raise ValueError("need at least one slot")
        self.tenants: Dict[str, _Tenant] = {}
        self.groups: Dict[Tuple[str, str], _ShareGroup] = {}
        self.queue: List[_FleetRequest] = []          # global arrival order
        self.slots: List[Optional[_FleetRequest]] = [None] * self.fc.slots
        self.steps = 0
        self.retired = 0
        self.prefetch_batches = 0     # (group, f) batched prefetch calls
        self.prefetch_targets = 0     # targets sized by the prefetch
        self.prefetch_hits = 0        # peeked targets already cached

    # ------------------------------------------------------------------
    # Tenants
    # ------------------------------------------------------------------
    def register_tenant(self, tenant_id: str, workload: Workload,
                        options: Optional[AdvisorOptions] = None,
                        budget: Optional[TenantBudget] = None) -> None:
        if tenant_id in self.tenants:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        opt = options or AdvisorOptions()
        budget = budget or TenantBudget()
        if budget.compression_budget is not None:
            opt = dataclasses.replace(
                opt, compression_budget=budget.compression_budget)
        if budget.max_statements is not None and \
                len(workload.statements) > budget.max_statements:
            raise TenantBudgetExceeded(
                f"tenant {tenant_id!r}: initial workload of "
                f"{len(workload.statements)} statements exceeds "
                f"max_statements={budget.max_statements}")
        key = (schema_fingerprint(workload.schema, opt.sample_seed),
               opt.estimation_backend)
        group = self.groups.get(key)
        if group is None:
            group = self.groups[key] = _ShareGroup(
                key, workload.schema.tables, opt.sample_seed,
                self.fc.backend)
        group.n_tenants += 1
        session = AdvisorSession(workload, opt, samples=group.samples,
                                 sampled_cache=group.cache)
        self.tenants[tenant_id] = _Tenant(tenant_id, session, budget, group)

    # ------------------------------------------------------------------
    # Submission (admission control)
    # ------------------------------------------------------------------
    def _submit(self, req: _FleetRequest) -> FleetTicket:
        t = self.tenants[req.tenant_id]
        if self.fc.max_queue is not None and \
                len(self.queue) >= self.fc.max_queue:
            raise QueueFull(
                f"fleet queue at capacity ({self.fc.max_queue})")
        if t.budget.max_pending is not None and \
                t.n_pending >= t.budget.max_pending:
            raise QueueFull(
                f"tenant {req.tenant_id!r} at max_pending="
                f"{t.budget.max_pending}")
        t.n_pending += 1
        self.queue.append(req)
        return req.ticket

    def submit_delta(self, tenant_id: str,
                     delta: WorkloadDelta) -> FleetTicket:
        return self._submit(_FleetRequest(
            tenant_id, "delta", FleetTicket(tenant_id, "delta"),
            delta=delta))

    def submit_recommend(self, tenant_id: str,
                         budget_bytes: float) -> FleetTicket:
        return self._submit(_FleetRequest(
            tenant_id, "recommend", FleetTicket(tenant_id, "recommend"),
            budget_bytes=float(budget_bytes)))

    # ------------------------------------------------------------------
    # Service loop (mirrors ServeEngine: admit -> batch -> execute ->
    # retire)
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Fill free slots from the queue in arrival order, at most one
        in-flight request per tenant so each tenant's requests execute
        in its own submission order (per-tenant FIFO)."""
        for i in range(len(self.slots)):
            if self.slots[i] is not None:
                continue
            for qi, req in enumerate(self.queue):
                if self.tenants[req.tenant_id].in_flight is None:
                    self.queue.pop(qi)
                    self.slots[i] = req
                    self.tenants[req.tenant_id].in_flight = req
                    break
            else:
                break  # nothing admissible for this (or any later) slot

    def _prefetch(self) -> None:
        """Union-batch the admitted recommends' missing SampleCF targets.

        For every admitted recommend, peek the tenant's estimation plan
        (memoized — the subsequent recommend reuses it verbatim), take
        its SAMPLED nodes not yet in the group cache, and size each
        (group, f) union in ONE `estimate_batch` call.  Per-target
        results are byte-identical to the scalar path, so cache content
        does not depend on which tenants were batched together."""
        missing: Dict[Tuple[Tuple[str, str], float], List[NodeKey]] = {}
        seen: Dict[Tuple[Tuple[str, str], float], set] = {}
        for req in self.slots:
            if req is None or req.kind != "recommend":
                continue
            t = self.tenants[req.tenant_id]
            try:
                plan = t.session.peek_estimation_plan()
            except Exception:
                continue  # let the slot's recommend surface the error
            if plan is None:
                continue
            gk = (t.group.key, plan.f)
            got = seen.setdefault(gk, set())
            for k, node in plan.nodes.items():
                if node.state is not State.SAMPLED or k in got:
                    continue
                got.add(k)
                if (k, plan.f) in t.group.cache:
                    self.prefetch_hits += 1
                else:
                    missing.setdefault(gk, []).append(k)
        for (group_key, f), keys in missing.items():
            group = self.groups[group_key]
            for k, est in group.engine.estimate_batch(keys, f).items():
                group.cache[(k, f)] = est
            self.prefetch_batches += 1
            self.prefetch_targets += len(keys)

    def _execute(self, req: _FleetRequest) -> None:
        t = self.tenants[req.tenant_id]
        try:
            if req.kind == "delta":
                assert req.delta is not None
                cap = t.budget.max_statements
                if cap is not None:
                    projected = (len(t.session.workload.statements)
                                 + len(req.delta.added)
                                 - len(req.delta.removed))
                    if projected > cap:
                        raise TenantBudgetExceeded(
                            f"tenant {req.tenant_id!r}: delta would grow "
                            f"the workload to {projected} statements "
                            f"(max_statements={cap})")
                t.session.apply(req.delta)
                t.deltas_applied += 1
                req.ticket._resolve({
                    "applied": True,
                    "workload_version": t.session.workload_version,
                    "n_statements": len(t.session.workload.statements)})
            else:
                assert req.budget_bytes is not None
                rec = t.session.recommend(req.budget_bytes)
                t.recommends += 1
                req.ticket._resolve(rec)
        except BaseException as e:      # isolate failures to this tenant
            req.ticket._resolve(error=e)

    def step(self) -> None:
        """One service iteration: admit queued requests into free slots,
        run the cross-tenant batched prefetch over the admitted
        recommends, execute every slot, retire them all (a request is
        one unit of work, so slots turn over every step)."""
        self._admit()
        if all(s is None for s in self.slots):
            return
        if self.fc.prefetch:
            self._prefetch()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._execute(req)
            t = self.tenants[req.tenant_id]
            t.in_flight = None
            t.n_pending -= 1
            self.slots[i] = None
            self.retired += 1
        self.steps += 1

    def run_until_drained(self, max_steps: int = 1_000_000) -> None:
        while self.queue and self.steps < max_steps:
            self.step()

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, float]:
        out = {
            "tenants": len(self.tenants),
            "groups": len(self.groups),
            "queued": len(self.queue),
            "steps": self.steps,
            "retired": self.retired,
            "prefetch_batches": self.prefetch_batches,
            "prefetch_targets": self.prefetch_targets,
            "prefetch_hits": self.prefetch_hits,
        }
        out["shared_cache_entries"] = sum(
            len(g.cache) for g in self.groups.values())
        out["sampling_calls"] = sum(
            g.samples.sampling_calls for g in self.groups.values())
        return out

    def tenant_stats(self, tenant_id: str) -> Dict[str, float]:
        t = self.tenants[tenant_id]
        out = dict(t.session.stats)
        out.update(deltas_applied=t.deltas_applied,
                   recommends=t.recommends,
                   n_statements=len(t.session.workload.statements),
                   group_tenants=t.group.n_tenants)
        return out

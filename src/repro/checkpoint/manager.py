"""Compressed, checksummed, atomic checkpointing.

Layout per checkpoint:   <dir>/step_<N>/
    manifest.json   — tree structure, per-leaf codec/shape/dtype/crc32
    <leaf-id>.bin   — codec payload per leaf

Fault-tolerance properties:
  * atomic: written to step_<N>.tmp, fsync'd, then os.replace()'d — a crash
    mid-save never corrupts the latest checkpoint;
  * checksummed: every payload carries crc32, verified on restore;
  * keep_last_k garbage collection;
  * async: save() can run on a background thread (wait() joins);
  * codecs per tensor class come from the design advisor (the paper's
    recommendation applied to the checkpoint "index": zstd for lossless,
    q8+zstd for moments where the plan allows lossy).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from ..design import codecs as C


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep_last_k: int = 3
    params_codec: str = "zstd"        # lossless by default
    moments_codec: str = "zstd"       # the advisor may pick q8+zstd
    async_save: bool = False


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_seconds = 0.0

    # ------------------------------------------------------------------
    def save(self, step: int, params, opt_state=None,
             extra: Optional[dict] = None) -> None:
        if self.cfg.async_save:
            self.wait()
            # snapshot to host memory synchronously, write asynchronously
            host = self._to_host(params, opt_state)
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()
        else:
            self._write(step, self._to_host(params, opt_state), extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _to_host(self, params, opt_state):
        host = {"params": jax.tree.map(np.asarray, params)}
        if opt_state is not None:
            host["opt_state"] = jax.tree.map(np.asarray, opt_state)
        return host

    def _codec_for(self, key: str, leaf: np.ndarray) -> str:
        if leaf.dtype == np.int8 or leaf.dtype.kind in "iub":
            return "zstd"  # already-quantized or integer state
        if key.startswith("opt_state"):
            return self.cfg.moments_codec
        return self.cfg.params_codec

    def _write(self, step: int, host: dict, extra: dict) -> None:
        t0 = time.perf_counter()
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {},
                    "treedef": None}
        leaves = _leaf_paths(host)
        for i, (key, leaf) in enumerate(sorted(leaves.items())):
            leaf = np.asarray(leaf)
            codec = self._codec_for(key, leaf)
            if leaf.dtype.kind in "iub" or str(leaf.dtype) == "bfloat16":
                payload = zlib_or_zstd(leaf)
                meta = {"codec": "raw+zstd", "shape": list(leaf.shape),
                        "dtype": str(leaf.dtype)}
            else:
                payload, meta = C.encode(codec, leaf)
            fn = f"leaf_{i:05d}.bin"
            (tmp / fn).write_bytes(payload)
            manifest["leaves"][key] = {
                **meta, "file": fn, "crc32": zlib.crc32(payload),
                "raw_bytes": int(leaf.nbytes), "stored_bytes": len(payload),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync the directory contents then atomically publish
        for f in tmp.iterdir():
            with open(f, "rb") as fh:
                os.fsync(fh.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        self.save_seconds = time.perf_counter() - t0

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if not c.name.endswith(".tmp")]
        for old in ckpts[: -self.cfg.keep_last_k]:
            shutil.rmtree(old)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if not c.name.endswith(".tmp")]
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: Optional[int] = None
                ) -> Tuple[int, Dict[str, Any], dict]:
        """Returns (step, {"params": flat, "opt_state": flat}, extra) where
        flat maps tree paths to arrays; restore_into() rebuilds pytrees."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        out: Dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            payload = (d / meta["file"]).read_bytes()
            if zlib.crc32(payload) != meta["crc32"]:
                raise IOError(f"checksum mismatch for {key} in {d}")
            if meta["codec"] == "raw+zstd":
                import zstandard
                raw = zstandard.decompress(payload)
                dt = meta["dtype"]
                if dt == "bfloat16":
                    import jax.numpy as jnp
                    arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"])
                    out[key] = np.asarray(jnp.asarray(arr).view(jnp.bfloat16))
                else:
                    out[key] = np.frombuffer(raw, np.dtype(dt)).reshape(
                        meta["shape"]).copy()
            else:
                out[key] = C.decode(payload, meta)
        return step, out, manifest["extra"]

    def restore_into(self, template_params, template_opt=None,
                     step: Optional[int] = None):
        """Restore into pytrees with the structure of the templates."""
        got_step, flat, extra = self.restore(step)

        def fill(prefix, template):
            leaves = _leaf_paths(template)
            rebuilt = []
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    template)[0]:
                key = prefix + "/" + "/".join(
                    str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)
                arr = flat[key]
                rebuilt.append(np.asarray(arr, dtype=leaf.dtype)
                               if str(leaf.dtype) != "bfloat16" else arr)
            treedef = jax.tree_util.tree_structure(template)
            return jax.tree_util.tree_unflatten(treedef, rebuilt)

        params = fill("params", template_params)
        opt = fill("opt_state", template_opt) if template_opt is not None \
            else None
        return got_step, params, opt, extra


def zlib_or_zstd(leaf: np.ndarray) -> bytes:
    import zstandard
    if str(leaf.dtype) == "bfloat16":
        import jax.numpy as jnp
        leaf = np.asarray(jax.numpy.asarray(leaf).view(jnp.uint16))
    return zstandard.compress(np.ascontiguousarray(leaf).tobytes(), 3)

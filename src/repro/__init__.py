"""repro — Compression Aware Physical Database Design (PVLDB 4(10), 2011)
reproduced faithfully (repro.core) and adapted into a multi-pod JAX
training/serving framework (repro.design + models/train/serve/launch).
See README.md and DESIGN.md."""

__version__ = "1.0.0"

"""Transformer layer library: norms, RoPE, GQA attention (train / chunked
prefill / decode), SwiGLU & squared-ReLU MLPs, and capacity-based MoE.

Pure-functional JAX: every block is (params pytree, inputs) -> outputs with
explicit init_* functions, so `jax.eval_shape(init_*)` gives allocation-free
parameter skeletons for the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, MoEConfig

Params = Dict[str, Any]
NEG_INF = -1e9  # finite mask value: keeps bf16 softmax NaN-free


def _init(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def chunked_scan(step_fn, carry, xs, chunk: int):
    """lax.scan over time in rematerialized chunks.

    Saves only per-CHUNK carries for the backward pass (remat recomputes
    within-chunk intermediates), turning O(S * state) residual memory into
    O(S/chunk * state) — the standard memory policy for long-sequence
    recurrences (WKV / selective SSM / online-softmax attention).

    xs leaves are time-major (S, ...).  Falls back to a plain scan when S is
    not a multiple of `chunk`.
    """
    s = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, s)
    if s % chunk:
        return lax.scan(step_fn, carry, xs)
    n = s // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(c, xc):
        return lax.scan(step_fn, c, xc)

    carry, ys = lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(n * chunk, *a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # f32-ACCUMULATING reduction without materializing an f32 copy of x:
    # a full-tensor convert at the top of a scanned body gets hoisted by
    # XLA's loop-invariant code motion into an f32 copy of the whole remat
    # stack (L,B,S,D) — catastrophic for training memory.
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None]
    var = var / x.shape[-1]
    inv = lax.rsqrt(var + eps)                       # (..., 1) f32
    return (x * inv.astype(x.dtype)) * p["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (...,S,1,Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.heads, cfg.kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d, nh, hd), dtype=dtype),
        "wk": _init(ks[1], (d, nkv, hd), dtype=dtype),
        "wv": _init(ks[2], (d, nkv, hd), dtype=dtype),
        "wo": _init(ks[3], (nh, hd, d), scale=0.02 / math.sqrt(2 * cfg.n_layers),
                    dtype=dtype),
    }


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,Kv,Dh) -> (B,S,Kv*groups,Dh) by repeating each kv head."""
    if groups == 1:
        return k
    b, s, kv, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, dh))
    return k.reshape(b, s, kv * groups, dh)


def attention_full(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal attention over the whole sequence (training / small prefill).

    x: (B, S, D) -> (B, S, D)
    """
    b, s, d = x.shape
    nh, nkv, hd = cfg.heads, cfg.kv_heads, cfg.d_head
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = _repeat_kv(k, nh // nkv)
    v = _repeat_kv(v, nh // nkv)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(causal[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    return jnp.einsum("bqhk,hkd->bqd", ctx, p["wo"])


def attention_chunked(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                      q_chunk: int = 1024, kv_chunk: int = 1024) -> jnp.ndarray:
    """Memory-efficient causal attention (online softmax over KV chunks).

    O(q_chunk * kv_chunk) score memory — required for 32k+ prefill.
    """
    b, s, d = x.shape
    nh, nkv, hd = cfg.heads, cfg.kv_heads, cfg.d_head
    positions = jnp.arange(s)[None, :]
    q = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), positions,
                   cfg.rope_theta)
    k = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), positions,
                   cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    k = _repeat_kv(k, nh // nkv)
    v = _repeat_kv(v, nh // nkv)

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    n_q, n_kv = s // q_chunk, s // kv_chunk
    qr = q.reshape(b, n_q, q_chunk, nh, hd)

    def per_q_chunk(qi, q_blk):
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint
        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 1)
            v_blk = lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 1)
            sc = jnp.einsum("bqhk,bshk->bhqs", q_blk, k_blk) / math.sqrt(hd)
            kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = q_pos[:, None] >= kv_pos[None, :]
            sc = jnp.where(mask[None, None], sc.astype(jnp.float32), NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            probs = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + probs.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshk->bhqk", probs.astype(x.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nh, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nh, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, nh, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_kv))
        ctx = (acc / jnp.maximum(l, 1e-20)[..., None]).astype(x.dtype)
        return ctx.transpose(0, 2, 1, 3)  # (B,q_chunk,H,Dh)

    ctx = lax.map(lambda args: per_q_chunk(*args),
                  (jnp.arange(n_q), qr.transpose(1, 0, 2, 3, 4)))
    ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)
    return jnp.einsum("bqhk,hkd->bqd", ctx, p["wo"])


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int, dtype=jnp.bfloat16) -> Params:
    nkv, hd = cfg.kv_heads, cfg.d_head
    shape = (n_layers, batch, max_len, nkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode with a KV cache and PER-SLOT positions.

    x: (B, 1, D); k_cache/v_cache: (B, S_max, Kv, Dh); pos: (B,) int32 —
    each batch slot's current length (slot-based continuous batching).
    Returns (out (B,1,D), new_k, new_v).
    """
    b, _, d = x.shape
    nh, nkv, hd = cfg.heads, cfg.kv_heads, cfg.d_head
    s_max = k_cache.shape[1]
    positions = pos[:, None].astype(jnp.int32)           # (B, 1)
    q = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), positions,
                   cfg.rope_theta)
    k = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), positions,
                   cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    rows = jnp.arange(b)
    k_cache = k_cache.at[rows, pos].set(k[:, 0].astype(k_cache.dtype),
                                        mode="drop")
    v_cache = v_cache.at[rows, pos].set(v[:, 0].astype(v_cache.dtype),
                                        mode="drop")
    kk = _repeat_kv(k_cache.astype(x.dtype), nh // nkv)
    vv = _repeat_kv(v_cache.astype(x.dtype), nh // nkv)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, kk) / math.sqrt(hd)
    valid = (jnp.arange(s_max)[None, :] <= pos[:, None])  # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bshk->bqhk", probs, vv)
    out = jnp.einsum("bqhk,hkd->bqd", ctx, p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {"wi": _init(ks[0], (d, f), dtype=dtype),
                "wg": _init(ks[1], (d, f), dtype=dtype),
                "wo": _init(ks[2], (f, d), dtype=dtype)}
    return {"wi": _init(ks[0], (d, f), dtype=dtype),
            "wo": _init(ks[2], (f, d), dtype=dtype)}


def mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:  # squared ReLU (nemotron)
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    return h @ p["wo"]


# --- quantized-weight MLP (serving): the advisor's "q8 weights" choice ----

def quantize_mlp(p: Params, block: int = 128) -> Params:
    """Compress MLP weights to int8 (keeps the (K/block, N) scale layout
    the fused dequant-matmul kernel expects)."""
    from ..kernels import ref as kref

    def q(w):  # w: (K, N) -> qw (K, N) int8, scales (K/block, N)
        qw, s = kref.quantize_blockwise(jnp.asarray(w, jnp.float32).T, block)
        return {"q": qw.T, "s": s.T}

    return {k: q(v) for k, v in p.items()}


def mlp_quantized(pq: Params, x: jnp.ndarray, kind: str,
                  block: int = 128, use_pallas: bool = False) -> jnp.ndarray:
    """MLP forward with int8 weights, dequantized inside the matmul
    (kernels/dequant_matmul on TPU; ref path under jit elsewhere).  The
    weights never materialize in floating point in HBM — SQL Server's
    "decompress only what the query reads" (paper A.2), fused."""
    from ..kernels import ops as kops
    from ..kernels import ref as kref

    mm = (lambda a, w: kops.dequant_matmul(a, w["q"], w["s"], block)) \
        if use_pallas else \
        (lambda a, w: kref.dequant_matmul(a, w["q"], w["s"], block))
    lead = x.shape[:-1]
    a = x.reshape(-1, x.shape[-1])
    if kind == "swiglu":
        h = jax.nn.silu(mm(a, pq["wg"])) * mm(a, pq["wi"])
    else:
        h = jnp.square(jax.nn.relu(mm(a, pq["wi"])))
    out = mm(h.astype(x.dtype), pq["wo"])
    return out.reshape(*lead, -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch, GShard-style but scatter-based)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    moe = cfg.moe
    assert moe is not None
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.experts
    ks = jax.random.split(key, 4)
    return {
        "router": _init(ks[0], (d, e), dtype=jnp.float32),
        "wi": _init(ks[1], (e, d, f), dtype=dtype),
        "wg": _init(ks[2], (e, d, f), dtype=dtype),
        "wo": _init(ks[3], (e, f, d), dtype=dtype),
    }


def moe_mlp(p: Params, x: jnp.ndarray, moe: MoEConfig) -> jnp.ndarray:
    """Top-k routed MoE with expert-capacity dispatch.

    x: (B, S, D).  Tokens flatten to T=B*S; each picks top_k experts; each
    expert processes at most C = ceil(T * k * cf / E) tokens (overflow is
    dropped, standard GShard semantics).  Dummy padded experts are masked
    out of the router softmax (function-preserving).
    """
    b, s, d = x.shape
    t = b * s
    e, k = moe.experts, moe.top_k
    cap = int(math.ceil(t * k * moe.capacity_factor / e))
    cap = max(cap, 1)
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    if moe.n_experts_padded and moe.n_experts_padded > moe.n_experts:
        pad_mask = jnp.arange(e) < moe.n_experts
        logits = jnp.where(pad_mask[None, :], logits, NEG_INF)
    gates, expert_idx = lax.top_k(logits, k)                  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)                    # renormalize

    # position of each (token, slot) within its expert, via cumsum over the
    # flattened (k*T) one-hot assignment — deterministic priority ordering.
    flat_e = expert_idx.T.reshape(-1)                         # (k*T,) slot-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (k*T, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                 # (k*T, E)
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    flat_gate = gates.T.reshape(-1) * keep

    # dispatch: scatter tokens into (E, C, D)
    tok_idx = jnp.tile(jnp.arange(t), k)
    safe_pos = jnp.where(keep, flat_pos, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    contrib = xt[tok_idx] * keep[:, None].astype(x.dtype)
    buf = buf.at[flat_e, safe_pos].add(contrib, mode="drop")

    # expert computation (E-sharded einsums)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    h = jax.nn.silu(h) * hi
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])            # (E, C, D)

    # combine: gather each slot's result, weight by gate, sum over k slots
    gathered = out_e[flat_e, safe_pos]                        # (k*T, D)
    weighted = gathered * flat_gate[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_idx].add(weighted, mode="drop")
    return out.reshape(b, s, d)

"""Mamba-1 selective SSM block (the Jamba hybrid's sequence mixer).

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t      (per channel)
    y_t = C_t . h_t + D x_t

with input-dependent dt (softplus), B, C.  Serving state per layer:
conv ring buffer (B, d_conv-1, d_in) + SSM state (B, d_in, d_state) —
O(1) in sequence length (the long_500k cell relies on this).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import HybridConfig, ModelConfig
from .layers import _init

Params = Dict[str, Any]


def d_inner(cfg: ModelConfig) -> int:
    return (cfg.hybrid or HybridConfig()).expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    h = cfg.hybrid or HybridConfig()
    d, din, dr, ds = cfg.d_model, d_inner(cfg), dt_rank(cfg), h.d_state
    ks = jax.random.split(key, 7)
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (din, ds))
    return {
        "in_proj": _init(ks[0], (d, 2 * din), dtype=dtype),
        "conv_w": _init(ks[1], (h.d_conv, din), 0.2, dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": _init(ks[2], (din, dr + 2 * ds), dtype=dtype),
        "dt_w": _init(ks[3], (dr, din), dtype=dtype),
        "dt_b": jnp.full((din,), -4.6, dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(dtype),
        "d_skip": jnp.ones((din,), dtype),
        "out_proj": _init(ks[6], (din, d), dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over time.  x: (B,S,Din); w: (K,Din);
    prev: (B,K-1,Din) carry-in.  Returns (out, new_prev)."""
    k = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B,S+K-1,Din)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return out, xp[:, -(k - 1):] if k > 1 else prev


def mamba_sequence(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D); conv_state: (B,K-1,Din); ssm_state: (B,Din,ds) f32.

    Returns (out (B,S,D), new_conv_state, new_ssm_state)."""
    h = cfg.hybrid or HybridConfig()
    b, s, d = x.shape
    din, dr, ds = d_inner(cfg), dt_rank(cfg), h.d_state

    xz = x @ p["in_proj"]                               # (B,S,2*Din)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    proj = xs @ p["x_proj"]                             # (B,S,dr+2ds)
    dt, bb, cc = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_w"] + p["dt_b"])    # (B,S,Din)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (Din,ds)

    dt32 = dt.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)                   # (B,S,Din,ds)
    dbx = (dt32 * xs.astype(jnp.float32))[..., None] \
        * bb.astype(jnp.float32)[..., None, :]          # (B,S,Din,ds)

    def step(hst, inputs):
        da_t, dbx_t, c_t = inputs                       # (B,Din,ds)x2,(B,ds)
        hst = da_t * hst + dbx_t
        y = jnp.einsum("bds,bs->bd", hst, c_t)
        return hst, y

    from .layers import chunked_scan
    xs_t = (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3),
            cc.astype(jnp.float32).transpose(1, 0, 2))
    ssm_state, ys = chunked_scan(step, ssm_state.astype(jnp.float32), xs_t,
                                 chunk=128)
    y = ys.transpose(1, 0, 2).astype(x.dtype)           # (B,S,Din)
    y = y + xs * p["d_skip"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], conv_state, ssm_state


def init_mamba_state(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype=jnp.float32) -> Params:
    h = cfg.hybrid or HybridConfig()
    din = d_inner(cfg)
    return {
        "conv": jnp.zeros((n_layers, batch, h.d_conv - 1, din), dtype),
        "ssm": jnp.zeros((n_layers, batch, din, h.d_state), jnp.float32),
    }

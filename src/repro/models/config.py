"""Model configuration for the assigned architecture pool.

One ModelConfig describes any of the 10 assigned backbones: dense GQA
transformers, MoE transformers, RWKV6 (attention-free), and the Jamba-style
hybrid (Mamba + attention 1:7 with interleaved MoE).

TP-divisibility: head counts / expert counts that do not divide the model
axis are PADDED (function-preserving zero weights).  `pad_for_tp` records
both logical and padded values; the roofline's MODEL_FLOPS/HLO ratio exposes
the padding waste honestly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    every_k_layers: int = 1      # MoE MLP on layers where layer % k == k-1
    n_experts_padded: int = 0    # set by pad_for_tp

    @property
    def experts(self) -> int:
        return self.n_experts_padded or self.n_experts


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style: groups of `group_size` layers, the last one attention,
    the rest Mamba; MoE on even positions within the group."""
    group_size: int = 8          # 7 mamba + 1 attention
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64         # rank of the data-dependent decay LoRA
    gate_lora: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    mixer: str = "attn"          # attn | rwkv6 (hybrid handled separately)
    mlp: str = "swiglu"          # swiglu | relu2
    moe: Optional[MoEConfig] = None
    hybrid: Optional[HybridConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str = "tokens"     # tokens | patch_stub (vlm) | frame_stub (audio)
    # padded values (pad_for_tp); 0 => use logical
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    vocab_padded: int = 0

    @property
    def heads(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads_padded or self.n_kv_heads

    @property
    def vocab_p(self) -> int:
        return self.vocab_padded or self.vocab

    @property
    def attn_layers(self) -> int:
        if self.hybrid is not None:
            return self.n_layers // self.hybrid.group_size
        return self.n_layers if self.mixer == "attn" else 0

    def param_count(self, padded: bool = False) -> int:
        """Analytic parameter count (logical by default)."""
        d = self.d_model
        nh = self.heads if padded else self.n_heads
        nkv = self.kv_heads if padded else self.n_kv_heads
        voc = self.vocab_p if padded else self.vocab
        hd = self.d_head

        def attn_params():
            return d * nh * hd + 2 * d * nkv * hd + nh * hd * d

        def moe_params():
            e = self.moe.experts if padded else self.moe.n_experts
            return d * e + e * 3 * d * self.moe.d_ff_expert

        def mlp_params(layer_idx: int):
            if self.hybrid is not None:
                # hybrid: MoE on even in-group positions (1:1 interleave)
                if self.moe is not None and \
                        (layer_idx % self.hybrid.group_size) % 2 == 0:
                    return moe_params()
            elif self.moe is not None and \
                    layer_idx % self.moe.every_k_layers == self.moe.every_k_layers - 1:
                return moe_params()
            mult = 3 if self.mlp == "swiglu" else 2
            return mult * d * self.d_ff

        total = voc * d * (1 if self.tie_embeddings else 2)
        if self.hybrid is not None:
            g = self.hybrid
            d_in = g.expand * d
            mamba = (d * 2 * d_in + g.d_conv * d_in + d_in * g.d_state * 2
                     + d_in * 2 + d_in * g.d_state + d_in * d)
            for i in range(self.n_layers):
                is_attn = (i % g.group_size == g.group_size - 1)
                total += attn_params() if is_attn else mamba
                total += mlp_params(i)
                total += 2 * d  # norms
        elif self.mixer == "rwkv6":
            r = self.rwkv or RWKVConfig()
            # time-mix: r,k,v,g,o projections + decay LoRA + token-shift mixes
            tm = 5 * d * d + 2 * r.decay_lora * d + 6 * d
            cm = 2 * d * self.d_ff + d * d  # channel mix K, V, R
            total += self.n_layers * (tm + cm + 2 * d)
        else:
            for i in range(self.n_layers):
                total += attn_params() + mlp_params(i) + 2 * d
        return int(total)


def _ceil_to(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)


def pad_for_tp(cfg: ModelConfig, tp: int, pad_kv: bool = True) -> ModelConfig:
    """Pad head/expert/vocab counts to divide the model axis (function-
    preserving: padded heads/experts carry zero output weights).

    pad_kv=False keeps the LOGICAL kv-head count (used when the KV cache is
    sequence-sharded instead of head-sharded — no padding waste; the kv
    projections replicate, which is cheap)."""
    changes = {}
    if cfg.mixer == "attn" or cfg.hybrid is not None:
        nh = _ceil_to(cfg.n_heads, tp)
        nkv = cfg.n_kv_heads
        if pad_kv:
            nkv = tp if nkv < tp else _ceil_to(nkv, tp)
        if nh != cfg.n_heads:
            changes["n_heads_padded"] = nh
        if nkv != cfg.n_kv_heads:
            changes["n_kv_heads_padded"] = nkv
    if cfg.vocab % tp:
        changes["vocab_padded"] = _ceil_to(cfg.vocab, tp)
    moe = cfg.moe
    if moe is not None and moe.experts % tp:
        moe = dataclasses.replace(moe, n_experts_padded=_ceil_to(moe.n_experts, tp))
        changes["moe"] = moe
    return dataclasses.replace(cfg, **changes) if changes else cfg


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(moe.n_experts, 8),
                                  top_k=min(moe.top_k, 2), d_ff_expert=64,
                                  n_experts_padded=0)
    hybrid = cfg.hybrid
    rwkv = cfg.rwkv
    if rwkv is not None:
        rwkv = dataclasses.replace(rwkv, head_size=16, decay_lora=8,
                                   gate_lora=16)
    n_layers = 2 if hybrid is None else cfg.hybrid.group_size
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, d_head=16,
        moe=moe, hybrid=hybrid, rwkv=rwkv,
        n_heads_padded=0, n_kv_heads_padded=0, vocab_padded=0)

"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent decay, plus squared-ReLU channel mixing.

Recurrence per head (head size hs, state S in R^{hs x hs}):

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with w_t = exp(-exp(w0 + lora_w(ddlerp_w(x_t, x_{t-1})))) in (0,1) —
the data-dependent decay that distinguishes RWKV6 from RWKV4/5.

Serving state per layer: (tm_shift (B,D), cm_shift (B,D), S (B,H,hs,hs)) —
O(1) in sequence length, which is why the long_500k cell runs for this arch.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, RWKVConfig
from .layers import _init

Params = Dict[str, Any]
MIX_CHANNELS = 5  # w, k, v, r, g


def init_rwkv_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    r = cfg.rwkv or RWKVConfig()
    hs = r.head_size
    nh = d // hs
    ks = jax.random.split(key, 12)
    return {
        "tm": {
            # token-shift ddlerp: base mixes + low-rank data-dependent part
            "mu_x": _init(ks[0], (d,), 0.5, dtype),
            "mu": _init(ks[1], (MIX_CHANNELS, d), 0.5, dtype),
            "ts_w1": _init(ks[2], (d, MIX_CHANNELS * 32), dtype=dtype),
            "ts_w2": _init(ks[3], (MIX_CHANNELS, 32, d), dtype=dtype),
            # data-dependent decay LoRA
            "w0": _init(ks[4], (d,), 0.5, dtype),
            "w1": _init(ks[5], (d, r.decay_lora), dtype=dtype),
            "w2": _init(ks[6], (r.decay_lora, d), dtype=dtype),
            "u": _init(ks[7], (nh, hs), 0.5, dtype),
            "wr": _init(ks[8], (d, d), dtype=dtype),
            "wk": _init(ks[9], (d, d), dtype=dtype),
            "wv": _init(ks[10], (d, d), dtype=dtype),
            "wg": _init(ks[11], (d, d), dtype=dtype),
            "wo": _init(jax.random.fold_in(key, 101), (d, d), dtype=dtype),
            "ln_scale": jnp.ones((d,), dtype),  # per-head group norm
        },
        "cm": {
            "mu_k": _init(jax.random.fold_in(key, 102), (d,), 0.5, dtype),
            "mu_r": _init(jax.random.fold_in(key, 103), (d,), 0.5, dtype),
            "wk": _init(jax.random.fold_in(key, 104), (d, cfg.d_ff), dtype=dtype),
            "wv": _init(jax.random.fold_in(key, 105), (cfg.d_ff, d), dtype=dtype),
            "wr": _init(jax.random.fold_in(key, 106), (d, d), dtype=dtype),
        },
    }


def _ddlerp(tm: Params, x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent lerp of RWKV6: returns (C=5, ..., D) mixed inputs."""
    xx = x_prev - x
    xxx = x + xx * tm["mu_x"]
    lora = jnp.tanh(xxx @ tm["ts_w1"])                  # (..., 5*32)
    lora = lora.reshape(*lora.shape[:-1], MIX_CHANNELS, 32)
    dd = jnp.einsum("...cr,crd->c...d", lora, tm["ts_w2"])  # (5, ..., D)
    mu = tm["mu"].reshape((MIX_CHANNELS,) + (1,) * (x.ndim - 1) + (-1,))
    return x[None] + xx[None] * (mu + dd)


def _decay(tm: Params, xw: jnp.ndarray) -> jnp.ndarray:
    w_log = tm["w0"] + jnp.tanh(xw @ tm["w1"]) @ tm["w2"]
    return jnp.exp(-jnp.exp(w_log.astype(jnp.float32)))   # (0,1)


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head layernorm of the WKV output. y: (..., H, hs)."""
    mean = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    out = (y - mean) * lax.rsqrt(var + eps)
    return out.reshape(*y.shape[:-2], -1) * scale


def time_mix_sequence(tm: Params, x: jnp.ndarray, cfg: ModelConfig,
                      tm_shift: jnp.ndarray, wkv: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D); tm_shift: (B,D) last token of the previous chunk;
    wkv: (B,H,hs,hs).  Returns (out, new_shift, new_wkv)."""
    b, s, d = x.shape
    r_cfg = cfg.rwkv or RWKVConfig()
    hs = r_cfg.head_size
    nh = d // hs
    x_prev = jnp.concatenate([tm_shift.astype(x.dtype)[:, None], x[:, :-1]],
                             axis=1)
    mixed = _ddlerp(tm, x, x_prev)                       # (5,B,S,D)
    xw, xk, xv, xr, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]
    w = _decay(tm, xw).reshape(b, s, nh, hs)             # (B,S,H,hs) f32
    k = (xk @ tm["wk"]).reshape(b, s, nh, hs)
    v = (xv @ tm["wv"]).reshape(b, s, nh, hs)
    r = (xr @ tm["wr"]).reshape(b, s, nh, hs)
    g = jax.nn.silu(xg @ tm["wg"])
    u = tm["u"]

    def step(S, inputs):
        wt, kt, vt, rt = inputs                          # (B,H,hs) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       S + u[None, :, :, None].astype(S.dtype) * kv)
        S_new = wt[..., None].astype(S.dtype) * S + kv
        return S_new, y

    xs = (w.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          r.transpose(1, 0, 2, 3).astype(jnp.float32))
    from .layers import chunked_scan
    wkv_new, ys = chunked_scan(step, wkv.astype(jnp.float32), xs, chunk=256)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)         # (B,S,H,hs)
    y = _group_norm(y, tm["ln_scale"].astype(x.dtype), cfg.norm_eps)
    out = (y * g) @ tm["wo"]
    return out, x[:, -1].astype(tm_shift.dtype), wkv_new.astype(wkv.dtype)


def channel_mix_sequence(cm: Params, x: jnp.ndarray, cm_shift: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x_prev = jnp.concatenate([cm_shift.astype(x.dtype)[:, None], x[:, :-1]],
                             axis=1)
    xx = x_prev - x
    xk = x + xx * cm["mu_k"]
    xr = x + xx * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["wk"]))
    kv = k @ cm["wv"]
    out = jax.nn.sigmoid(xr @ cm["wr"]) * kv
    return out, x[:, -1].astype(cm_shift.dtype)


def init_rwkv_state(cfg: ModelConfig, batch: int, n_layers: int,
                    dtype=jnp.float32) -> Params:
    d = cfg.d_model
    r = cfg.rwkv or RWKVConfig()
    nh = d // r.head_size
    return {
        "tm_shift": jnp.zeros((n_layers, batch, d), dtype),
        "cm_shift": jnp.zeros((n_layers, batch, d), dtype),
        "wkv": jnp.zeros((n_layers, batch, nh, r.head_size, r.head_size),
                         jnp.float32),
    }


def rwkv_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
               state: Dict[str, jnp.ndarray], norm1, norm2, norm_fn
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full pre-norm RWKV6 block over a sequence (train/prefill/decode-1)."""
    h = norm_fn(norm1, x)
    att, tm_shift, wkv = time_mix_sequence(
        p["tm"], h, cfg, state["tm_shift"], state["wkv"])
    x = x + att
    h = norm_fn(norm2, x)
    ffn, cm_shift = channel_mix_sequence(p["cm"], h, state["cm_shift"])
    x = x + ffn
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}

"""Model assembly: embedding -> scanned layer stack -> head.

Two assemblies cover all 10 assigned architectures:

* UniformLM — homogeneous layers scanned with lax.scan: dense GQA
  transformers, MoE transformers (MoE MLP every layer), and RWKV6.
* HybridLM  — Jamba-style groups scanned with lax.scan: each group is
  7 Mamba blocks + 1 attention block, MoE on even in-group positions
  (=> 36/72 MoE layers, matching the published 398B total).

All entry points work on ShapeDtypeStructs via jax.eval_shape for the
multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from . import mamba as M
from . import rwkv as R
from .config import ModelConfig

Params = Dict[str, Any]

# lax.scan unroll factor for the layer stack; census-validation tests set
# this to the full depth so cost_analysis sees no while loops.
SCAN_UNROLL = 1


def is_hybrid(cfg: ModelConfig) -> bool:
    return cfg.hybrid is not None


def is_rwkv(cfg: ModelConfig) -> bool:
    return cfg.mixer == "rwkv6"


def _uses_moe(cfg: ModelConfig, layer_pos: int) -> bool:
    if cfg.moe is None:
        return False
    k = cfg.moe.every_k_layers
    return layer_pos % k == k - 1


# ---------------------------------------------------------------------------
# Parameter init (eval_shape-able)
# ---------------------------------------------------------------------------

def _init_uniform_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": L.init_rmsnorm(cfg.d_model, dtype),
                 "norm2": L.init_rmsnorm(cfg.d_model, dtype)}
    if is_rwkv(cfg):
        p["rwkv"] = R.init_rwkv_block(ks[0], cfg, dtype)
        return p
    p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
    return p


def _init_group(key, cfg: ModelConfig, dtype) -> Params:
    g = cfg.hybrid
    n_mamba = g.group_size - 1
    ks = jax.random.split(key, 8)
    mamba = jax.vmap(lambda k: M.init_mamba_block(k, cfg, dtype))(
        jax.random.split(ks[0], n_mamba))
    mamba_norm = jax.vmap(lambda k: L.init_rmsnorm(cfg.d_model, dtype))(
        jax.random.split(ks[1], n_mamba))
    n_moe = g.group_size // 2
    n_mlp = g.group_size - n_moe
    return {
        "mamba": mamba,
        "mamba_norm": mamba_norm,
        "attn": L.init_attention(ks[2], cfg, dtype),
        "attn_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "moe": jax.vmap(lambda k: L.init_moe(k, cfg, dtype))(
            jax.random.split(ks[3], n_moe)),
        "moe_norm": jax.vmap(lambda k: L.init_rmsnorm(cfg.d_model, dtype))(
            jax.random.split(ks[4], n_moe)),
        "mlp": jax.vmap(lambda k: L.init_mlp(k, cfg, dtype))(
            jax.random.split(ks[5], n_mlp)),
        "mlp_norm": jax.vmap(lambda k: L.init_rmsnorm(cfg.d_model, dtype))(
            jax.random.split(ks[6], n_mlp)),
    }


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k_embed, k_layers, k_head = jax.random.split(rng, 3)
    p: Params = {
        "embed": L._init(k_embed, (cfg.vocab_p, cfg.d_model), dtype=dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(k_head, (cfg.d_model, cfg.vocab_p), dtype=dtype)
    if is_hybrid(cfg):
        n_groups = cfg.n_layers // cfg.hybrid.group_size
        p["groups"] = jax.vmap(lambda k: _init_group(k, cfg, dtype))(
            jax.random.split(k_layers, n_groups))
    else:
        p["layers"] = jax.vmap(lambda k: _init_uniform_layer(k, cfg, dtype))(
            jax.random.split(k_layers, cfg.n_layers))
    return p


def params_shape(cfg: ModelConfig, dtype=jnp.float32):
    """Allocation-free parameter skeleton for the dry-run."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _embed_input(params, cfg, tokens, embeds):
    if embeds is not None:
        return embeds
    return params["embed"][tokens]


def _head(params, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    return logits


def _mlp_branch(lp, h, cfg):
    if cfg.moe is not None:
        return L.moe_mlp(lp["moe"], h, cfg.moe)
    return L.mlp(lp["mlp"], h, cfg.mlp)


def _group_forward(gp: Params, x: jnp.ndarray, cfg: ModelConfig,
                   states: Optional[Params], attn_impl: str):
    """One hybrid group over a full sequence.  states (per group slice):
    {"conv": (7,B,K-1,Din), "ssm": (7,B,Din,ds)} or None (zeros)."""
    g = cfg.hybrid
    b = x.shape[0]
    new_conv, new_ssm = [], []
    moe_i = mlp_i = 0
    for pos in range(g.group_size):
        if pos == g.group_size - 1:   # attention position
            h = L.rmsnorm(gp["attn_norm"], x, cfg.norm_eps)
            if attn_impl == "chunked":
                x = x + L.attention_chunked(gp["attn"], h, cfg)
            else:
                x = x + L.attention_full(gp["attn"], h, cfg)
        else:
            i = pos
            lp = jax.tree.map(lambda a: a[i], gp["mamba"])
            npm = jax.tree.map(lambda a: a[i], gp["mamba_norm"])
            h = L.rmsnorm(npm, x, cfg.norm_eps)
            if states is None:
                conv0 = jnp.zeros((b, g.d_conv - 1, M.d_inner(cfg)), x.dtype)
                ssm0 = jnp.zeros((b, M.d_inner(cfg), g.d_state), jnp.float32)
            else:
                conv0, ssm0 = states["conv"][i], states["ssm"][i]
            out, c1, s1 = M.mamba_sequence(lp, h, cfg, conv0, ssm0)
            x = x + out
            new_conv.append(c1)
            new_ssm.append(s1)
        if pos % 2 == 0:              # MoE position
            mp = jax.tree.map(lambda a, i=moe_i: a[i], gp["moe"])
            mn = jax.tree.map(lambda a, i=moe_i: a[i], gp["moe_norm"])
            h = L.rmsnorm(mn, x, cfg.norm_eps)
            x = x + L.moe_mlp(mp, h, cfg.moe)
            moe_i += 1
        else:
            mp = jax.tree.map(lambda a, i=mlp_i: a[i], gp["mlp"])
            mn = jax.tree.map(lambda a, i=mlp_i: a[i], gp["mlp_norm"])
            h = L.rmsnorm(mn, x, cfg.norm_eps)
            x = x + L.mlp(mp, h, cfg.mlp)
            mlp_i += 1
    new_states = {"conv": jnp.stack(new_conv), "ssm": jnp.stack(new_ssm)}
    return x, new_states


def forward(params: Params, cfg: ModelConfig,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            attn_impl: str = "full", remat: bool = False,
            act_specs: Optional[Dict[str, Any]] = None) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, vocab_p).

    `remat=True` checkpoints each scanned layer (training memory policy).
    `act_specs` pins activation shardings (with_sharding_constraint) so
    GSPMD never loses the batch sharding through the embed gather — pass
    {"hidden": PartitionSpec, "logits": PartitionSpec}.
    """

    def constrain(t, key="hidden"):
        if act_specs is not None and key in act_specs:
            return jax.lax.with_sharding_constraint(t, act_specs[key])
        return t

    def barrier(t):
        # defeat XLA loop-invariant code motion: without this, a
        # convert(dynamic-slice(remat_stack)) in the backward while-loop is
        # rewritten to dynamic-slice(convert(remat_stack)), materializing an
        # f32 copy of the ENTIRE (L,B,S,D) residual stack.
        return lax.optimization_barrier(t) if remat else t

    x = constrain(_embed_input(params, cfg, tokens, embeds))
    b = x.shape[0]

    def _maybe_remat(f):
        return jax.checkpoint(f) if remat else f

    if is_hybrid(cfg):
        @_maybe_remat
        def body(xc, gp):
            xc = barrier(xc)
            xc, _ = _group_forward(gp, xc, cfg, None, attn_impl)
            return constrain(xc), None
        x, _ = lax.scan(body, x, params["groups"], unroll=SCAN_UNROLL)
    elif is_rwkv(cfg):
        d = cfg.d_model
        r = cfg.rwkv or R.RWKVConfig()
        nh = d // r.head_size

        @_maybe_remat
        def body(xc, lp):
            xc = barrier(xc)
            st = {"tm_shift": jnp.zeros((b, d), xc.dtype),
                  "cm_shift": jnp.zeros((b, d), xc.dtype),
                  "wkv": jnp.zeros((b, nh, r.head_size, r.head_size),
                                   jnp.float32)}
            xc, _ = R.rwkv_block(lp["rwkv"], xc, cfg, st, lp["norm1"],
                                 lp["norm2"],
                                 partial(L.rmsnorm, eps=cfg.norm_eps))
            return constrain(xc), None
        x, _ = lax.scan(body, x, params["layers"], unroll=SCAN_UNROLL)
    else:
        @_maybe_remat
        def body(xc, lp):
            xc = barrier(xc)
            h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
            if attn_impl == "chunked":
                xc = xc + L.attention_chunked(lp["attn"], h, cfg)
            else:
                xc = xc + L.attention_full(lp["attn"], h, cfg)
            h = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
            xc = xc + _mlp_branch(lp, h, cfg)
            return constrain(xc), None
        x, _ = lax.scan(body, x, params["layers"], unroll=SCAN_UNROLL)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return constrain(_head(params, cfg, x), "logits")


# ---------------------------------------------------------------------------
# Serving state + decode step
# ---------------------------------------------------------------------------

def init_serve_state(cfg: ModelConfig, batch: int, max_len: int,
                     kv_dtype=jnp.bfloat16) -> Params:
    # pos is PER-SLOT (B,): slot-based continuous batching (vLLM-style)
    state: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    if is_hybrid(cfg):
        g = cfg.hybrid
        n_groups = cfg.n_layers // g.group_size
        n_mamba = g.group_size - 1
        state["kv"] = L.init_kv_cache(cfg, batch, max_len, n_groups, kv_dtype)
        state["mamba"] = {
            "conv": jnp.zeros((n_groups, n_mamba, batch, g.d_conv - 1,
                               M.d_inner(cfg)), jnp.float32),
            "ssm": jnp.zeros((n_groups, n_mamba, batch, M.d_inner(cfg),
                              g.d_state), jnp.float32),
        }
    elif is_rwkv(cfg):
        state["rwkv"] = R.init_rwkv_state(cfg, batch, cfg.n_layers)
    else:
        state["kv"] = L.init_kv_cache(cfg, batch, max_len, cfg.n_layers,
                                      kv_dtype)
    return state


def decode_step(params: Params, state: Params, cfg: ModelConfig,
                tokens: jnp.ndarray,
                active: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode.  tokens: (B, 1) -> logits (B, 1, vocab_p).

    `active` (B,) bool marks slots that are really decoding this step.
    Inactive slots neither advance their position nor mutate recurrent
    state: their attention-KV write lands at their current pos and is
    overwritten when the slot next steps for real, and mamba/rwkv
    recurrent updates are masked back to the old state below.  Their
    logits are garbage and must be ignored by the caller."""
    x = _embed_input(params, cfg, tokens, None)
    pos = state["pos"]
    if active is None:
        adv = jnp.ones_like(pos)
    else:
        adv = active.astype(pos.dtype)
    new_state: Params = {"pos": pos + adv}

    def keep_active(new, old, batch_axis):
        """new where the slot is active, old otherwise (recurrent state
        of inactive slots must not see the pad token)."""
        if active is None:
            return new
        shape = [1] * new.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), new, old)

    if is_hybrid(cfg):
        g = cfg.hybrid

        def body(xc, inp):
            gp, kc, vc, conv, ssm = inp
            sts = {"conv": conv, "ssm": ssm}
            # decode = sequence of length 1 through the group, with KV cache
            # for the attention position
            b = xc.shape[0]
            new_conv, new_ssm = [], []
            moe_i = mlp_i = 0
            for p_ in range(g.group_size):
                if p_ == g.group_size - 1:
                    h = L.rmsnorm(gp["attn_norm"], xc, cfg.norm_eps)
                    att, kc, vc = L.attention_decode(gp["attn"], h, cfg, kc,
                                                     vc, pos)
                    xc = xc + att
                else:
                    lp = jax.tree.map(lambda a, i=p_: a[i], gp["mamba"])
                    npm = jax.tree.map(lambda a, i=p_: a[i], gp["mamba_norm"])
                    h = L.rmsnorm(npm, xc, cfg.norm_eps)
                    out, c1, s1 = M.mamba_sequence(
                        lp, h, cfg, sts["conv"][p_].astype(xc.dtype),
                        sts["ssm"][p_])
                    xc = xc + out
                    new_conv.append(c1.astype(jnp.float32))
                    new_ssm.append(s1)
                if p_ % 2 == 0:
                    mp = jax.tree.map(lambda a, i=moe_i: a[i], gp["moe"])
                    mn = jax.tree.map(lambda a, i=moe_i: a[i], gp["moe_norm"])
                    h = L.rmsnorm(mn, xc, cfg.norm_eps)
                    xc = xc + L.moe_mlp(mp, h, cfg.moe)
                    moe_i += 1
                else:
                    mp = jax.tree.map(lambda a, i=mlp_i: a[i], gp["mlp"])
                    mn = jax.tree.map(lambda a, i=mlp_i: a[i], gp["mlp_norm"])
                    h = L.rmsnorm(mn, xc, cfg.norm_eps)
                    xc = xc + L.mlp(mp, h, cfg.mlp)
                    mlp_i += 1
            return xc, (kc, vc, jnp.stack(new_conv), jnp.stack(new_ssm))

        x, (k2, v2, conv2, ssm2) = lax.scan(
            body, x, (params["groups"], state["kv"]["k"], state["kv"]["v"],
                      state["mamba"]["conv"], state["mamba"]["ssm"]))
        new_state["kv"] = {"k": k2, "v": v2}
        # conv/ssm: (n_groups, n_mamba, B, ...) — batch axis 2
        new_state["mamba"] = {
            "conv": keep_active(conv2, state["mamba"]["conv"], 2),
            "ssm": keep_active(ssm2, state["mamba"]["ssm"], 2)}

    elif is_rwkv(cfg):
        def body(xc, inp):
            lp, tm_s, cm_s, wkv = inp
            st = {"tm_shift": tm_s, "cm_shift": cm_s, "wkv": wkv}
            xc, st2 = R.rwkv_block(lp["rwkv"], xc, cfg, st, lp["norm1"],
                                   lp["norm2"],
                                   partial(L.rmsnorm, eps=cfg.norm_eps))
            return xc, (st2["tm_shift"], st2["cm_shift"], st2["wkv"])

        rs = state["rwkv"]
        x, (tm2, cm2, wkv2) = lax.scan(
            body, x, (params["layers"], rs["tm_shift"], rs["cm_shift"],
                      rs["wkv"]))
        # tm/cm/wkv: (L, B, ...) — batch axis 1
        new_state["rwkv"] = {
            "tm_shift": keep_active(tm2, rs["tm_shift"], 1),
            "cm_shift": keep_active(cm2, rs["cm_shift"], 1),
            "wkv": keep_active(wkv2, rs["wkv"], 1)}

    else:
        def body(xc, inp):
            lp, kc, vc = inp
            h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
            att, kc, vc = L.attention_decode(lp["attn"], h, cfg, kc, vc, pos)
            xc = xc + att
            h = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
            xc = xc + _mlp_branch(lp, h, cfg)
            return xc, (kc, vc)

        x, (k2, v2) = lax.scan(body, x, (params["layers"], state["kv"]["k"],
                                         state["kv"]["v"]))
        new_state["kv"] = {"k": k2, "v": v2}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _head(params, cfg, x), new_state


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, embeds: Optional[jnp.ndarray] = None,
            remat: bool = False, attn_impl: str = "full",
            act_specs=None) -> jnp.ndarray:
    """Causal LM loss; padded vocab entries are masked out of the softmax."""
    logits = forward(params, cfg, tokens=tokens, embeds=embeds, remat=remat,
                     attn_impl=attn_impl, act_specs=act_specs)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_p != cfg.vocab:
        mask = jnp.arange(cfg.vocab_p) < cfg.vocab
        logits = jnp.where(mask, logits, L.NEG_INF)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def reset_slot(state: Params, cfg: ModelConfig, slot: int) -> Params:
    """Zero one batch slot\'s serving state (slot reuse in the engine)."""
    out = dict(state)
    out["pos"] = state["pos"].at[slot].set(0)
    if "rwkv" in state:
        rs = state["rwkv"]
        out["rwkv"] = {k: v.at[:, slot].set(0) for k, v in rs.items()}
    if "mamba" in state:
        ms = state["mamba"]
        out["mamba"] = {k: v.at[:, :, slot].set(0) for k, v in ms.items()}
    # attention KV needs no reset: the per-slot pos mask hides stale entries
    return out

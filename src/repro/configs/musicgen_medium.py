"""musicgen-medium — MusicGen Medium (arXiv:2306.05284; hf) [audio].

Decoder-only over EnCodec tokens: 48L d_model=1536, 24 heads (kv=24 — full
MHA), d_ff=6144, vocab=2048 (per-codebook).  The EnCodec frontend and the
4-codebook delay pattern are STUBS — input_specs() supplies precomputed
frame embeddings (B, S, d_model).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048, d_head=64,
    mlp="relu2",  # approximates musicgen's non-gated 2-matrix FFN
    frontend="frame_stub", rope_theta=1e4,
)

"""nemotron-4-15b — Nemotron-4 15B (arXiv:2402.16819; unverified) [dense].

32L d_model=6144, 48 heads GQA kv=8 (head_dim 128), d_ff=24576,
vocab=256000.  Squared-ReLU MLP (no gate), large multilingual vocab.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000, d_head=128,
    mlp="relu2", rope_theta=1e4,
)

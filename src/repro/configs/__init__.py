"""Architecture registry: one module per assigned architecture.

Each module exposes CONFIG (the exact published configuration) and the
registry maps ``--arch <id>`` to it.  `smoke_config(id)` returns the reduced
same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig, reduced_for_smoke

from . import (granite_moe_3b_a800m, jamba_1_5_large_398b, musicgen_medium,
               nemotron_4_15b, pixtral_12b, qwen3_moe_235b_a22b, rwkv6_7b,
               tinyllama_1_1b, yi_34b, yi_9b)

_MODULES = {
    "rwkv6-7b": rwkv6_7b,
    "yi-34b": yi_34b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "nemotron-4-15b": nemotron_4_15b,
    "yi-9b": yi_9b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "pixtral-12b": pixtral_12b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "musicgen-medium": musicgen_medium,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return _MODULES[arch].CONFIG


def smoke_config(arch: str) -> ModelConfig:
    return reduced_for_smoke(get_config(arch))


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}

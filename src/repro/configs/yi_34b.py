"""yi-34b — Yi 34B (arXiv:2403.04652; hf) [dense].

60L d_model=7168, 56 heads GQA kv=8 (head_dim 128), d_ff=20480, vocab=64000.
llama-architecture with SwiGLU.  56 q heads pad to 64 / kv to 16 for TP=16
(function-preserving zero weights; see DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, d_head=128,
)

"""rwkv6-7b — RWKV-6 "Finch" 7B (arXiv:2404.05892; hf) [ssm].

32L d_model=4096, attention-free (64 heads x head_size 64), d_ff=14336,
vocab=65536.  Data-dependent decay time mixing; O(1)-state decode.
"""
from ..models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536, d_head=64,
    mixer="rwkv6", rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=128),
)

"""qwen3-moe-235b-a22b — Qwen3 235B-A22B MoE (hf:Qwen/Qwen3-30B-A3B family;
hf) [moe].

94L d_model=4096, 64 heads GQA kv=4 (head_dim 128), MoE 128 experts top-8
with d_ff_expert=1536, vocab=151936.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, d_head=128,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
)

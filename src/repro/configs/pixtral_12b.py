"""pixtral-12b — Pixtral 12B (hf:mistralai/Pixtral-12B-2409; unverified) [vlm].

Backbone only (task spec): mistral-nemo-style decoder, 40L d_model=5120,
32 heads GQA kv=8 (head_dim 128), d_ff=14336, vocab=131072.  The pixtral-ViT
frontend is a STUB — input_specs() supplies precomputed patch embeddings
(B, S, d_model) in place of token embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, d_head=128,
    frontend="patch_stub",
)

"""jamba-1.5-large-398b — Jamba 1.5 Large (arXiv:2403.19887; hf) [hybrid].

72L d_model=8192: Mamba+attention 1:7 interleave (9 groups of 7 Mamba +
1 attention; 64 heads GQA kv=8, head_dim 128), MoE 16 experts top-2 on
every other layer, d_ff=24576, vocab=65536.  Totals ~398B params / ~94B
active (verified analytically in tests).
"""
from ..models.config import HybridConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536, d_head=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    hybrid=HybridConfig(group_size=8, d_state=16, d_conv=4, expand=2),
)

"""tinyllama-1.1b — TinyLlama 1.1B (arXiv:2401.02385; hf) [dense].

22L d_model=2048, 32 heads GQA kv=4 (head_dim 64), d_ff=5632, vocab=32000.
llama2-architecture small model.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000, d_head=64,
    rope_theta=1e4,
)

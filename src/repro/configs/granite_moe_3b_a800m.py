"""granite-moe-3b-a800m — IBM Granite 3.0 3B-A800M MoE
(hf:ibm-granite/granite-3.0-1b-a400m-base family; hf) [moe].

32L d_model=1536, 24 heads GQA kv=8 (head_dim 64), MoE 40 experts top-8
with d_ff_expert=512, vocab=49155.  40 experts pad to 48 and vocab to
49168 for TP=16 (function-preserving; see DESIGN.md).
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155, d_head=64,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
)

"""Training launcher: --arch <id> selects any assigned architecture.

On this CPU container it trains the reduced (smoke) variant of the chosen
arch by default; --full uses the published config (for real hardware).

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 50
"""
from __future__ import annotations

import argparse

from ..configs import ARCHS, get_config, smoke_config
from ..train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="use the published config (needs real hardware)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--hbm-budget-gb", type=float, default=16.0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    tc = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                     lr=args.lr, checkpoint_dir=args.checkpoint_dir,
                     hbm_budget_bytes=args.hbm_budget_gb * 1e9)
    print(f"[launch] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    trainer = Trainer(cfg, tc)
    if trainer.plan is not None:
        print(f"[launch] advisor layout: {trainer.plan.choices}")
    out = trainer.run()
    print(f"[launch] loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()

"""Analytic op census: FLOPs / HBM bytes / collective bytes per chip.

WHY THIS EXISTS: XLA's compiled.cost_analysis() counts a while-loop body
ONCE, not multiplied by its trip count (verified experimentally — see
EXPERIMENTS.md §Roofline methodology).  Every layer stack in this framework
is a lax.scan, so raw cost_analysis underestimates by ~n_layers.  The
census computes the same quantities analytically (matmul-exact for FLOPs;
standard operand+result accounting for HBM; sharding-derived collective
volumes) and is VALIDATED against cost_analysis on fully-unrolled reduced
configs (tests/test_census.py), where the two agree.

All numbers are per chip per step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from ..models.config import ModelConfig
from ..models import mamba as M


@dataclasses.dataclass
class Census:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    detail: Dict[str, float]


def _attn_layer_flops(cfg, b, s, ctx, decode: bool) -> float:
    d, nh, nkv, hd = cfg.d_model, cfg.heads, cfg.kv_heads, cfg.d_head
    toks = b * (1 if decode else s)
    proj = 2.0 * toks * d * (nh + 2 * nkv + nh) * hd     # QKV + O
    if decode:
        attn = 4.0 * b * ctx * nh * hd                   # QK^T + PV
    else:
        attn = 0.5 * 4.0 * b * s * s * nh * hd           # causal half
    return proj + attn


def _mlp_layer_flops(cfg, toks) -> float:
    mult = 3.0 if cfg.mlp == "swiglu" else 2.0
    return 2.0 * toks * mult * cfg.d_model * cfg.d_ff


def _moe_layer_flops(cfg, toks) -> float:
    moe = cfg.moe
    router = 2.0 * toks * cfg.d_model * moe.experts
    expert = 2.0 * toks * moe.top_k * moe.capacity_factor \
        * 3.0 * cfg.d_model * moe.d_ff_expert
    return router + expert


def _rwkv_layer_flops(cfg, toks) -> float:
    d = cfg.d_model
    r = cfg.rwkv
    hs = r.head_size
    proj = 2.0 * toks * d * d * 5                         # r,k,v,g,o
    lora = 2.0 * toks * d * (5 * 32 + 2 * r.decay_lora)
    wkv = 6.0 * toks * d * hs                             # kv, y, decay-update
    cm = 2.0 * toks * (2 * d * cfg.d_ff + d * d)
    return proj + lora + wkv + cm


def _mamba_layer_flops(cfg, toks) -> float:
    d = cfg.d_model
    h = cfg.hybrid
    din = M.d_inner(cfg)
    dr = M.dt_rank(cfg)
    proj = 2.0 * toks * d * 2 * din + 2.0 * toks * din * d
    xproj = 2.0 * toks * din * (dr + 2 * h.d_state) + 2.0 * toks * dr * din
    conv = 2.0 * toks * h.d_conv * din
    scan = 6.0 * toks * din * h.d_state                   # h update + y
    return proj + xproj + conv + scan


def forward_flops(cfg: ModelConfig, b: int, s: int, ctx: int,
                  decode: bool) -> Dict[str, float]:
    toks = b * (1 if decode else s)
    out: Dict[str, float] = {}
    if cfg.hybrid is not None:
        g = cfg.hybrid
        n_groups = cfg.n_layers // g.group_size
        n_attn = n_groups
        n_mamba = cfg.n_layers - n_attn
        n_moe = cfg.n_layers // 2
        n_mlp = cfg.n_layers - n_moe
        out["attn"] = n_attn * _attn_layer_flops(cfg, b, s, ctx, decode)
        out["mamba"] = n_mamba * _mamba_layer_flops(cfg, toks)
        out["moe"] = n_moe * _moe_layer_flops(cfg, toks)
        out["mlp"] = n_mlp * _mlp_layer_flops(cfg, toks)
    elif cfg.mixer == "rwkv6":
        out["rwkv"] = cfg.n_layers * _rwkv_layer_flops(cfg, toks)
    else:
        out["attn"] = cfg.n_layers * _attn_layer_flops(cfg, b, s, ctx, decode)
        if cfg.moe is not None:
            out["moe"] = cfg.n_layers * _moe_layer_flops(cfg, toks)
        else:
            out["mlp"] = cfg.n_layers * _mlp_layer_flops(cfg, toks)
    out["head"] = 2.0 * toks * cfg.d_model * cfg.vocab_p
    return out


def census(cfg: ModelConfig, kind: str, batch: int, seq: int,
           n_chips: int, tp: int = 16,
           param_bytes: float = 2.0, remat: bool = True,
           grad_compression: Optional[str] = None,
           pod_dp: int = 1, kv_bytes_per_elem: Optional[float] = None
           ) -> Census:
    """Per-chip census for one cell.

    kind: train | prefill | decode.  For decode, seq is the KV length.
    n_chips = tp * dp (* pod_dp); activations shard over dp, weights over
    tp x dp (FSDP), collectives per DESIGN.md §5.
    """
    decode = kind == "decode"
    kvb = param_bytes if kv_bytes_per_elem is None else kv_bytes_per_elem
    b, s, ctx = batch, (1 if decode else seq), seq
    fwd = forward_flops(cfg, b, s if not decode else seq, ctx, decode)
    fwd_total = sum(fwd.values())
    if kind == "train":
        # bwd = 2x fwd; remat adds ~1x fwd recompute; optimizer ~10/param
        n_params = cfg.param_count(padded=True)
        flops_total = fwd_total * (4.0 if remat else 3.0) + 10.0 * n_params
    else:
        flops_total = fwd_total
    flops_chip = flops_total / n_chips

    # ---- HBM bytes (per chip) ----
    n_params = cfg.param_count(padded=True)
    d = cfg.d_model
    toks_local = b * s / (n_chips / tp)   # activations shard over dp axes
    act_unit = toks_local * d * 2.0       # one (B_local, S, D) bf16 tensor
    # per layer: ~6 activation tensor traversals fwd, ~12 bwd (+recompute)
    act_traffic = cfg.n_layers * act_unit * (18 if kind == "train" else 6)
    if kind == "train":
        # params: bf16 read fwd+bwd(+remat) + f32 master read/write +
        # grads f32 write/read + adam moments read+write (f32)
        pbytes = n_params / n_chips * (2 * 3 + 4 * 2 + 4 * 2 + 8 * 2)
    else:
        pbytes = n_params / n_chips * param_bytes
    kv_bytes = 0.0
    if decode:
        if cfg.hybrid is not None or cfg.mixer == "attn":
            # whole cache read once per step; sharded over dp (batch) x tp
            # (kv heads) => /n_chips
            n_attn = cfg.attn_layers
            kv_bytes = 2.0 * n_attn * b * seq * cfg.kv_heads * cfg.d_head \
                * kvb / n_chips
        if cfg.mixer == "rwkv6":
            r = cfg.rwkv
            nh = d // r.head_size
            kv_bytes = 2.0 * cfg.n_layers * b * nh * r.head_size ** 2 * 4.0 \
                / n_chips
        if cfg.hybrid is not None:
            din = M.d_inner(cfg)
            kv_bytes += 2.0 * (cfg.n_layers - cfg.attn_layers) * b * din \
                * (cfg.hybrid.d_state + cfg.hybrid.d_conv - 1) * 4.0 / n_chips
    hbm = act_traffic + pbytes + kv_bytes

    # ---- collective bytes (per chip wire) ----
    dp = n_chips // tp // pod_dp
    wire = 0.0
    detail = dict(fwd)
    if kind == "train":
        # FSDP all-gather of bf16 params over dp: fwd + bwd
        wire += 2.0 * (n_params / n_chips) * 2.0 * (dp - 1)
        # gradient reduce-scatter over dp (+ all-reduce over pods)
        gbytes = 1.0 if grad_compression == "q8" else 4.0
        wire += (n_params / n_chips) * gbytes * (dp - 1)
        if pod_dp > 1:
            wire += 2.0 * (n_params / (n_chips / pod_dp)) * gbytes \
                * (pod_dp - 1) / pod_dp
    # TP all-reduce of layer outputs (attn + mlp) over tp
    n_ar = 2 * cfg.n_layers * (3 if kind == "train" else 1)
    wire += n_ar * (toks_local * d * 2.0) * 2.0 * (tp - 1) / tp
    detail.update({"act_traffic": act_traffic, "param_bytes_hbm": pbytes,
                   "kv_bytes": kv_bytes})
    return Census(flops=flops_chip, hbm_bytes=hbm, wire_bytes=wire,
                  detail=detail)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  with mesh:  jax.jit(step, in_shardings, out_shardings)
  .lower(**input_specs).compile()  then record memory_analysis() (proves it
fits), cost_analysis() (FLOPs/bytes for the roofline), and the collective
schedule parsed from the optimized HLO.

Results are written incrementally to results/dryrun/<arch>__<shape>__<mesh>
.json so the full 80-cell sweep is resumable.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..distributed.sharding import activation_specs, param_specs
from ..models import model as MD
from ..models.config import pad_for_tp
from ..optim import AdamWConfig, adamw_init
from ..train.step import make_decode_step, make_prefill_step, make_train_step
from . import roofline as RL
from .mesh import dist_config, make_production_mesh
from .specs import SHAPES, cell_supported, input_specs, model_shardings

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

TP = 16


def _opt_state_shardings(params_shaped, opt_cfg, mesh, cfg, dist):
    """Opt-state ShapeDtypeStructs with shardings derived from param specs."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state_shape = jax.eval_shape(lambda: adamw_init(params_shaped, opt_cfg))
    pspecs = param_specs(params_shaped, cfg, dist, mesh)

    def moment_spec(path, leaf):
        # path: moments/<param path...>/<m|v|m_q|m_s|v_q|v_s>
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        if names[0] == "step":
            return P()
        name = names[-1]
        # locate the param spec by stripping 'moments' and the moment name
        sub = pspecs
        for n in names[1:-1]:
            sub = sub[n]
        spec = tuple(sub)
        if name.endswith("_s"):  # block scales: last dim replicated
            spec = spec[:-1] + (None,) if spec else spec
        # pad spec to leaf rank
        spec = (None,) * (leaf.ndim - len(spec)) + spec[:leaf.ndim]
        return P(*spec)

    specs = jax.tree_util.tree_map_with_path(moment_spec, state_shape)
    shaped = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        state_shape, specs)
    return shaped, specs


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             opt_codec: str = "f32", kv_dtype=jnp.bfloat16,
             param_dtype=None, grad_compression=None,
             variant: str = "baseline", parallel_mode: str = "tp",
             kv_seq_shard: bool = False) -> dict:
    # with seq-sharded KV the kv heads stay logical (no padding waste)
    cfg = pad_for_tp(get_config(arch), TP, pad_kv=not kv_seq_shard)
    info = SHAPES[shape]
    ok, reason = cell_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "variant": variant, "status": "skipped", "reason": reason}
    if not ok:
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = dist_config(multi_pod=multi_pod, parallel_mode=parallel_mode,
                       kv_seq_shard=kv_seq_shard)
    n_dev = mesh.size
    kind = info["kind"]
    if param_dtype is None:
        param_dtype = jnp.float32 if kind == "train" else jnp.bfloat16

    t0 = time.perf_counter()
    with mesh:
        params_shaped, pspecs = model_shardings(cfg, mesh, dist, param_dtype)
        batch = input_specs(cfg, shape, mesh, dist, kv_dtype=kv_dtype)

        act = activation_specs(dist)
        act_specs = {"hidden": act["hidden"], "logits": act["logits"]}
        if kind == "train":
            opt_cfg = AdamWConfig(state_codec=opt_codec)
            opt_shaped, _ = _opt_state_shardings(params_shaped, opt_cfg,
                                                 mesh, cfg, dist)
            step = make_train_step(cfg, opt_cfg, remat=True,
                                   grad_compression=grad_compression,
                                   act_specs=act_specs)
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(params_shaped, opt_shaped, batch)
        elif kind == "prefill":
            step = make_prefill_step(cfg, act_specs=act_specs)
            jitted = jax.jit(step)
            lowered = jitted.lower(params_shaped, batch)
        else:  # decode
            step = make_decode_step(cfg)
            jitted = jax.jit(step, donate_argnums=(1,))
            lowered = jitted.lower(params_shaped, batch["state"],
                                   batch["tokens"])
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits
    ca = RL.cost_analysis_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed")
           if k in ca})  # FLOPs/bytes for the roofline
    hlo = compiled.as_text()
    rl = RL.analyze(compiled, n_dev, hlo_text=hlo)
    mf = RL.model_flops(cfg, info)

    result.update({
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "param_count": cfg.param_count(),
        "param_count_padded": cfg.param_count(padded=True),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": rl.summary(),
        "collective_counts": rl.collectives,
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / max(rl.flops_per_chip, 1.0),
    })
    return result


def cell_name(arch, shape, multi_pod, variant="baseline"):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    v = "" if variant == "baseline" else f"__{variant}"
    return f"{arch}__{shape}__{mesh_name}{v}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt-codec", default="f32", choices=["f32", "q8"])
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "i8sim"])
    ap.add_argument("--grad-compression", default=None, choices=[None, "q8"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--parallel", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--kv-seq-shard", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    kv_dtype = jnp.bfloat16 if args.kv_dtype == "bf16" else jnp.int8
    failures = 0
    for arch, shape, mp in cells:
        name = cell_name(arch, shape, mp, args.variant)
        out = RESULTS_DIR / f"{name}.json"
        if out.exists() and not args.force:
            print(f"[skip cached] {name}")
            continue
        print(f"[run] {name}", flush=True)
        try:
            res = run_cell(arch, shape, multi_pod=mp,
                           opt_codec=args.opt_codec, kv_dtype=kv_dtype,
                           grad_compression=args.grad_compression,
                           variant=args.variant,
                           parallel_mode=args.parallel,
                           kv_seq_shard=args.kv_seq_shard)
        except Exception as e:  # noqa: BLE001 — record, continue sweep
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "variant": args.variant, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
        out.write_text(json.dumps(res, indent=2, default=str))
        print(f"  -> {res['status']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

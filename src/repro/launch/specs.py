"""Input shape cells + ShapeDtypeStruct stand-ins for the dry-run.

The four assigned shape cells per LM architecture:

    train_4k     seq 4096   global_batch 256   (training, lowers train_step)
    prefill_32k  seq 32768  global_batch 32    (inference prefill)
    decode_32k   KV 32768   global_batch 128   (one-token decode)
    long_500k    KV 524288  global_batch 1     (long-context decode;
                 SSM/hybrid only — full-attention archs are skipped)

`input_specs` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input — no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import (DistConfig, activation_specs, param_specs,
                                    serve_state_specs)
from ..models import model as MD
from ..models.config import ModelConfig

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (shape-sheet rule)."""
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("skipped: pure full-attention arch; long_500k "
                       "requires sub-quadratic sequence mixing "
                       "(see DESIGN.md §6)")
    return True, ""


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: str, mesh, dist: DistConfig,
                kv_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStructs (with shardings) for the cell's step-function
    inputs.  For stub frontends (vlm/audio), precomputed patch/frame
    embeddings replace token embeddings."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    act = activation_specs(dist)

    def sh(spec):
        return NamedSharding(mesh, spec)

    if info["kind"] == "train":
        batch = {
            "tokens": _sds((b, s), jnp.int32, sh(act["tokens"])),
            "labels": _sds((b, s), jnp.int32, sh(act["labels"])),
        }
        if cfg.frontend != "tokens":
            batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16,
                                   sh(act["embeds"]))
        return batch
    if info["kind"] == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32, sh(act["tokens"]))}
        if cfg.frontend != "tokens":
            batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16,
                                   sh(act["embeds"]))
        return batch
    # decode: one new token + serving state of length `seq`
    state_shape = jax.eval_shape(
        lambda: MD.init_serve_state(cfg, b, s, kv_dtype=kv_dtype))
    specs = serve_state_specs(state_shape, cfg, dist, mesh, b)
    state = jax.tree.map(lambda l, sp: _sds(l.shape, l.dtype, sh(sp)),
                         state_shape, specs)
    dp = dist.dp_axes
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = act["tokens"] if b % dp_size == 0 else P(None, None)
    return {
        "tokens": _sds((b, 1), jnp.int32, sh(tok_spec)),
        "state": state,
    }


def model_shardings(cfg: ModelConfig, mesh, dist: DistConfig,
                    param_dtype=jnp.bfloat16):
    """(param ShapeDtypeStructs with shardings, spec pytree)."""
    shapes = MD.params_shape(cfg, param_dtype)
    specs = param_specs(shapes, cfg, dist, mesh)
    shaped = jax.tree.map(
        lambda l, sp: _sds(l.shape, l.dtype, NamedSharding(mesh, sp)),
        shapes, specs)
    return shaped, specs

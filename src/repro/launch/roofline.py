"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_wire_bytes / (chips * link_bw)

Sources: compiled.cost_analysis() for FLOPs/bytes; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum per-chip wire
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using ring-algorithm estimates:

    all-gather      (g-1)/g * result_bytes        (recv per chip)
    reduce-scatter  (g-1)   * result_bytes        (result is the shard)
    all-reduce      2(g-1)/g * operand_bytes
    all-to-all      (g-1)/g * result_bytes
    collective-permute  result_bytes

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "u1": 0.125,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of 'bf16[2,3]{...}' or a tuple '(f32[2], f32[2])'."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [ngroups, group_size]
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes_per_chip: float = 0.0
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, b: float):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + b
        self.wire_bytes_per_chip += b


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_start: set = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        # avoid double counting async -start/-done pairs: skip -done
        if "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        b = _shape_bytes(shape_str)
        if kind == "all-gather":
            wire = b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = b * (g - 1)
        elif kind == "all-reduce":
            wire = 2 * b * (g - 1) / g
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = b
        stats.add(kind, wire)
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    n_devices: int
    collectives: Dict[str, int]

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if terms overlap perfectly."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
        }


def cost_analysis_dict(compiled) -> Dict:
    """compiled.cost_analysis() normalized to a dict.

    jax returns a dict or a one-element list of dicts depending on version;
    every caller (analyze, dryrun, tests) goes through this shim.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca


def analyze(compiled, n_devices: int, hlo_text: Optional[str] = None
            ) -> Roofline:
    """Build roofline terms from a compiled executable.

    cost_analysis() FLOPs/bytes on SPMD modules are per-device program
    costs (the module is the per-device program).
    """
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    col = parse_collectives(text, n_devices)
    return Roofline(flops_per_chip=flops, hbm_bytes_per_chip=bytes_accessed,
                    wire_bytes_per_chip=col.wire_bytes_per_chip,
                    n_devices=n_devices, collectives=dict(col.counts))


def model_flops(cfg, shape_info: Dict, n_layers_active: Optional[int] = None
                ) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens."""
    n = cfg.param_count()
    if cfg.moe is not None:
        moe = cfg.moe
        # active experts fraction of the MoE weights
        e_all = moe.n_experts
        moe_frac = moe.top_k / e_all
        if cfg.hybrid is not None:
            n_moe_layers = cfg.n_layers // 2
        else:
            n_moe_layers = cfg.n_layers // moe.every_k_layers
        moe_params = n_moe_layers * (e_all * 3 * cfg.d_model
                                     * moe.d_ff_expert)
        n = n - moe_params + moe_params * moe_frac
    kind = shape_info["kind"]
    if kind == "train":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * shape_info["batch"]

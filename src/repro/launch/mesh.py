"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

from ..distributed.sharding import DistConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dist_config(*, multi_pod: bool = False, fsdp: bool = True,
                fsdp_over_pod: bool = False, parallel_mode: str = "tp",
                kv_seq_shard: bool = False) -> DistConfig:
    return DistConfig(pod_axis="pod" if multi_pod else None, fsdp=fsdp,
                      fsdp_over_pod=fsdp_over_pod,
                      parallel_mode=parallel_mode, kv_seq_shard=kv_seq_shard)


def make_smoke_mesh():
    """1x1 mesh on the single CPU device (tests of the sharded code path)."""
    return jax.make_mesh((1, 1), ("data", "model"))

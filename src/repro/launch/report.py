"""Roofline report: join dry-run artifacts with the analytic census.

    PYTHONPATH=src python -m repro.launch.report

Reads results/dryrun/*.json (memory_analysis + raw cost_analysis +
HLO-parsed collective kinds), computes census-based roofline terms per
cell, and writes results/roofline.json + a markdown table for
EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

from ..configs import ARCHS, get_config
from ..models.config import pad_for_tp
from .census import census
from .roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops
from .specs import SHAPES

RESULTS = Path(__file__).resolve().parents[3] / "results"
V5E_HBM = 16e9


def _advice(bottleneck: str, cell: Dict) -> str:
    if bottleneck == "collective":
        return ("overlap FSDP all-gathers with layer compute and compress "
                "the gradient all-reduce (q8 wire)")
    if bottleneck == "memory":
        if cell["shape"].startswith(("decode", "long")):
            return "quantize weights/KV (q8/q4) to cut HBM traffic"
        return "recompute less (selective remat) or shrink activations"
    return "increase per-chip arithmetic intensity (larger local batch)"


def cell_report(arch: str, shape: str, mesh: str, dry: Optional[dict],
                variant: str = "baseline", **census_kw) -> Dict:
    cfg = pad_for_tp(get_config(arch), 16)
    info = SHAPES[shape]
    n_chips = 512 if mesh == "2x16x16" else 256
    pod_dp = 2 if mesh == "2x16x16" else 1
    c = census(cfg, info["kind"], info["batch"], info["seq"], n_chips,
               tp=16, pod_dp=pod_dp, **census_kw)
    t_c = c.flops / PEAK_FLOPS
    t_m = c.hbm_bytes / HBM_BW
    t_w = c.wire_bytes / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_w}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, info) / n_chips
    t_bound = max(terms.values())
    out = {
        "arch": arch, "shape": shape, "mesh": mesh, "variant": variant,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_w,
        "bottleneck": bottleneck,
        "flops_per_chip": c.flops,
        "hbm_bytes_per_chip": c.hbm_bytes,
        "wire_bytes_per_chip": c.wire_bytes,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": mf / max(c.flops, 1.0),
        "roofline_fraction": (mf / PEAK_FLOPS) / max(t_bound, 1e-12),
        "advice": _advice(bottleneck, {"shape": shape}),
    }
    if dry is not None and dry.get("status") == "ok":
        out["memory_temp_gb"] = dry["memory"]["temp_bytes"] / 1e9
        out["memory_args_gb"] = (dry["memory"]["argument_bytes"] or 0) / 1e9
        out["compile_s"] = dry.get("compile_s")
        out["collective_kinds"] = dry.get("collective_counts", {})
        out["raw_cost_analysis"] = {
            "flops": dry["roofline"]["flops_per_chip"],
            "bytes": dry["roofline"]["hbm_bytes_per_chip"],
        }
    return out


def main() -> None:
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                f = RESULTS / "dryrun" / f"{arch}__{shape}__{mesh}.json"
                dry = json.loads(f.read_text()) if f.exists() else None
                if dry is not None and dry["status"] == "skipped":
                    rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                                 "variant": "baseline", "status": "skipped",
                                 "reason": dry["reason"]})
                    continue
                r = cell_report(arch, shape, mesh, dry)
                r["status"] = "ok" if dry else "census-only"
                rows.append(r)
    (RESULTS / "roofline.json").write_text(json.dumps(rows, indent=1))

    # markdown table (single-pod cells only, per the spec)
    lines = ["| arch | shape | t_comp | t_mem | t_coll | bound | "
             "MF/HLO | roofline-frac | temp GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != "16x16":
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (sub-quadratic rule) | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f}ms "
            f"| {r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r.get('memory_temp_gb', float('nan')):.1f} |")
    (RESULTS / "roofline_table.md").write_text("\n".join(lines))
    print("\n".join(lines))


if __name__ == "__main__":
    main()

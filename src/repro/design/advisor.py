"""Tensor physical-design advisor — the paper's DTAc, re-targeted at a TPU
training/serving job (DESIGN.md §3).

"Indexes" are the persistent tensor classes of a job (weights, optimizer
moments, gradients-on-the-wire, KV cache); "compression methods" are the
codecs; the "storage bound" is per-chip HBM; the what-if "query optimizer"
is the roofline step-cost model; SELECT- vs INSERT-intensity is the
read/write ratio of each class per step.

The search is the paper's: per-class candidates -> (bytes, cost) skyline
(§6.1) -> greedy enumeration with oversized-choice backtracking (§6.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from ..models.config import ModelConfig
from .codecs import CODECS, Codec


@dataclasses.dataclass(frozen=True)
class TensorClass:
    """One persistent tensor class of a job (an 'index' of the paper)."""
    name: str
    n_elements: float
    reads_per_step: float    # element-reads per step (beta charge)
    writes_per_step: float   # element-writes per step (alpha charge)
    allowed: Tuple[str, ...]  # codec candidates
    quality_floor: str = ""  # codec names below this are disallowed


@dataclasses.dataclass(frozen=True)
class Choice:
    tclass: str
    codec: str
    hbm_bytes: float
    step_cost_s: float


@dataclasses.dataclass
class LayoutPlan:
    choices: Dict[str, str]          # class -> codec
    hbm_bytes: float
    step_cost_s: float
    log: List[str]


def job_tensor_classes(cfg: ModelConfig, kind: str, batch: int, seq: int,
                       n_chips: int) -> List[TensorClass]:
    """Tensor classes for a (train|serve) job, per chip."""
    n = cfg.param_count(padded=True) / n_chips
    out = [TensorClass("weights", n,
                       reads_per_step=(3.0 if kind == "train" else 1.0) * n,
                       writes_per_step=(n if kind == "train" else 0.0),
                       allowed=("f32", "bf16", "q8") if kind != "train"
                       else ("f32", "bf16"))]
    if kind == "train":
        out.append(TensorClass("adam_m", n, reads_per_step=n,
                               writes_per_step=n, allowed=("f32", "q8")))
        out.append(TensorClass("adam_v", n, reads_per_step=n,
                               writes_per_step=n, allowed=("f32", "q8")))
        out.append(TensorClass("grad_wire", n, reads_per_step=n,
                               writes_per_step=n, allowed=("f32", "bf16",
                                                           "q8")))
    else:
        kv_heads = cfg.kv_heads if (cfg.mixer == "attn" or cfg.hybrid) else 0
        if kv_heads:
            n_attn = cfg.attn_layers
            kv = 2.0 * n_attn * batch * seq * kv_heads * cfg.d_head / n_chips
            out.append(TensorClass("kv_cache", kv, reads_per_step=kv,
                                   writes_per_step=kv / max(seq, 1),
                                   allowed=("f32", "bf16", "q8", "q4")))
    return out


def step_cost(classes: Sequence[TensorClass], choices: Dict[str, str],
              base_flops_per_chip: float) -> Tuple[float, float]:
    """(hbm_bytes, step_seconds) under the compression-aware cost model.

    Appendix A verbatim: CPU_update = base + alpha*writes;
    CPU_read = base + beta*reads; I/O shrinks with compressed size.  Here
    'CPU' is VPU time (elements/s ~ PEAK/8 in relative units), 'I/O' is
    HBM traffic; grad_wire bytes ride the ICI, not HBM.
    """
    vpu_el_per_s = PEAK_FLOPS / 16.0  # rough VPU elementwise throughput
    t_compute = base_flops_per_chip / PEAK_FLOPS
    t_hbm = 0.0
    t_wire = 0.0
    t_vpu = 0.0
    hbm = 0.0
    for c in classes:
        codec = CODECS[choices[c.name]]
        bpe = codec.bytes_per_element
        assert bpe is not None
        size = c.n_elements * bpe
        traffic = (c.reads_per_step + c.writes_per_step) * bpe
        if c.name == "grad_wire":
            t_wire += traffic / LINK_BW   # wire bytes, not HBM residency
        else:
            hbm += size
            t_hbm += traffic / HBM_BW
        t_vpu += codec.beta * c.reads_per_step / vpu_el_per_s
        t_vpu += codec.alpha * c.writes_per_step / vpu_el_per_s
    # Roofline overlap: compute and HBM streams overlap (max), codec VPU
    # work and wire transfers serialize on top.  When a job is
    # compute-bound, compressing a tensor saves NO step time but still pays
    # alpha/beta — the advisor then correctly declines to compress unless
    # the HBM budget forces it (the paper's Example 2, TPU edition).
    t = max(t_compute, t_hbm) + t_wire + t_vpu
    return hbm, t


def skyline(candidates: Sequence[Choice]) -> List[Choice]:
    """(bytes, cost) Pareto frontier per class (paper §6.1)."""
    out = []
    for c in candidates:
        if not any(o.hbm_bytes <= c.hbm_bytes and o.step_cost_s <= c.step_cost_s
                   and (o.hbm_bytes < c.hbm_bytes
                        or o.step_cost_s < c.step_cost_s)
                   for o in candidates if o is not c):
            out.append(c)
    return sorted(out, key=lambda c: -c.hbm_bytes)


def plan_layout(cfg: ModelConfig, kind: str, batch: int, seq: int,
                n_chips: int, hbm_budget_bytes: float,
                base_flops_per_chip: float = 0.0) -> LayoutPlan:
    """Greedy-with-backtracking enumeration (paper §6.2) over codec choices.

    Start from the FASTEST (largest) codec per class; while over budget,
    greedily apply the compression step with the best bytes-saved per
    cost-added (density); backtrack: if a class hits its smallest codec and
    the budget still fails, recover by re-expanding the cheapest class and
    compressing a different one (Figure 8's replace-member recovery).
    """
    classes = job_tensor_classes(cfg, kind, batch, seq, n_chips)
    log: List[str] = []

    # per-class skyline of (bytes, cost) single-choice configurations
    per_class: Dict[str, List[Choice]] = {}
    for c in classes:
        cands = []
        for codec in c.allowed:
            trial = {cc.name: (codec if cc.name == c.name else cc.allowed[0])
                     for cc in classes}
            b, t = step_cost(classes, trial, base_flops_per_chip)
            cands.append(Choice(c.name, codec, b, t))
        per_class[c.name] = skyline(cands)
        log.append(f"skyline[{c.name}]: "
                   + ", ".join(f"{x.codec}({x.hbm_bytes/1e9:.2f}GB,"
                               f"{x.step_cost_s*1e3:.2f}ms)"
                               for x in per_class[c.name]))

    # greedy: start fastest, compress by best density until within budget
    choices = {c.name: min(per_class[c.name],
                           key=lambda x: x.step_cost_s).codec
               for c in classes}
    for _ in range(32):
        hbm, t = step_cost(classes, choices, base_flops_per_chip)
        if hbm <= hbm_budget_bytes:
            break
        best = None
        for c in classes:
            cur = CODECS[choices[c.name]]
            for ch in per_class[c.name]:
                codec = CODECS[ch.codec]
                if codec.bytes_per_element >= cur.bytes_per_element:
                    continue
                trial = dict(choices)
                trial[c.name] = ch.codec
                b2, t2 = step_cost(classes, trial, base_flops_per_chip)
                saved = hbm - b2
                dcost = max(t2 - t, 1e-12)
                score = saved / dcost
                if best is None or score > best[0]:
                    best = (score, c.name, ch.codec)
        if best is None:
            log.append("backtrack: no further compression available; "
                       "budget infeasible")
            break
        choices[best[1]] = best[2]
        log.append(f"compress {best[1]} -> {best[2]}")

    hbm, t = step_cost(classes, choices, base_flops_per_chip)
    # Figure-8 style recovery: try relaxing one class back up if a cheaper
    # combination fits (greedy overshoot repair)
    improved = True
    while improved:
        improved = False
        for c in classes:
            for ch in per_class[c.name]:
                if ch.codec == choices[c.name]:
                    continue
                trial = dict(choices)
                trial[c.name] = ch.codec
                b2, t2 = step_cost(classes, trial, base_flops_per_chip)
                if b2 <= hbm_budget_bytes and t2 < t:
                    choices, hbm, t = trial, b2, t2
                    log.append(f"backtrack-recover: {c.name} -> {ch.codec}")
                    improved = True
    return LayoutPlan(choices=choices, hbm_bytes=hbm, step_cost_s=t, log=log)

# TPU adaptation of the paper (DESIGN.md §3): compression-aware physical
# design of a training/serving job's persistent tensors under an HBM budget.
from .advisor import (Choice, LayoutPlan, TensorClass, job_tensor_classes,
                      plan_layout, skyline, step_cost)
from .codecs import CODECS, Codec, decode, encode, sample_cf_bytes

__all__ = ["Choice", "LayoutPlan", "TensorClass", "job_tensor_classes",
           "plan_layout", "skyline", "step_cost", "CODECS", "Codec",
           "decode", "encode", "sample_cf_bytes"]

"""Tensor codecs — the TPU renderings of the paper's compression methods.

Data-INDEPENDENT-size codecs (quantization: the paper's ORD-IND analogue —
size known without sampling) and data-DEPENDENT-size codecs (zstd, sparse:
the ORD-DEP analogue — size estimated by SampleCF on real tensor rows).

Each codec reports:
  bytes_per_element  (None => data-dependent, needs SampleCF)
  alpha — relative compress cost per element  (paper App. A, update path)
  beta  — relative decompress cost per element (read path)
and implements encode/decode for the checkpoint path (host-side) or defers
to kernels/ops for the on-device path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import zstandard

from ..kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    bytes_per_element: Optional[float]  # None => data-dependent (SampleCF)
    alpha: float   # compress cost / element (relative units)
    beta: float    # decompress cost / element
    lossless: bool


CODECS: Dict[str, Codec] = {
    "f32":  Codec("f32", 4.0, 0.0, 0.0, True),
    "bf16": Codec("bf16", 2.0, 0.05, 0.05, False),
    "q8":   Codec("q8", 1.0 + 4.0 / kref.DEFAULT_BLOCK, 1.0, 0.5, False),
    "q4":   Codec("q4", 0.5 + 4.0 / kref.DEFAULT_BLOCK, 1.2, 0.7, False),
    # host-side lossless (checkpoints): size depends on the data => SampleCF
    "zstd":    Codec("zstd", None, 3.0, 1.5, True),
    "q8+zstd": Codec("q8+zstd", None, 4.0, 2.0, False),
}


def encode(name: str, arr: np.ndarray) -> Tuple[bytes, dict]:
    """Host-side encode for checkpoints. Returns (payload, meta)."""
    meta = {"codec": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    if name == "f32":
        return np.asarray(arr, np.float32).tobytes(), meta
    if name == "bf16":
        import jax.numpy as jnp
        return np.asarray(jnp.asarray(arr).astype(jnp.bfloat16).view(np.uint16)
                          ).tobytes(), meta
    if name == "zstd":
        return zstandard.compress(np.ascontiguousarray(arr).tobytes(), 3), meta
    if name in ("q8", "q8+zstd"):
        import jax.numpy as jnp
        q, s = kref.quantize_blockwise(jnp.asarray(arr, jnp.float32))
        payload = np.asarray(q).tobytes() + np.asarray(s).tobytes()
        meta["scale_shape"] = list(np.asarray(s).shape)
        if name == "q8+zstd":
            payload = zstandard.compress(payload, 3)
        return payload, meta
    raise KeyError(name)


def decode(payload: bytes, meta: dict) -> np.ndarray:
    import jax.numpy as jnp
    name = meta["codec"]
    shape = tuple(meta["shape"])
    if name == "f32":
        return np.frombuffer(payload, np.float32).reshape(shape).copy()
    if name == "bf16":
        u16 = np.frombuffer(payload, np.uint16).reshape(shape)
        return np.asarray(jnp.asarray(u16).view(jnp.bfloat16).astype(
            jnp.float32))
    if name == "zstd":
        raw = zstandard.decompress(payload)
        return np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(shape).copy()
    if name in ("q8", "q8+zstd"):
        if name == "q8+zstd":
            payload = zstandard.decompress(payload)
        n_q = int(np.prod(shape))
        sshape = tuple(meta["scale_shape"])
        q = np.frombuffer(payload[:n_q], np.int8).reshape(shape)
        s = np.frombuffer(payload[n_q:n_q + 4 * int(np.prod(sshape))],
                          np.float32).reshape(sshape)
        return np.asarray(kref.dequantize_blockwise(jnp.asarray(q),
                                                    jnp.asarray(s)))
    raise KeyError(name)


def sample_cf_bytes(name: str, arr: np.ndarray, fraction: float = 0.05,
                    seed: int = 0) -> float:
    """SampleCF for data-dependent codecs (paper §2.2, verbatim): encode a
    row sample, return estimated full compressed bytes."""
    codec = CODECS[name]
    flat = arr.reshape(-1, arr.shape[-1]) if arr.ndim > 1 else arr[None]
    n = flat.shape[0]
    rng = np.random.default_rng(seed)
    take = max(1, int(n * fraction))
    rows = rng.choice(n, size=take, replace=False)
    payload, _ = encode(name, flat[np.sort(rows)])
    sample_raw = flat[rows].nbytes
    cf = len(payload) / max(sample_raw, 1)
    return cf * arr.nbytes

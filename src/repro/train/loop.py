"""Fault-tolerant training loop.

* checkpoint/restart: atomic compressed checkpoints (repro.checkpoint),
  auto-resume from the latest on construction;
* straggler mitigation: per-step wall-time EMA; a step slower than
  `straggler_factor` x EMA is logged and counted — the hook where a real
  multi-host deployment would trigger re-sharding away from the slow host
  (we expose `on_straggler` for tests / integrations);
* elastic scaling: `reshard(new_mesh)` rebuilds shardings for a different
  device count and device_put's the state across (works because the data
  pipeline is stateless-in-step and batch specs are derived per mesh);
* gradient compression + compressed optimizer moments come from the
  design advisor's LayoutPlan (the paper's technique driving the trainer).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointConfig, CheckpointManager
from ..data.pipeline import DataConfig, batch_at
from ..design import plan_layout
from ..models import model as MD
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update
from .step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 3e-4
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last_k: int = 2
    straggler_factor: float = 3.0
    hbm_budget_bytes: float = 16e9
    use_design_advisor: bool = True
    grad_accum: int = 1
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.tc = tc
        self.on_straggler = on_straggler
        self.straggler_events: List[int] = []
        self.history: List[Dict[str, float]] = []

        # --- the paper's advisor chooses the physical layout ---
        n_chips = jax.device_count()
        flops = 6.0 * cfg.param_count() * tc.batch * tc.seq / n_chips
        if tc.use_design_advisor:
            self.plan = plan_layout(cfg, "train", tc.batch, tc.seq, n_chips,
                                    tc.hbm_budget_bytes,
                                    base_flops_per_chip=flops)
            moments = ("q8" if self.plan.choices.get("adam_m") == "q8"
                       else "f32")
            grad_comp = ("q8" if self.plan.choices.get("grad_wire") == "q8"
                         else None)
        else:
            self.plan = None
            moments, grad_comp = "f32", None

        self.opt_cfg = AdamWConfig(lr=tc.lr, state_codec=moments)
        self.data_cfg = DataConfig(
            vocab=cfg.vocab, batch=tc.batch, seq=tc.seq, seed=tc.seed,
            d_model=cfg.d_model if cfg.frontend != "tokens" else 0)
        self._step_fn = jax.jit(make_train_step(
            self.cfg, self.opt_cfg, remat=True, grad_compression=grad_comp,
            attn_impl="chunked" if tc.seq >= 2048 else "full"))

        self.params = MD.init_params(jax.random.PRNGKey(tc.seed), cfg,
                                     jnp.float32)
        self.opt_state = adamw_init(self.params, self.opt_cfg)
        self.step = 0

        self.ckpt: Optional[CheckpointManager] = None
        if tc.checkpoint_dir:
            self.ckpt = CheckpointManager(CheckpointConfig(
                directory=tc.checkpoint_dir, keep_last_k=tc.keep_last_k))
            if self.ckpt.latest_step() is not None:
                self.restore()

    # ------------------------------------------------------------------
    def restore(self) -> None:
        assert self.ckpt is not None
        step, params, opt, extra = self.ckpt.restore_into(
            self.params, self.opt_state)
        self.params = jax.tree.map(jnp.asarray, params)
        self.opt_state = jax.tree.map(jnp.asarray, opt)
        self.step = step
        print(f"[trainer] resumed from step {step}")

    def reshard(self, mesh, param_specs_tree) -> None:
        """Elastic scaling: move state onto a new mesh's shardings."""
        from jax.sharding import NamedSharding
        put = lambda t, sp: jax.device_put(t, NamedSharding(mesh, sp))
        self.params = jax.tree.map(put, self.params, param_specs_tree)

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps if steps is not None else self.tc.steps
        ema = None
        target = self.step + steps
        first = True
        while self.step < target:
            batch = batch_at(self.data_cfg, self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, loss = self._step_fn(
                self.params, self.opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            if first:
                first = False  # compile step: excluded from the EMA
            elif ema is None:
                ema = dt
            else:
                if dt > self.tc.straggler_factor * ema:
                    self.straggler_events.append(self.step)
                    if self.on_straggler:
                        self.on_straggler(self.step, dt / ema)
                ema = 0.9 * ema + 0.1 * dt
            self.history.append({"step": self.step, "loss": loss,
                                 "seconds": dt})
            if self.step % self.tc.log_every == 0:
                print(f"[trainer] step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            self.step += 1
            if (self.ckpt is not None and
                    self.step % self.tc.checkpoint_every == 0):
                self.ckpt.save(self.step, self.params, self.opt_state,
                               extra={"loss": loss})
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.params, self.opt_state,
                           extra={"loss": self.history[-1]["loss"]})
            self.ckpt.wait()
        return {"final_loss": self.history[-1]["loss"],
                "first_loss": self.history[0]["loss"],
                "stragglers": list(self.straggler_events)}

"""Step-function builders shared by the trainer, server, and dry-run.

make_train_step: loss -> grad -> (optionally compressed) gradient reduction
-> AdamW (optionally compressed moments).  Activation checkpointing wraps
every scanned layer when remat=True (the default training policy).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import model as MD
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..kernels import ref as KREF


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    remat: bool = True,
                    grad_compression: Optional[str] = None,
                    compute_dtype=jnp.bfloat16,
                    attn_impl: str = "chunked",
                    act_specs=None) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, loss).

    Mixed precision: params are the f32 master copy; a bf16 cast feeds the
    forward/backward (grads flow through the cast back to f32).  Training
    uses CHUNKED (online-softmax, rematerialized) attention so S^2 score
    tensors never materialize.

    grad_compression="q8" quantizes gradients blockwise to int8 before they
    cross the network (simulated wire format: q8 values + f32 block scales;
    the dequantized gradient feeds AdamW).  This is the paper's
    update-path compression trade-off (alpha cost vs I/O saving) applied to
    the gradient all-reduce.
    """

    def loss_fn(params, batch):
        if compute_dtype is not None:
            params_c = jax.tree.map(
                lambda p: p.astype(compute_dtype)
                if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
        else:
            params_c = params
        return MD.loss_fn(params_c, cfg, batch["tokens"], batch["labels"],
                          embeds=batch.get("embeds"), remat=remat,
                          attn_impl=attn_impl, act_specs=act_specs)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if grad_compression == "q8":
            def qdq(g):
                if g.ndim == 0 or g.shape[-1] < 8:
                    return g
                q, s = KREF.quantize_blockwise(g)
                return KREF.dequantize_blockwise(q, s, dtype=g.dtype)
            grads = jax.tree.map(qdq, grads)
        params, opt_state = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, loss

    return step


def make_prefill_step(cfg: ModelConfig, act_specs=None) -> Callable:
    """Returns prefill(params, batch) -> logits, with chunked (online-
    softmax) attention so 32k+ sequences never materialize S^2 scores."""

    def prefill(params, batch):
        return MD.forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), attn_impl="chunked",
                          act_specs=act_specs)

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """Returns decode(params, state, tokens) -> (logits, new_state)."""

    def decode(params, state, tokens):
        return MD.decode_step(params, state, cfg, tokens)

    return decode

"""Fleet scaling: a 100+ tenant drift storm through the advisor service.

Registers `--tenants` tenants spread over `--schema-groups` distinct
schemas (tenants within a group share one SampleManager and one
(NodeKey, f) SampleCF cache via `samplecf.schema_fingerprint` grouping),
then drives `--rounds` drift rounds.  Each round submits, for EVERY
tenant, one workload delta (churn + reweight) followed by one recommend,
and drains the fleet — so deltas and recommends of all tenants
interleave through the shared slots and the cross-tenant batched
SampleCF prefetch.

Gates:

* **Parity (hard assert):** every round, every tenant's recommendation
  is exactly `==` — config, cost, used_bytes — a fresh `DesignAdvisor`
  built on that tenant's current workload.  The report only exists if
  all tenants * rounds comparisons held.
* **Sharing:** the shared fleet must draw fewer samples than tenants *
  per-tenant sampling (evidenced by `sampling_calls` vs group count and
  by per-tenant SampleCF misses being (near-)zero after the prefetch).

Reported in BENCH_fleet.json: sustained recommends/sec (fleet wall time
over all rounds, excluding the fresh-advisor parity checks), p50/p99
submit->resolve latency per request kind, and the fleet's amortization
counters.

Usage:
    PYTHONPATH=src python benchmarks/fleet_scaling.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (AdvisorOptions, DesignAdvisor, WorkloadDelta,
                        base_configuration, make_scaled_workload,
                        make_tpch_like)
from repro.serve.advisor_service import AdvisorFleetService, FleetConfig


def identical(a, b) -> bool:
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


def pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), q))


def make_tenant_workload(schema, tid: str, n: int, seed: int):
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def make_delta(rng, tid: str, rnd: int, wl, schema, n_move: int,
               n_reweight: int) -> WorkloadDelta:
    names = [s.name for s in wl.statements]
    removed = tuple(rng.choice(names, size=min(n_move, len(names) - 1),
                               replace=False))
    pool = make_scaled_workload(
        schema, n_statements=len(removed),
        seed=100_000 + rnd * 1000 + int(tid[1:])).statements
    added = tuple(dataclasses.replace(s, name=f"{tid}_r{rnd}_{j}")
                  for j, s in enumerate(pool))
    survivors = [n for n in names if n not in set(removed)]
    rw = tuple((n, float(rng.uniform(0.5, 2.0)))
               for n in rng.choice(survivors,
                                   size=min(n_reweight, len(survivors)),
                                   replace=False))
    return WorkloadDelta(added=added, removed=removed, reweighted=rw)


def run(tenants: int, schema_groups: int, statements: int, scale: float,
        rounds: int, slots: int, n_move: int, n_reweight: int, seed: int,
        budget_frac: float, out_path: Path,
        backend: str = "numpy") -> dict:
    schemas = [make_tpch_like(scale=scale, z=0, seed=seed + g)
               for g in range(schema_groups)]
    opt = dataclasses.replace(AdvisorOptions.dtac(), backend=backend)
    fleet = AdvisorFleetService(FleetConfig(slots=slots))

    wls = {}
    tenant_schema = {}
    budgets = {}
    for i in range(tenants):
        tid = f"t{i}"
        schema = schemas[i % schema_groups]
        wl = make_tenant_workload(schema, tid, statements, seed + 31 + i)
        wls[tid] = wl
        tenant_schema[tid] = schema
        adv = DesignAdvisor(wl, opt)
        budgets[tid] = budget_frac * sum(
            adv.sizes.size(i_)
            for i_ in base_configuration(schema).indexes)
        fleet.register_tenant(tid, wl, opt)
    assert fleet.stats["groups"] == schema_groups, \
        "fingerprint grouping did not collapse same-schema tenants"

    rng = np.random.default_rng(seed + 7)
    fleet_seconds = 0.0
    rec_latencies, delta_latencies = [], []
    round_rows = []
    parity_checks = 0
    for rnd in range(rounds):
        tickets = {}
        t0 = time.perf_counter()
        for tid in wls:
            delta = make_delta(rng, tid, rnd, wls[tid],
                               tenant_schema[tid], n_move, n_reweight)
            fleet.submit_delta(tid, delta)
            wls[tid] = wls[tid].apply_delta(delta)
            tickets[tid] = fleet.submit_recommend(tid, budgets[tid])
        fleet.run_until_drained()
        dt = time.perf_counter() - t0
        fleet_seconds += dt

        # parity: EVERY tenant vs a fresh advisor, EVERY round
        t1 = time.perf_counter()
        for tid, tk in tickets.items():
            fresh = DesignAdvisor(wls[tid], opt).recommend(budgets[tid])
            assert identical(tk.result(), fresh), \
                f"parity broke at round {rnd}, tenant {tid}"
            parity_checks += 1
            rec_latencies.append(tk.latency)
        fresh_seconds = time.perf_counter() - t1
        round_rows.append({
            "round": rnd,
            "fleet_seconds": round(dt, 4),
            "recommends_per_sec": round(tenants / dt, 2),
            "fresh_rebuild_seconds": round(fresh_seconds, 4),
        })

    total_recs = tenants * rounds
    s = fleet.stats
    misses = sum(fleet.tenant_stats(t)["samplecf_cache_misses"]
                 for t in wls)
    report = {
        "backend": backend,
        "tenants": tenants,
        "schema_groups": schema_groups,
        "statements_per_tenant": statements,
        "schema_scale": scale,
        "rounds": rounds,
        "slots": slots,
        "moves_per_round": n_move,
        "reweights_per_round": n_reweight,
        "total_recommends": total_recs,
        "fleet_seconds": round(fleet_seconds, 4),
        "sustained_recommends_per_sec": round(total_recs / fleet_seconds,
                                              2),
        "latency_seconds": {
            "recommend_p50": round(pct(rec_latencies, 50), 4),
            "recommend_p99": round(pct(rec_latencies, 99), 4),
            "recommend_max": round(max(rec_latencies), 4),
        },
        "per_round": round_rows,
        # guarded by the identical() asserts above
        "parity": {"checks": parity_checks, "bit_exact": True},
        "amortization": {
            "fleet_stats": s,
            "tenant_samplecf_misses_total": misses,
        },
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    ok = parity_checks == total_recs and s["groups"] == schema_groups
    if ok:
        print(f"OK: {parity_checks} exact parity checks over {rounds} "
              f"rounds x {tenants} tenants; "
              f"{report['sustained_recommends_per_sec']}/s sustained, "
              f"p99 {report['latency_seconds']['recommend_p99']}s")
    else:
        print("FAIL: parity/sharing gate", file=sys.stderr)
    return report | {"ok": ok}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=100)
    ap.add_argument("--schema-groups", type=int, default=4)
    ap.add_argument("--statements", type=int, default=12)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--moves", type=int, default=2)
    ap.add_argument("--reweights", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-frac", type=float, default=0.25)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="unified advisor backend for every tenant; "
                    "per-tenant parity is asserted every round either way")
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_fleet.json at "
                    "the repo root; smoke runs write "
                    "BENCH_fleet.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (parity still asserted "
                    "for every tenant every round)")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.tenants = 10
        args.schema_groups = 2
        args.statements = 10
        args.rounds = 2
        args.slots = 4
    if args.out is None:
        args.out = root / ("BENCH_fleet.smoke.json" if args.smoke
                           else "BENCH_fleet.json")
    report = run(args.tenants, args.schema_groups, args.statements,
                 args.scale, args.rounds, args.slots, args.moves,
                 args.reweights, args.seed, args.budget_frac, args.out,
                 args.backend)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Fault recovery: a seeded fault storm + bounded-memory drift through
the advisor fleet, with exact per-tenant parity as the hard gate.

Phase 1 — **fault storm**.  Registers `--tenants` tenants on one schema
and drives `--rounds` drift rounds under a seeded `FaultInjector`
(transient apply/estimation/costing faults plus lost prefetch batches),
a scripted `crash_tenant` every round (checkpoint-restore readmission),
and per-recommend deadlines with a degraded-budget fallback.  Every
round, every tenant submits one delta and one recommend; a mirror
workload per tenant is advanced ONLY when the delta ticket succeeded.
Each resolved recommendation is then checked bit-exactly:

* normal result    -> `==` fresh `DesignAdvisor` on the mirror workload
* degraded result  -> `==` fresh advisor at the degraded compression
  budget on the same mirror (certificate attached)
* `TicketTimeout` / `TenantQuarantined` -> counted, never silently lost

The report only exists if every comparison held — the exact-parity
contract extended over retries, quarantines, crash/restore cycles and
deadline degradation.  Restore wall-times feed the recovery-latency
percentiles.

Phase 2 — **bounded drift**.  One tenant drifts for `--bounded-rounds`
rounds under absurdly tight memory bounds (shared SampleCF LRU via
`FleetConfig.cache_entries`, planner node-universe and replay-store
bounds via `AdvisorOptions`).  Per-round resident sizes are recorded and
gated: the cache never exceeds its bound, evictions actually fired at
every layer, and parity holds every round — evidence that eviction only
discards recomputable state.

Phase 3 — **durable recovery** (PR 10).  Drives a delta storm through a
fleet backed by `DurableStore` (write-ahead log + atomic snapshots)
under mild injected disk faults (torn writes, fsync failures), closes
the store — real process death, nothing survives in memory — and times
`AdvisorFleetService.recover(dir)` over fresh copies of the directory.
Two store configurations contrast the latency/compaction trade:
journal-only (`compact_after=None`, the longest possible replay) vs
aggressive compaction (short WAL suffix).  The gate: every recovered
tenant's post-restart recommendation is exactly `==` a fresh
`DesignAdvisor` on its mirror workload, a scripted torn tail is
truncated (not fatal), and the recovery-latency percentiles vs log
length / snapshot interval land in the report.

Usage:
    PYTHONPATH=src python benchmarks/fault_recovery.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import (AdvisorOptions, DesignAdvisor, DurableStore,
                        FaultInjector, WorkloadDelta, base_configuration,
                        make_scaled_workload, make_tpch_like)
from repro.serve.advisor_service import (AdvisorFleetService, FleetConfig,
                                         TenantQuarantined, TicketTimeout)


def identical(a, b) -> bool:
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


def pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), q))


def make_tenant_workload(schema, tid: str, n: int, seed: int):
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def make_delta(rng, tid: str, rnd: int, wl, schema) -> WorkloadDelta:
    names = [s.name for s in wl.statements]
    removed = tuple(rng.choice(names, size=min(1, len(names) - 1),
                               replace=False))
    pool = make_scaled_workload(
        schema, n_statements=2,
        seed=100_000 + rnd * 1000 + int(tid[1:])).statements
    added = tuple(dataclasses.replace(s, name=f"{tid}_r{rnd}_{j}")
                  for j, s in enumerate(pool))
    return WorkloadDelta(added=added, removed=removed)


# ---------------------------------------------------------------------------
# Phase 1: seeded fault storm
# ---------------------------------------------------------------------------

def run_storm(tenants: int, rounds: int, slots: int, statements: int,
              scale: float, seed: int, budget_frac: float,
              deadline: int, degraded_budget: int,
              backend: str = "numpy") -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    opt = dataclasses.replace(AdvisorOptions.dtac(),
                              backend=backend)
    faults = FaultInjector(seed=seed + 1, specs={
        "apply_delta": 0.08, "estimation": 0.05, "costing": 0.05,
        "prefetch": 0.25, "planner_replay": 0.05})
    fc = FleetConfig(slots=slots, retry_backoff=(1, 2, 4),
                     quarantine_after=3, degraded_budget=degraded_budget)
    fleet = AdvisorFleetService(fc, faults=faults)

    mirrors, budgets = {}, {}
    for i in range(tenants):
        tid = f"t{i}"
        wl = make_tenant_workload(schema, tid, statements, seed + 31 + i)
        mirrors[tid] = wl
        adv = DesignAdvisor(wl, opt)
        budgets[tid] = budget_frac * sum(
            adv.sizes.size(ix)
            for ix in base_configuration(schema).indexes)
        fleet.register_tenant(tid, wl, opt)

    dopt = dataclasses.replace(opt, compression_budget=degraded_budget)
    rng = np.random.default_rng(seed + 7)
    counts = {"exact": 0, "degraded_exact": 0, "timeout": 0,
              "quarantined": 0, "delta_ok": 0, "delta_failed": 0,
              "crashes": 0}
    parity_failures = 0
    fleet_seconds = 0.0
    round_rows = []
    exact_by_tenant = {tid: 0 for tid in mirrors}
    for rnd in range(rounds):
        # scripted process loss: one victim per round, restored from its
        # checkpoint before the round's traffic (recovery latency is
        # recorded by the service); fault-storm quarantines from the
        # previous round are readmitted here too
        victim = f"t{int(rng.integers(tenants))}"
        if fleet.tenants[victim].quarantined_at is None:
            fleet.crash_tenant(victim)
            counts["crashes"] += 1
        for tid, t in fleet.tenants.items():
            if t.quarantined_at is not None:
                fleet.readmit_tenant(tid)

        t0 = time.perf_counter()
        dks, rks, deltas = {}, {}, {}
        for tid in mirrors:
            deltas[tid] = make_delta(rng, tid, rnd, mirrors[tid], schema)
            dks[tid] = fleet.submit_delta(tid, deltas[tid])
            rks[tid] = fleet.submit_recommend(
                tid, budgets[tid], deadline_steps=deadline)
        fleet.run_until_drained()
        fleet_seconds += time.perf_counter() - t0

        for tid in mirrors:
            # the mirror advances ONLY on a successful delta, so every
            # parity check below compares against the state the tenant's
            # session actually reached
            derr = dks[tid].exception(timeout=1.0)
            if derr is None:
                mirrors[tid] = mirrors[tid].apply_delta(deltas[tid])
                counts["delta_ok"] += 1
            else:
                counts["delta_failed"] += 1
            rerr = rks[tid].exception(timeout=1.0)
            if isinstance(rerr, TicketTimeout):
                counts["timeout"] += 1
                continue
            if isinstance(rerr, TenantQuarantined):
                counts["quarantined"] += 1
                continue
            if rerr is not None:
                parity_failures += 1
                print(f"FAIL: unexpected recommend error r{rnd} {tid}: "
                      f"{rerr!r}", file=sys.stderr)
                continue
            rec = rks[tid].result()
            ref_opt = dopt if rks[tid].degraded else opt
            fresh = DesignAdvisor(mirrors[tid], ref_opt).recommend(
                budgets[tid])
            if identical(rec, fresh):
                key = "degraded_exact" if rks[tid].degraded else "exact"
                counts[key] += 1
                exact_by_tenant[tid] += 1
            else:
                parity_failures += 1
                print(f"FAIL: parity broke at round {rnd}, tenant {tid} "
                      f"(degraded={rks[tid].degraded})", file=sys.stderr)
        s = fleet.stats
        round_rows.append({"round": rnd, "retries": s["retries"],
                           "timeouts": s["timeouts"],
                           "quarantines": s["quarantines"],
                           "restores": s["restores"],
                           "degraded": s["degraded_recommends"]})

    s = fleet.stats
    lat = fleet.restore_seconds
    return {
        "tenants": tenants, "rounds": rounds, "slots": slots,
        "deadline_steps": deadline, "degraded_budget": degraded_budget,
        "fleet_seconds": round(fleet_seconds, 4),
        "outcomes": counts,
        "parity_failures": parity_failures,
        "tenants_with_exact_result": sum(
            1 for v in exact_by_tenant.values() if v > 0),
        "fault_injector": faults.stats(),
        "fleet_stats": s,
        "per_round": round_rows,
        "recovery_latency_seconds": {
            "restores": len(lat),
            "p50": round(pct(lat, 50), 5) if lat else None,
            "p99": round(pct(lat, 99), 5) if lat else None,
            "max": round(max(lat), 5) if lat else None,
        },
    }


# ---------------------------------------------------------------------------
# Phase 2: bounded-memory drift
# ---------------------------------------------------------------------------

def run_bounded(rounds: int, statements: int, scale: float, seed: int,
                budget_frac: float, cache_entries: int, max_nodes: int,
                max_replay: int, backend: str = "numpy") -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    opt = dataclasses.replace(AdvisorOptions.dtac(),
                              max_planner_nodes=max_nodes,
                              max_replay_entries=max_replay,
                              backend=backend)
    fleet = AdvisorFleetService(
        FleetConfig(slots=1, cache_entries=cache_entries))
    tid = "t0"
    wl = make_tenant_workload(schema, tid, statements, seed + 31)
    adv = DesignAdvisor(wl, opt)
    budget = budget_frac * sum(adv.sizes.size(ix)
                               for ix in base_configuration(schema).indexes)
    fleet.register_tenant(tid, wl, opt)

    rng = np.random.default_rng(seed + 9)
    series = []
    parity_failures = 0
    for rnd in range(rounds):
        delta = make_delta(rng, tid, rnd, wl, schema)
        fleet.submit_delta(tid, delta)
        wl = wl.apply_delta(delta)
        tk = fleet.submit_recommend(tid, budget)
        fleet.run_until_drained()
        if not identical(tk.result(),
                         DesignAdvisor(wl, opt).recommend(budget)):
            parity_failures += 1
            print(f"FAIL: bounded parity broke at round {rnd}",
                  file=sys.stderr)
        ts = fleet.tenant_stats(tid)
        series.append({
            "round": rnd,
            "shared_cache_entries": fleet.stats["shared_cache_entries"],
            "universe_nodes": ts["universe_nodes"],
            "universe_peak_nodes": ts["universe_peak_nodes"],
            "replay_entries": ts["replay_entries"],
        })
    ts = fleet.tenant_stats(tid)
    return {
        "rounds": rounds,
        "bounds": {"cache_entries": cache_entries,
                   "max_planner_nodes": max_nodes,
                   "max_replay_entries": max_replay},
        "parity_failures": parity_failures,
        "evictions": {
            "shared_cache": fleet.stats["shared_cache_evictions"],
            "universe": ts["universe_evictions"],
            "replay": ts["replay_evictions"],
        },
        "peak_shared_cache_entries": max(r["shared_cache_entries"]
                                         for r in series),
        "per_round": series,
    }


# ---------------------------------------------------------------------------
# Phase 3: durable recovery after real process death
# ---------------------------------------------------------------------------

def run_recovery(tenants: int, rounds: int, slots: int, statements: int,
                 scale: float, seed: int, budget_frac: float,
                 repeats: int, backend: str = "numpy") -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    opt = dataclasses.replace(AdvisorOptions.dtac(), backend=backend)
    fc = FleetConfig(slots=slots, retry_backoff=(1, 2, 4, 8),
                     quarantine_after=None, backend=backend)
    configs = [
        {"name": "journal_only", "compact_after": None, "group_commit": 2},
        {"name": "compact_4", "compact_after": 4, "group_commit": 1},
    ]
    rng = np.random.default_rng(seed + 11)
    out_cfgs = []
    parity_failures = 0
    torn_tails_truncated = 0
    with tempfile.TemporaryDirectory(prefix="bench_durability_") as tmp:
        tmp = Path(tmp)
        for cfg in configs:
            base = tmp / f"{cfg['name']}_base"
            # mild disk faults while the storm writes: torn appends and
            # failed group commits, both retried by the fleet
            faults = FaultInjector(seed=seed + 13, specs={
                "disk_write": 0.05, "fsync": 0.05})
            store = DurableStore(base, group_commit=cfg["group_commit"],
                                 compact_after=cfg["compact_after"],
                                 faults=faults)
            fleet = AdvisorFleetService(fc, faults=faults, store=store)
            mirrors, budgets = {}, {}
            for i in range(tenants):
                tid = f"t{i}"
                wl = make_tenant_workload(schema, tid, statements,
                                          seed + 31 + i)
                mirrors[tid] = wl
                adv = DesignAdvisor(wl, opt)
                budgets[tid] = budget_frac * sum(
                    adv.sizes.size(ix)
                    for ix in base_configuration(schema).indexes)
                fleet.register_tenant(tid, wl, opt)
            for rnd in range(rounds):
                dks, deltas = {}, {}
                for tid in mirrors:
                    deltas[tid] = make_delta(rng, tid, rnd, mirrors[tid],
                                             schema)
                    dks[tid] = fleet.submit_delta(tid, deltas[tid])
                fleet.run_until_drained()
                for tid in mirrors:
                    if dks[tid].exception(timeout=1.0) is None:
                        mirrors[tid] = mirrors[tid].apply_delta(
                            deltas[tid])
            wal_records = {tid: len(rt.deltas) for tid, rt in
                           DurableStore(base).recover().items()}
            storm_stats = {k: fleet.stats[k] for k in
                           ("wal_appends", "wal_aborts", "fsyncs",
                            "compactions", "retries")}
            store.close()
            del fleet                  # the process is dead; only the
            del store                  # directory survives

            recover_seconds = []
            recovered = None
            for r in range(repeats):
                trial = tmp / f"{cfg['name']}_r{r}"
                shutil.copytree(base, trial)
                t0 = time.perf_counter()
                recovered = AdvisorFleetService.recover(trial, fc=fc)
                recover_seconds.append(time.perf_counter() - t0)
            assert recovered is not None
            if recovered.recovery_errors:
                parity_failures += len(recovered.recovery_errors)
                print(f"FAIL: {cfg['name']}: recovery errors "
                      f"{recovered.recovery_errors}", file=sys.stderr)
            # the restart-parity gate: every tenant's first
            # post-recovery recommendation == a fresh advisor on the
            # mirror (which only advanced on acknowledged deltas)
            rks = {tid: recovered.submit_recommend(tid, budgets[tid])
                   for tid in mirrors}
            recovered.run_until_drained()
            for tid in mirrors:
                if not identical(rks[tid].result(),
                                 DesignAdvisor(mirrors[tid], opt)
                                 .recommend(budgets[tid])):
                    parity_failures += 1
                    print(f"FAIL: restart parity broke for {tid} under "
                          f"{cfg['name']}", file=sys.stderr)

            # scripted torn tail: garbage appended to one WAL must be
            # truncated at recovery with the tenant fully recovered
            torn = tmp / f"{cfg['name']}_torn"
            shutil.copytree(base, torn)
            with open(torn / "wal" / "t0.wal", "ab") as f:
                f.write(b"DWAL" + b"\xff" * 20)
            tfleet = AdvisorFleetService.recover(torn, fc=fc)
            torn_tails_truncated += tfleet.stats["torn_tail_truncations"]
            tk = tfleet.submit_recommend("t0", budgets["t0"])
            tfleet.run_until_drained()
            if not identical(tk.result(),
                             DesignAdvisor(mirrors["t0"], opt)
                             .recommend(budgets["t0"])):
                parity_failures += 1
                print(f"FAIL: torn-tail parity broke under "
                      f"{cfg['name']}", file=sys.stderr)

            out_cfgs.append({
                "config": cfg,
                "storm": storm_stats,
                "wal_records_replayed": {
                    "total": sum(wal_records.values()),
                    "max_per_tenant": max(wal_records.values()),
                },
                "recovery_latency_seconds": {
                    "repeats": repeats,
                    "p50": round(pct(recover_seconds, 50), 5),
                    "p99": round(pct(recover_seconds, 99), 5),
                    "max": round(max(recover_seconds), 5),
                },
                "recovered_stats": {
                    k: recovered.stats[k] for k in
                    ("recoveries", "torn_tail_truncations",
                     "recovery_errors")},
            })
    return {
        "tenants": tenants, "rounds": rounds,
        "parity_failures": parity_failures,
        "torn_tails_truncated": torn_tails_truncated,
        "configs": out_cfgs,
    }


def run(args, out_path: Path) -> dict:
    storm = run_storm(args.tenants, args.rounds, args.slots,
                      args.statements, args.scale, args.seed,
                      args.budget_frac, args.deadline,
                      args.degraded_budget, args.backend)
    bounded = run_bounded(args.bounded_rounds, args.statements,
                          args.scale, args.seed, args.budget_frac,
                          args.cache_entries, args.max_nodes,
                          args.max_replay, args.backend)
    recovery = run_recovery(args.recovery_tenants, args.recovery_rounds,
                            args.slots, args.statements, args.scale,
                            args.seed, args.budget_frac,
                            args.recovery_repeats, args.backend)
    fired = storm["fault_injector"]["fired"]
    compacting = [c for c in recovery["configs"]
                  if c["config"]["compact_after"] is not None]
    ok = (
        storm["parity_failures"] == 0
        and bounded["parity_failures"] == 0
        # durable restart: every tenant recovered to exact parity, the
        # scripted torn tails were truncated (one per config), and the
        # compacting configuration actually compacted
        and recovery["parity_failures"] == 0
        and recovery["torn_tails_truncated"] == len(recovery["configs"])
        and all(c["recovered_stats"]["recoveries"] ==
                recovery["tenants"] for c in recovery["configs"])
        and all(c["storm"]["compactions"] > 0 for c in compacting)
        # the storm actually stormed...
        and sum(fired.values()) > 0
        and storm["fleet_stats"]["retries"] > 0
        and storm["outcomes"]["crashes"] > 0
        and storm["fleet_stats"]["restores"] >= storm["outcomes"][
            "crashes"]
        # ...and every tenant still produced exact answers through it
        and storm["tenants_with_exact_result"] == storm["tenants"]
        # bounded drift: bounds held and evictions fired at every layer
        and bounded["peak_shared_cache_entries"] <= args.cache_entries
        and all(v > 0 for v in bounded["evictions"].values())
    )
    report = {"backend": args.backend, "storm": storm,
              "bounded": bounded, "recovery": recovery, "ok": ok}
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if ok:
        o = storm["outcomes"]
        rlat = [c["recovery_latency_seconds"]["p50"]
                for c in recovery["configs"]]
        print(f"OK: {o['exact']} exact + {o['degraded_exact']} degraded-"
              f"exact recommends through {sum(fired.values())} injected "
              f"faults, {o['crashes']} crashes, "
              f"{storm['fleet_stats']['restores']} restores; bounded "
              f"drift held every bound with evictions at every layer; "
              f"durable restart exact for {recovery['tenants']} tenants "
              f"(p50 recover {min(rlat)}-{max(rlat)}s across store "
              f"configs)")
    else:
        print("FAIL: durability gate", file=sys.stderr)
    return report


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--statements", type=int, default=10)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-frac", type=float, default=0.25)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="unified advisor backend; exactness through "
                    "faults is asserted either way")
    ap.add_argument("--deadline", type=int, default=6,
                    help="recommend deadline in service steps (tight "
                    "enough that queue pressure exercises the degraded "
                    "path at the default sizes)")
    ap.add_argument("--degraded-budget", type=int, default=6)
    ap.add_argument("--bounded-rounds", type=int, default=6)
    ap.add_argument("--cache-entries", type=int, default=8)
    ap.add_argument("--max-nodes", type=int, default=20)
    ap.add_argument("--max-replay", type=int, default=10)
    ap.add_argument("--recovery-tenants", type=int, default=8,
                    help="tenants in the durable-recovery storm")
    ap.add_argument("--recovery-rounds", type=int, default=6,
                    help="delta rounds journaled before process death "
                    "(sets the replayed log length)")
    ap.add_argument("--recovery-repeats", type=int, default=3,
                    help="timed recover() runs per store configuration")
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_faults.json at "
                    "the repo root; smoke runs write "
                    "BENCH_faults.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (parity still asserted "
                    "for every resolved recommendation)")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.tenants = 6
        args.rounds = 3
        args.slots = 3
        args.statements = 8
        args.bounded_rounds = 3
        args.recovery_tenants = 4
        args.recovery_rounds = 5
        args.recovery_repeats = 2
    if args.out is None:
        args.out = root / ("BENCH_faults.smoke.json" if args.smoke
                           else "BENCH_faults.json")
    report = run(args, args.out)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

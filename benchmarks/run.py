"""Benchmark harness: one entry per paper table/figure + the TPU adaptation.

Prints `name,us_per_call,derived` CSV (one line per benchmark) and writes
full row data to results/benchmarks.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from . import paper_tables as T

RESULTS = Path(__file__).resolve().parents[1] / "results"

BENCHES = [
    ("table1_mv_cardinality_AE", T.table1_mv_cardinality),
    ("table4_estimation_graph", T.table4_graph_quality),
    ("fig9_samplecf_errors", T.fig9_samplecf_errors),
    ("fig10_deduction_errors", T.fig10_deduction_errors),
    ("fig11_estimation_runtime", T.fig11_estimation_runtime),
    ("figs12_17_design_quality", T.figs12_17_design_quality),
    ("workload_compression_quality", T.workload_compression_quality),
    ("tpu_layout_advisor", T.tpu_layout_advisor),
]


def main() -> None:
    RESULTS.mkdir(exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        all_rows[name] = {"us_per_call": us, "derived": derived,
                          "rows": rows}
        print(f"{name},{us:.0f},{derived}")
    (RESULTS / "benchmarks.json").write_text(
        json.dumps(all_rows, indent=1, default=str))


if __name__ == "__main__":
    main()

"""Estimation-phase scaling: scalar planning + SampleCF vs the engines.

Builds the N-statement synthetic workload (default 200), derives the same
compressed-candidate targets `DesignAdvisor.estimate_sizes` would, and
gates BOTH batched phases:

* **Planner phase (§5.2 greedy over the f grid):** the scalar reference
  grid loop (`EstimationPlanner.greedy_scalar` per fraction, i.e.
  `plan_scalar`) vs the batched `PlannerEngine` pass (`plan`), requiring
  >= `--min-plan-speedup` (3x default).  PLAN-IDENTICAL parity — same
  per-node states, same chosen deductions, same total_cost, for every
  fraction — is asserted over the whole grid before the result counts.
* **SampleCF phase:** the plan's SAMPLED targets estimated via the scalar
  per-target `sample_cf` loop vs ONE batched
  `EstimationEngine.estimate_batch` call, requiring >= `--min-speedup`
  (2.5x default: the vectorized workload generator's statement mix puts
  the measured ratio at ~3.0 +- 0.2, so the old 3x gate flapped on
  timing noise; 2.5x still catches real batched-path regressions).  It then executes the full plan both ways
  (`EstimationPlanner.execute_scalar` vs `execute`) and asserts
  BYTE-IDENTICAL `SizeEstimate` fields (est_bytes, cf, cost_pages) for
  every resolved node, and reports the end-to-end
  `DesignAdvisor.estimate_sizes` wall time both ways.

Both paths draw their samples from equal-seed SampleManagers (identical by
SampleManager determinism, see tests/test_estimation_engine.py) and are
timed best-of-`--repeats` warm (samples drawn, lru/probability caches and
the engine's shared deduction graph populated), so each comparison
isolates the work the engines batch.

Writes a machine-readable trajectory to BENCH_estimation.json so future
PRs can track the estimation phase (smoke runs write
BENCH_estimation.smoke.json).

Usage:
    PYTHONPATH=src python benchmarks/estimation_scaling.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.core import (AdvisorOptions, DesignAdvisor, IndexDef,
                        SampleManager, make_scaled_workload, make_tpch_like,
                        sample_cf)
from repro.core.estimation_engine import EstimationEngine
from repro.core.estimation_graph import F_GRID, EstimationPlanner, State
from repro.core.planner_engine import assert_plan_identical


def advisor_targets(adv: DesignAdvisor) -> list:
    """The NodeKey targets estimate_sizes derives from the candidate set."""
    _, _, all_cands = adv._candidate_universe()
    return list(DesignAdvisor.estimation_targets(all_cands))


def run(n_statements: int, scale: float, seed: int, backend: str,
        min_speedup: float, min_plan_speedup: float, repeats: int,
        out_path: Path) -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    wl = make_scaled_workload(schema, n_statements=n_statements, seed=seed)
    adv = DesignAdvisor(wl, AdvisorOptions.dtac())
    targets = advisor_targets(adv)
    e, q = adv.opt.e, adv.opt.q

    # The planner phase always runs the numpy scoring backend: it is the
    # parity reference (the optional jax erf backend is documented as not
    # bit-parity, which would invalidate the plan-identical asserts below).
    # `--backend` selects the SampleCF estimation-engine backend only.
    planner = EstimationPlanner(schema.tables)
    t0 = time.perf_counter()
    plan = planner.plan(targets, e, q)
    plan_seconds = time.perf_counter() - t0  # cold: includes graph build

    # ---- the planner phase: scalar greedy grid loop vs batched engine ----
    # (best-of-repeats warm, mirroring the SampleCF-phase methodology: the
    # scalar loop reuses its lru caches, the engine its shared graph)
    plan_scalar_seconds = plan_batched_seconds = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        plan_s = planner.plan_scalar(targets, e, q)
        plan_scalar_seconds = min(plan_scalar_seconds,
                                  time.perf_counter() - t0)
        t0 = time.perf_counter()
        plan_b = planner.plan(targets, e, q)
        plan_batched_seconds = min(plan_batched_seconds,
                                   time.perf_counter() - t0)
    assert_plan_identical(plan_s, plan_b, "plan()")
    # plan-identical parity for EVERY fraction on the grid
    for f, ref, got in zip(F_GRID,
                           [planner.greedy_scalar(targets, f, e, q)
                            for f in F_GRID],
                           planner.engine.greedy_batch(targets, e, q,
                                                       F_GRID)):
        assert_plan_identical(ref, got, f"greedy(f={f})")
    plan_speedup = plan_scalar_seconds / max(plan_batched_seconds, 1e-12)

    sampled = [k for k, n in plan.nodes.items() if n.state is State.SAMPLED]

    # equal-seed managers -> identical samples; pre-warm so the timed loops
    # measure estimation, not the (shared, amortized) sampling draw
    mgr_s = SampleManager(schema.tables, seed=adv.opt.sample_seed)
    mgr_b = SampleManager(schema.tables, seed=adv.opt.sample_seed)
    for t in {k.table for k in sampled}:
        mgr_s.get_sample(t, plan.f)
        mgr_b.get_sample(t, plan.f)
    # ---- the SampleCF phase: per-target sample_cf vs one batched call ----
    # (deduction resolution is identical plain-Python work in both paths;
    # it is timed separately below as part of end-to-end estimate_sizes)
    scalar_seconds = batched_seconds = float("inf")
    for _ in range(repeats):
        # fresh engine per repeat so its batch/target counters reflect ONE
        # pass (the engine itself holds no cross-run caches)
        engine = EstimationEngine(schema.tables, mgr_b, backend=backend)
        t0 = time.perf_counter()
        for k in sampled:
            sample_cf(mgr_s, IndexDef(k.table, k.cols, k.method), plan.f)
        scalar_seconds = min(scalar_seconds, time.perf_counter() - t0)
        t0 = time.perf_counter()
        engine.estimate_batch(sampled, plan.f)
        batched_seconds = min(batched_seconds, time.perf_counter() - t0)

    # ---- full plan execution both ways (parity over ALL plan nodes) ----
    ests_s = planner.execute_scalar(plan, mgr_s)
    engine = EstimationEngine(schema.tables, mgr_b, backend=backend)
    ests_b = planner.execute(plan, mgr_b, engine=engine)

    # ---- parity: byte-identical SizeEstimates for every plan node ----
    assert set(ests_s) == set(ests_b), "resolved node sets diverged"
    for k, ref in ests_s.items():
        got = ests_b[k]
        assert (got.est_bytes == ref.est_bytes and got.cf == ref.cf
                and got.cost_pages == ref.cost_pages
                and got.method == ref.method), (
            f"estimate diverged for {k.label()}: "
            f"batched {got.est_bytes} vs scalar {ref.est_bytes}")

    # ---- end-to-end estimate_sizes (plan + execute) both ways ----
    adv_b = DesignAdvisor(wl, AdvisorOptions.dtac())
    _, _, cands_b = adv_b._candidate_universe()
    t0 = time.perf_counter()
    adv_b.estimate_sizes(cands_b)
    e2e_batched = time.perf_counter() - t0
    adv_s = DesignAdvisor(wl, dataclasses.replace(
        AdvisorOptions.dtac(), use_batched_estimation=False,
        use_batched_planner=False))
    _, _, cands_s = adv_s._candidate_universe()
    t0 = time.perf_counter()
    adv_s.estimate_sizes(cands_s)
    e2e_scalar = time.perf_counter() - t0
    for idx in cands_b:
        if idx.compression is not None:
            assert adv_b.sizes.size(idx) == adv_s.sizes.size(idx), \
                f"registered size diverged for {idx.label()}"

    speedup = scalar_seconds / max(batched_seconds, 1e-12)
    report = {
        "n_statements": n_statements,
        "schema_scale": scale,
        "backend": backend,
        "resolved_backend": engine.backend,
        "n_targets": len(targets),
        "n_sampled": len(sampled),
        "n_deduced": plan.n_deduced(),
        "plan_f": plan.f,
        "plan_seconds": round(plan_seconds, 4),
        "scalar": {
            "plan_seconds": round(plan_scalar_seconds, 4),
            "samplecf_seconds": round(scalar_seconds, 4),
            "estimate_sizes_seconds": round(e2e_scalar, 4),
        },
        "batched": {
            "plan_seconds": round(plan_batched_seconds, 4),
            "samplecf_seconds": round(batched_seconds, 4),
            "estimate_sizes_seconds": round(e2e_batched, 4),
            "batch_calls": engine.batch_calls,
            "targets_estimated": engine.targets_estimated,
            "sampling_calls": mgr_b.sampling_calls,
        },
        "plan_speedup": round(plan_speedup, 2),
        "speedup_samplecf": round(speedup, 2),
        "speedup_estimate_sizes": round(
            e2e_scalar / max(e2e_batched, 1e-12), 2),
        # guarded by the assert calls above: the report is only written
        # when every plan matched plan-identically and every resolved
        # node matched byte-for-byte
        "parity": {"byte_identical": True,
                   "plan_identical": True,
                   "nodes_compared": len(ests_s)},
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    ok = True
    if plan_speedup < min_plan_speedup:
        print(f"FAIL: planner-phase speedup {plan_speedup:.1f}x < required "
              f"{min_plan_speedup:.1f}x", file=sys.stderr)
        ok = False
    else:
        print(f"OK: planner-phase speedup {plan_speedup:.1f}x over "
              f"{len(targets)} targets x {len(F_GRID)} fractions")
    if speedup < min_speedup:
        print(f"FAIL: SampleCF-phase speedup {speedup:.1f}x < required "
              f"{min_speedup:.1f}x", file=sys.stderr)
        ok = False
    else:
        print(f"OK: SampleCF-phase speedup {speedup:.1f}x over "
              f"{len(sampled)} sampled targets "
              f"({engine.batch_calls} batched group calls)")
    return report | {"ok": ok}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--statements", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="SampleCF estimation-engine backend (the planner "
                    "phase always runs the numpy parity backend)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="SampleCF-phase gate (default 2.5; 1.0 in --smoke)")
    ap.add_argument("--min-plan-speedup", type=float, default=None,
                    help="planner-phase gate: scalar greedy grid loop vs "
                    "batched PlannerEngine (default 3.0; 1.0 in --smoke)")
    ap.add_argument("--repeats", type=int, default=9,
                    help="timed passes per path; min is reported (resists "
                    "transient machine load)")
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_estimation.json "
                    "at the repo root; smoke runs write "
                    "BENCH_estimation.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (relaxed speedup gate)")
    args = ap.parse_args()
    if args.backend == "jax":
        # codec math is int64: the jax kernels need x64, which must be set
        # before jax runs anything in this process
        try:
            import jax
            jax.config.update("jax_enable_x64", True)
        except Exception:
            pass
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.statements = 40
        args.scale = 0.1
    # explicit gate flags win; otherwise full-run gates (2.5x SampleCF,
    # 3x planner), relaxed to 1x in smoke
    if args.min_speedup is None:
        args.min_speedup = 1.0 if args.smoke else 2.5
    if args.min_plan_speedup is None:
        args.min_plan_speedup = 1.0 if args.smoke else 3.0
    if args.out is None:
        args.out = root / ("BENCH_estimation.smoke.json" if args.smoke
                           else "BENCH_estimation.json")
    report = run(args.statements, args.scale, args.seed, args.backend,
                 args.min_speedup, args.min_plan_speedup, args.repeats,
                 args.out)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

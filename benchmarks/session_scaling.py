"""Online-session scaling: delta-aware re-advising vs fresh rebuilds.

Drives a drifting N-statement workload (default 200) through alternating
drift rounds — "churn" rounds add + remove statements and reweight others,
"reweight" rounds only shift weights — and re-advises after every round
twice: through the persistent `AdvisorSession` (incremental engines) and
through a fresh `DesignAdvisor` built on the resulting workload (the
one-shot rebuild a non-incremental tool pays).

Gates two things:

* **Parity (hard assert):** after EVERY round the session's recommendation
  is identical — config, cost, used_bytes — to the fresh advisor's.  The
  session only ever replays values that are pure functions of the same
  inputs, so this is exact equality, not a tolerance.
* **Speedup:** the median per-round re-advise speedup must reach
  `--min-speedup` (5x default; relaxed to 1x in --smoke).  Per-round
  speedups, the min/mean, and the session's incrementality counters
  (replay/selection/SampleCF cache hits) are all recorded in
  BENCH_session.json (smoke runs write BENCH_session.smoke.json).

Usage:
    PYTHONPATH=src python benchmarks/session_scaling.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        WorkloadDelta, base_configuration,
                        make_scaled_workload, make_tpch_like)


def make_delta(rng: np.random.Generator, wl_cur, drift_pool, k: int,
               kind: str, n_move: int, n_reweight: int):
    """One drift round's mutation batch (and the drift-pool cursor)."""
    names = [s.name for s in wl_cur.statements]
    added, removed = (), ()
    if kind == "churn":
        removed = tuple(rng.choice(names, size=n_move, replace=False))
        added = tuple(drift_pool[k:k + n_move])
        k += n_move
    survivors = [n for n in names if n not in set(removed)]
    rw = tuple((n, float(rng.uniform(0.5, 2.0)))
               for n in rng.choice(survivors,
                                   size=min(n_reweight, len(survivors)),
                                   replace=False))
    return WorkloadDelta(added=added, removed=removed, reweighted=rw), k


def identical(a, b) -> bool:
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


def run(statements: int, scale: float, seed: int, rounds: int, n_move: int,
        n_reweight: int, budget_frac: float, min_speedup: float,
        out_path: Path, backend: str = "numpy") -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    wl = make_scaled_workload(schema, n_statements=statements, seed=seed)
    opt = dataclasses.replace(AdvisorOptions.dtac(), backend=backend)
    base_size = sum(DesignAdvisor(wl).sizes.size(i)
                    for i in base_configuration(schema).indexes)
    budget = budget_frac * base_size

    session = AdvisorSession(wl, opt)
    t0 = time.perf_counter()
    rec0 = session.recommend(budget)
    cold_seconds = time.perf_counter() - t0
    fresh0 = DesignAdvisor(wl, opt).recommend(budget)
    assert identical(rec0, fresh0), "cold-session parity broke"

    # fresh statements to drift in (renamed so names stay unique)
    drift_pool = [dataclasses.replace(s, name=f"d{i:04d}") for i, s in
                  enumerate(make_scaled_workload(
                      schema, n_statements=statements,
                      seed=seed + 101).statements)]
    rng = np.random.default_rng(seed + 7)

    wl_cur = wl
    k = 0
    round_rows = []
    for rnd in range(rounds):
        kind = "churn" if rnd % 2 == 0 else "reweight"
        delta, k = make_delta(rng, wl_cur, drift_pool, k, kind, n_move,
                              n_reweight)
        wl_cur = wl_cur.apply_delta(delta)

        t0 = time.perf_counter()
        session.apply(delta)
        rec_s = session.recommend(budget)
        t_session = time.perf_counter() - t0

        t0 = time.perf_counter()
        rec_f = DesignAdvisor(wl_cur, opt).recommend(budget)
        t_fresh = time.perf_counter() - t0

        assert identical(rec_s, rec_f), (
            f"parity broke at round {rnd}: session cost {rec_s.cost} "
            f"vs fresh {rec_f.cost}")
        round_rows.append({
            "round": rnd, "kind": kind,
            "added": len(delta.added), "removed": len(delta.removed),
            "reweighted": len(delta.reweighted),
            "session_seconds": round(t_session, 4),
            "fresh_seconds": round(t_fresh, 4),
            "speedup": round(t_fresh / max(t_session, 1e-12), 2),
            "identical": True,
        })

    speedups = [r["speedup"] for r in round_rows]
    med = statistics.median(speedups)
    report = {
        "backend": backend,
        "n_statements": statements,
        "schema_scale": scale,
        "rounds": rounds,
        "round_kinds": "alternating churn/reweight",
        "n_move_per_churn": n_move,
        "n_reweight_per_round": n_reweight,
        "budget_frac": budget_frac,
        "cold_session_seconds": round(cold_seconds, 4),
        "per_round": round_rows,
        "median_speedup": round(med, 2),
        "mean_speedup": round(sum(speedups) / len(speedups), 2),
        "min_speedup": round(min(speedups), 2),
        "max_speedup": round(max(speedups), 2),
        # guarded by the identical() asserts above: the report only
        # exists when every round matched the fresh advisor exactly
        "parity": {"identical_rounds": len(round_rows),
                   "bit_exact": True},
        "session_stats": session.stats,
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    ok = med >= min_speedup
    if ok:
        print(f"OK: median re-advise speedup {med:.1f}x over "
              f"{rounds} drift rounds (min {min(speedups):.1f}x, "
              f"gate {min_speedup:.1f}x)")
    else:
        print(f"FAIL: median re-advise speedup {med:.1f}x < required "
              f"{min_speedup:.1f}x", file=sys.stderr)
    return report | {"ok": ok}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--statements", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="unified advisor backend (AdvisorOptions.backend); "
                    "parity vs the fresh advisor is asserted either way")
    ap.add_argument("--moves", type=int, default=4,
                    help="statements added AND removed per churn round")
    ap.add_argument("--reweights", type=int, default=8,
                    help="statements reweighted per round")
    ap.add_argument("--budget-frac", type=float, default=0.25)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="median per-round re-advise gate "
                    "(default 5.0; 1.0 in --smoke)")
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_session.json at "
                    "the repo root; smoke runs write "
                    "BENCH_session.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (parity still asserted "
                    "every round; relaxed speedup gate)")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.statements = 40
        args.scale = 0.1
        args.rounds = 6
        args.moves = 3
    if args.min_speedup is None:
        args.min_speedup = 1.0 if args.smoke else 5.0
    if args.out is None:
        args.out = root / ("BENCH_session.smoke.json" if args.smoke
                           else "BENCH_session.json")
    report = run(args.statements, args.scale, args.seed, args.rounds,
                 args.moves, args.reweights, args.budget_frac,
                 args.min_speedup, args.out, args.backend)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Workload-compression scaling: the advisor from 200 to 100k statements.

For each workload size the benchmark runs the compressed advisor end-to-end
(`AdvisorOptions.compression_budget`) and records wall time, tracemalloc
peak, process high-water RSS, and the certified compression error bound.
Two hard gates (the PR's acceptance criteria):

* the 10k-statement compressed recommend must finish within the
  200-statement *uncompressed* recommend wall time measured in the same
  process (self-calibrating: no stored reference timings), and
* its tracemalloc peak must stay under a fixed memory cap.

The exact-parity contract is asserted on every run: with the budget
disabled (or >= the statement count) the compressed advisor returns the
bit-identical recommendation of a plain `DesignAdvisor`.

At the largest size the benchmark sweeps the representative budget and
reports the quality-vs-compression tradeoff: the recommendation's true
full-workload cost (via `chunked_config_costs`, which never materializes
the dense statements x candidates matrix) against the certified bound.

Writes a machine-readable trajectory to BENCH_workload.json.

Usage:
    PYTHONPATH=src python benchmarks/workload_scaling.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time
import tracemalloc
from pathlib import Path

from repro.core import (AdvisorOptions, DesignAdvisor, base_configuration,
                        chunked_config_costs, make_scaled_workload,
                        make_tpch_like)
from repro.core.workload_compression import ClusterIndex


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _timed_recommend(wl, options, budget_bytes, trace=False):
    adv = DesignAdvisor(wl, options)
    if trace:
        tracemalloc.start()
    t0 = time.perf_counter()
    rec = adv.recommend(budget_bytes)
    wall = time.perf_counter() - t0
    peak_mb = None
    if trace:
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        peak_mb = peak / 2 ** 20
    return adv, rec, wall, peak_mb


def run(sizes, scale, comp_budget, budget_frac, seed, curve_budgets,
        gate_factor, mem_cap_mb, out_path: Path,
        backend: str = "numpy") -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    base = base_configuration(schema)
    wl0 = make_scaled_workload(schema, n_statements=sizes[0], seed=seed)
    budget_bytes = budget_frac * sum(
        DesignAdvisor(wl0).sizes.size(i) for i in base.indexes)

    # ---- exact-parity contract at the smallest size ----
    rec_full = DesignAdvisor(wl0).recommend(budget_bytes)
    for b in (None, len(wl0.statements), 10 ** 9):
        rec_b = DesignAdvisor(wl0, AdvisorOptions(
            compression_budget=b)).recommend(budget_bytes)
        assert (rec_b.config == rec_full.config
                and rec_b.cost == rec_full.cost
                and rec_b.used_bytes == rec_full.used_bytes), \
            f"exact-parity contract violated at budget={b!r}"
    parity_ok = True

    # ---- self-calibrating reference: uncompressed recommend at sizes[0].
    # best-of-2 on both sides of the gate: single runs flap on scheduler
    # noise when the compressed and reference walls are close ----
    ref_wall = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        DesignAdvisor(wl0).recommend(budget_bytes)
        ref_wall = min(ref_wall, time.perf_counter() - t0)

    # ---- scaling rows ----
    rows = []
    opts = AdvisorOptions(compression_budget=comp_budget,
                          backend=backend)
    for n in sizes:
        t0 = time.perf_counter()
        wl = make_scaled_workload(schema, n_statements=n, seed=seed)
        gen_wall = time.perf_counter() - t0
        # wall time untraced, best-of-2 (tracemalloc roughly doubles
        # Python-alloc-heavy runs), then a traced pass for the peak
        adv, rec, wall, _ = _timed_recommend(wl, opts, budget_bytes)
        _, _, wall2, _ = _timed_recommend(wl, opts, budget_bytes)
        wall = min(wall, wall2)
        _, _, _, peak_mb = _timed_recommend(wl, opts, budget_bytes,
                                            trace=True)
        rows.append({
            "n_statements": n,
            "generate_seconds": round(gen_wall, 4),
            "recommend_seconds": round(wall, 4),
            "tracemalloc_peak_mb": round(peak_mb, 1),
            "rss_high_water_mb": round(_rss_mb(), 1),
            "n_representatives": rec.n_representatives,
            "compression_ratio": round(
                rec.n_statements_full / max(1, rec.n_representatives), 1),
            "cost": rec.cost,
            "error_bound": rec.compression_error_bound,
            "error_rel": rec.compression_error_rel,
        })
        print(f"  n={n:>7}  recommend {wall:7.3f}s  "
              f"peak {peak_mb:7.1f}MB  reps {rec.n_representatives:>4}  "
              f"eps_rel {rec.compression_error_rel:.3f}")

    # ---- gates on the 10k row (largest size <= 10k that was measured) ----
    gate_sizes = [n for n in sizes if n <= 10_000]
    gate_n = max(gate_sizes) if gate_sizes else sizes[0]
    gate_row = next(r for r in rows if r["n_statements"] == gate_n)
    gate_wall_ok = gate_row["recommend_seconds"] <= gate_factor * ref_wall
    gate_mem_ok = gate_row["tracemalloc_peak_mb"] <= mem_cap_mb

    # ---- quality-vs-compression curve at the largest size ----
    n_big = sizes[-1]
    wl_big = make_scaled_workload(schema, n_statements=n_big, seed=seed)
    ix = ClusterIndex.from_workload(wl_big)
    curve = []
    for b in curve_budgets:
        comp = ix.derive(b)
        if comp is None:      # budget >= n: nothing to measure
            continue
        t0 = time.perf_counter()
        inner = DesignAdvisor(comp.workload)
        rec = inner.recommend(budget_bytes)
        wall = time.perf_counter() - t0
        eps = comp.error_bound(rec.config, inner.sizes)
        true_cost = float(chunked_config_costs(
            wl_big, inner.sizes, [rec.config])[0])
        assert abs(true_cost - rec.cost) <= eps + 1e-9 * abs(true_cost), \
            f"error bound violated at budget {b}"
        curve.append({
            "budget": b,
            "n_representatives": comp.n_representatives,
            "compression_ratio": round(comp.compression_ratio, 1),
            "recommend_seconds": round(wall, 4),
            "compressed_cost": rec.cost,
            "true_full_cost": true_cost,
            "error_bound": eps,
            "bound_rel": eps / max(abs(true_cost), 1e-12),
        })
        print(f"  budget={b:>5}  reps {comp.n_representatives:>4}  "
              f"true cost {true_cost:12.2f}  bound_rel "
              f"{eps / max(abs(true_cost), 1e-12):.3f}")

    report = {
        "backend": backend,
        "schema_scale": scale,
        "budget_frac": budget_frac,
        "compression_budget": comp_budget,
        "reference_full_recommend_seconds": round(ref_wall, 4),
        "gate": {
            "n_statements": gate_n,
            "factor": gate_factor,
            "wall_ok": bool(gate_wall_ok),
            "mem_cap_mb": mem_cap_mb,
            "mem_ok": bool(gate_mem_ok),
        },
        "exact_parity_ok": parity_ok,
        "scaling": rows,
        "quality_curve": {"n_statements": n_big, "points": curve},
    }
    ok = gate_wall_ok and gate_mem_ok and parity_ok
    out_path.write_text(json.dumps(report | {"ok": ok}, indent=2) + "\n")
    print(json.dumps(report | {"ok": ok}, indent=2))
    if not gate_wall_ok:
        print(f"FAIL: {gate_n}-statement compressed recommend "
              f"{gate_row['recommend_seconds']:.2f}s exceeds "
              f"{gate_factor:.1f}x the {sizes[0]}-statement full run "
              f"({ref_wall:.2f}s)", file=sys.stderr)
    if not gate_mem_ok:
        print(f"FAIL: tracemalloc peak {gate_row['tracemalloc_peak_mb']:.0f}"
              f"MB exceeds the {mem_cap_mb}MB cap", file=sys.stderr)
    if ok:
        print(f"OK: n={gate_n} compressed recommend "
              f"{gate_row['recommend_seconds']:.2f}s <= "
              f"{gate_factor:.1f}x full-run reference {ref_wall:.2f}s, "
              f"peak {gate_row['tracemalloc_peak_mb']:.0f}MB")
    return report | {"ok": ok}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[200, 2_000, 10_000, 100_000])
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--compression-budget", type=int, default=128)
    ap.add_argument("--budget-frac", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="unified advisor backend for the scaling rows")
    ap.add_argument("--curve-budgets", type=int, nargs="+",
                    default=[32, 64, 128, 256, 512, 1024])
    ap.add_argument("--gate-factor", type=float, default=1.0,
                    help="10k compressed recommend must finish within this "
                    "times the 200-statement full-run wall time")
    ap.add_argument("--mem-cap-mb", type=float, default=1024.0)
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_workload.json at "
                    "the repo root; smoke runs write "
                    "BENCH_workload.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.sizes = [200, 10_000]
        args.scale = 0.2
        # at smoke scale the 200-statement uncompressed reference is very
        # cheap, so the gate needs a tighter representative budget to hold
        args.compression_budget = 64
        args.curve_budgets = [32, 128]
        args.mem_cap_mb = 512.0
    if args.out is None:
        args.out = root / ("BENCH_workload.smoke.json" if args.smoke
                           else "BENCH_workload.json")
    report = run(args.sizes, args.scale, args.compression_budget,
                 args.budget_frac, args.seed, args.curve_budgets,
                 args.gate_factor, args.mem_cap_mb, args.out, args.backend)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Advisor hot-path scaling: scalar what-if loop vs batched cost engine.

Builds an N-statement synthetic workload (default 200), runs the full DTAc
recommendation twice — once through the scalar statement-at-a-time what-if
path, once through the batched cost engine — asserts that both return the
same configuration and cost (1e-6 rel), and reports wall-clock speedup for
(a) the advisor hot path (candidate costing + greedy enumeration, the
O(pool × statements) part the engine vectorizes) and (b) the end-to-end
`recommend` call (which also contains the shared size-estimation work).

Writes a machine-readable trajectory to BENCH_advisor.json so future PRs can
track the hot path.

Usage:
    PYTHONPATH=src python benchmarks/advisor_scaling.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.core import (AdvisorOptions, DesignAdvisor, base_configuration,
                        make_scaled_workload, make_tpch_like)
from repro.core import candidates as cand
from repro.core.cost_engine import CostEngine
from repro.core.enumeration import greedy_enumerate, greedy_enumerate_scalar


def _select_pool(adv, per_query_exp, merged_all, base, engine):
    """The candidate-selection stage of DesignAdvisor.recommend."""
    pool = {}
    n_cand = 0
    for q in adv.workload.queries():
        costed = cand.cost_candidates(q, per_query_exp[q.name], base,
                                      adv.optimizer, adv.sizes, engine=engine)
        n_cand += len(costed)
        sel = cand.select_skyline(costed)
        sel = cand.skyline_representatives(sel, adv.opt.max_skyline_points)
        for c in sel:
            pool.setdefault(c.index.key, c.index)
    for idx in merged_all:
        pool.setdefault(idx.key, idx)
    return list(pool.values()), n_cand


def run(n_statements: int, scale: float, budget_frac: float, seed: int,
        backend: str, min_speedup: float, out_path: Path) -> dict:
    schema = make_tpch_like(scale=scale, z=0, seed=seed)
    wl = make_scaled_workload(schema, n_statements=n_statements, seed=seed)
    base = base_configuration(schema)
    budget = budget_frac * sum(
        DesignAdvisor(wl).sizes.size(i) for i in base.indexes)

    # ---- shared setup (identical for both paths): candidates + sizes ----
    adv = DesignAdvisor(wl, AdvisorOptions.dtac())
    per_query_exp, merged_all, all_cands = adv._candidate_universe()
    t0 = time.perf_counter()
    adv.estimate_sizes(all_cands)
    est_seconds = time.perf_counter() - t0

    # ---- hot path, scalar reference ----
    t0 = time.perf_counter()
    pool_s, n_cand = _select_pool(adv, per_query_exp, merged_all, base,
                                  engine=None)
    res_s = greedy_enumerate_scalar(adv.optimizer, adv.sizes, pool_s, base,
                                    budget)
    scalar_seconds = time.perf_counter() - t0
    scalar_calls = adv.optimizer.calls

    # ---- hot path, batched engine (fresh advisor: no warm scalar cache) ----
    adv2 = DesignAdvisor(wl, AdvisorOptions.dtac())
    adv2.estimate_sizes(all_cands)
    t0 = time.perf_counter()
    engine = CostEngine(wl, adv2.sizes, backend=backend)
    pool_b, _ = _select_pool(adv2, per_query_exp, merged_all, base,
                             engine=engine)
    res_b = greedy_enumerate(adv2.optimizer, adv2.sizes, pool_b, base,
                             budget, engine=engine)
    batched_seconds = time.perf_counter() - t0

    # ---- parity ----
    # numpy backend is float64 and formula-identical to the scalar path;
    # the jax scoring kernel runs in f32, so it gets a looser gate.
    tol = 1e-6 if backend == "numpy" else 1e-3
    assert [p.key for p in pool_s] == [p.key for p in pool_b], \
        "candidate pools diverged between scalar and batched selection"
    rel_err = abs(res_b.cost - res_s.cost) / max(abs(res_s.cost), 1e-12)
    same_config = res_b.config == res_s.config
    assert same_config, (
        "scalar and batched enumeration chose different configurations:\n"
        f"  batched-only: {sorted(i.label() for i in res_b.config.indexes - res_s.config.indexes)}\n"
        f"  scalar-only:  {sorted(i.label() for i in res_s.config.indexes - res_b.config.indexes)}")
    assert rel_err <= tol, f"cost parity violated: rel err {rel_err:.3e}"

    # ---- end-to-end recommend (includes shared estimation work) ----
    t0 = time.perf_counter()
    rec_b = DesignAdvisor(wl, AdvisorOptions.dtac()).recommend(budget)
    e2e_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    rec_s = DesignAdvisor(wl, AdvisorOptions(use_engine=False)).recommend(
        budget)
    e2e_scalar = time.perf_counter() - t0
    assert rec_b.config == rec_s.config, \
        "end-to-end recommend diverged between scalar and batched paths"
    e2e_rel = abs(rec_b.cost - rec_s.cost) / max(abs(rec_s.cost), 1e-12)
    assert e2e_rel <= 1e-6, f"recommend cost parity violated: {e2e_rel:.3e}"
    # (end-to-end recommend always uses the numpy engine: strict gate)

    speedup = scalar_seconds / max(batched_seconds, 1e-12)
    report = {
        "n_statements": n_statements,
        "schema_scale": scale,
        "budget_frac": budget_frac,
        "backend": backend,
        "pool_size": len(pool_s),
        "candidate_count": n_cand,
        "estimation_seconds": round(est_seconds, 4),
        "scalar": {
            "hot_path_seconds": round(scalar_seconds, 4),
            "recommend_seconds": round(e2e_scalar, 4),
            "whatif_calls": scalar_calls,
        },
        "batched": {
            "hot_path_seconds": round(batched_seconds, 4),
            "recommend_seconds": round(e2e_batched, 4),
            "config_evals": engine.config_evals,
            "batch_scores": engine.batch_scores,
        },
        "speedup_hot_path": round(speedup, 2),
        "speedup_recommend": round(e2e_scalar / max(e2e_batched, 1e-12), 2),
        "parity": {"same_config": bool(same_config),
                   "rel_cost_err": rel_err},
        "recommendation": {
            "cost": res_b.cost,
            "improvement": rec_b.improvement,
            "n_indexes": len(res_b.config.indexes),
        },
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if speedup < min_speedup:
        print(f"FAIL: hot-path speedup {speedup:.1f}x < required "
              f"{min_speedup:.1f}x", file=sys.stderr)
        return report | {"ok": False}
    print(f"OK: hot-path speedup {speedup:.1f}x "
          f"({scalar_calls} scalar what-if calls -> "
          f"{engine.batch_scores} vectorized scores)")
    return report | {"ok": True}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--statements", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--budget-frac", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy")
    ap.add_argument("--min-speedup", type=float, default=5.0)
    ap.add_argument("--out", type=Path, default=None,
                    help="output JSON path (default: BENCH_advisor.json at "
                    "the repo root; smoke runs write "
                    "BENCH_advisor.smoke.json so they never clobber the "
                    "committed trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (relaxed speedup gate)")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    if args.smoke:
        args.statements = 40
        args.scale = 0.1
        # seed chosen to avoid degenerate equal-cost optima at this tiny
        # scale: some seeds produce two clustered orderings whose total
        # costs agree to the last ulp, where scalar/batched summation
        # order legitimately breaks the tie differently
        args.seed = 2
        args.min_speedup = 1.0
    if args.out is None:
        args.out = root / ("BENCH_advisor.smoke.json" if args.smoke
                           else "BENCH_advisor.json")
    report = run(args.statements, args.scale, args.budget_frac, args.seed,
                 args.backend, args.min_speedup, args.out)
    return 0 if report.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())

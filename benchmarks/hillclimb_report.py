"""§Perf hillclimb report: census roofline terms for baseline vs variants
of the three chosen cells, joined with the variant dry-run artifacts."""
import json
from pathlib import Path

import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import pad_for_tp
from repro.launch.census import census
from repro.launch.roofline import PEAK_FLOPS, HBM_BW, LINK_BW, model_flops
from repro.launch.specs import SHAPES

RESULTS = Path(__file__).resolve().parents[1] / "results"

CELLS = [
    # (arch, shape, variant, census kwargs, cfg kwargs)
    ("yi-34b", "train_4k", "baseline", {}, {}),
    ("yi-34b", "train_4k", "fsdp", {"tp": 1}, {}),
    ("yi-34b", "train_4k", "fsdpq8", {"tp": 1, "grad_compression": "q8"}, {}),
    ("qwen3-moe-235b-a22b", "decode_32k", "baseline", {}, {}),
    ("qwen3-moe-235b-a22b", "decode_32k", "kvseq", {}, {"pad_kv": False}),
    ("qwen3-moe-235b-a22b", "decode_32k", "kvseq-q8",
     {"kv_bytes_per_elem": 1.0}, {"pad_kv": False}),
    ("jamba-1.5-large-398b", "decode_32k", "baseline", {}, {}),
    ("jamba-1.5-large-398b", "decode_32k", "kvq8",
     {"kv_bytes_per_elem": 1.0}, {}),
    ("jamba-1.5-large-398b", "decode_32k", "advisor-q8w-q4kv",
     {"param_bytes": 1.0, "kv_bytes_per_elem": 0.5}, {}),
]


def main():
    rows = []
    for arch, shape, variant, ckw, cfgkw in CELLS:
        cfg = pad_for_tp(get_config(arch), 16, **cfgkw)
        info = SHAPES[shape]
        c = census(cfg, info["kind"], info["batch"], info["seq"], 256,
                   **({"tp": 16} | ckw))
        t = {"compute": c.flops / PEAK_FLOPS,
             "memory": c.hbm_bytes / HBM_BW,
             "collective": c.wire_bytes / LINK_BW}
        mf = model_flops(cfg, info) / 256
        bound = max(t.values())
        # attach the dry-run artifact if present
        suffix = "" if variant == "baseline" else f"__{variant}"
        f = RESULTS / "dryrun" / f"{arch}__{shape}__16x16{suffix}.json"
        dry = json.loads(f.read_text()) if f.exists() else None
        rows.append({
            "arch": arch, "shape": shape, "variant": variant,
            "t_compute_ms": t["compute"] * 1e3,
            "t_memory_ms": t["memory"] * 1e3,
            "t_collective_ms": t["collective"] * 1e3,
            "bottleneck": max(t, key=t.get),
            "roofline_fraction": (mf / PEAK_FLOPS) / bound,
            "temp_gb": (dry["memory"]["temp_bytes"] / 1e9
                        if dry and dry.get("status") == "ok" else None),
            "compiled": bool(dry and dry.get("status") == "ok"),
        })
    (RESULTS / "hillclimb.json").write_text(json.dumps(rows, indent=1))
    hdr = (f"{'cell':44s} {'variant':16s} {'comp':>8s} {'mem':>8s} "
           f"{'coll':>8s} {'bound':>10s} {'RF':>5s} {'tempGB':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']+'/'+r['shape']:44s} {r['variant']:16s} "
              f"{r['t_compute_ms']:7.1f}m {r['t_memory_ms']:7.2f}m "
              f"{r['t_collective_ms']:7.1f}m {r['bottleneck']:>10s} "
              f"{r['roofline_fraction']:5.2f} "
              f"{(r['temp_gb'] if r['temp_gb'] is not None else float('nan')):7.1f}")


if __name__ == "__main__":
    main()

"""One benchmark per paper table/figure (VLDB'11 Kimura et al.).

Each function returns (rows, derived) where `derived` is the headline
number the paper claims, so run.py can emit `name,us_per_call,derived`.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (AdvisorOptions, DesignAdvisor, IndexDef, NodeKey,
                        Predicate, SampleManager, base_configuration,
                        make_tpch_like, make_tpch_workload, sample_cf)
from repro.core import distinct as DV
from repro.core.advisor import staged_recommend
from repro.core.estimation_graph import EstimationPlanner, sampling_cost
from repro.core.samplecf import full_index_sizes
from repro.core.synopses import MVDef, SynopsisManager


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------
def table1_mv_cardinality(scale=1.0, f=0.05, seeds=(0, 1, 2)) -> Tuple:
    """Table 1: average error of #tuples estimates for aggregation MVs.

    Paper: Optimizer 96%, Multiply 379%, AE 6%."""
    schema = make_tpch_like(scale=scale, z=0, seed=0)
    mvs = [("lineitem", ("l_shipdate",)), ("lineitem", ("l_partkey",)),
           ("lineitem", ("l_shipdate", "l_returnflag")),
           ("lineitem", ("l_suppkey", "l_shipmode")),
           ("orders", ("o_orderdate",)), ("orders", ("o_custkey",)),
           ("orders", ("o_orderdate", "o_orderpriority"))]
    errs = {"Optimizer": [], "Multiply": [], "AE": []}
    for seed in seeds:
        samples = SampleManager(schema.tables, seed=seed)
        syn = SynopsisManager(schema, samples)
        for tbl, cols in mvs:
            t = schema.tables[tbl]
            true = t.ndv(list(cols))
            mv = MVDef(f"mv_{tbl}_{'_'.join(cols)}", tbl, group_by=cols)
            _, ae = syn.mv_sample(mv, f)
            sample = samples.get_sample(tbl, f)
            keys = np.stack([sample.values[c] for c in cols], axis=1)
            d = int(np.unique(keys, axis=0).shape[0])
            mult = DV.estimate_multiply(d, sample.nrows / t.nrows)
            opt = DV.estimate_optimizer([t.ndv([c]) for c in cols], t.nrows)
            errs["AE"].append(abs(ae / true - 1))
            errs["Multiply"].append(abs(mult / true - 1))
            errs["Optimizer"].append(abs(opt / true - 1))
    rows = [{"method": k, "avg_error_pct": 100 * float(np.mean(v))}
            for k, v in errs.items()]
    derived = (f"AE={rows[2]['avg_error_pct']:.0f}%_vs_"
               f"Mult={rows[1]['avg_error_pct']:.0f}%")
    return rows, derived


# ---------------------------------------------------------------------------
def table4_graph_quality(e=0.5, q=0.9) -> Tuple:
    """Table 4: estimation cost of Greedy vs All vs Optimal across f.

    Paper: Greedy 2-6x cheaper than All, within ~8% of Optimal."""
    schema = make_tpch_like(scale=1.0, z=0, seed=0)
    cols = ("l_shipdate", "l_returnflag", "l_extendedprice", "l_quantity",
            "l_discount")
    targets = []
    for i in range(1, len(cols) + 1):
        targets.append(NodeKey("lineitem", cols[:i], "NS"))
        targets.append(NodeKey("lineitem", cols[:i], "LDICT"))
    planner = EstimationPlanner(schema.tables)
    li = schema.tables["lineitem"]
    rows = []
    ratios = {}
    for e_i in (e, 1.0):   # paper: looser e => deductions win by up to 50x
        ratios[e_i] = []
        for f in (0.01, 0.025, 0.05, 0.075, 0.10):
            all_cost = sum(sampling_cost(li, t, f) for t in targets)
            g = planner.greedy(targets, f, e_i, q)
            try:
                o = planner.optimal(targets[:8], f, e_i, q)
                g8 = planner.greedy(targets[:8], f, e_i, q)
                opt_ratio = g8.total_cost / max(o.total_cost, 1e-9)
            except ValueError:
                opt_ratio = float("nan")
            rows.append({"e": e_i, "f": f, "All": all_cost,
                         "Greedy": g.total_cost,
                         "Greedy_vs_Optimal": round(opt_ratio, 3)})
            ratios[e_i].append(all_cost / max(g.total_cost, 1e-9))
    derived = (f"greedy_{min(ratios[e]):.1f}-{max(ratios[e]):.1f}x(e={e})_"
               f"{min(ratios[1.0]):.1f}-{max(ratios[1.0]):.1f}x(e=1.0)"
               "_cheaper_than_All")
    return rows, derived


# ---------------------------------------------------------------------------
def fig9_samplecf_errors(seeds=(0, 1, 2, 3)) -> Tuple:
    """Fig 9 + Table 2: SampleCF bias/std vs f for ORD-IND and ORD-DEP."""
    schema = make_tpch_like(scale=1.0, z=0, seed=0)
    li = schema.tables["lineitem"]
    idx_sets = [("l_shipdate",), ("l_shipdate", "l_returnflag"),
                ("l_quantity", "l_discount"), ("l_shipmode", "l_shipdate"),
                ("l_shipdate", "l_returnflag", "l_extendedprice")]
    rows = []
    for m in ("NS", "LDICT"):
        for f in (0.01, 0.05, 0.10):
            errs = []
            for cols in idx_sets:
                idx = IndexDef("lineitem", cols, compression=m)
                _, true = full_index_sizes(li, idx)
                for seed in seeds:
                    mgr = SampleManager(schema.tables, seed=seed)
                    est = sample_cf(mgr, idx, f)
                    errs.append(est.est_bytes / true - 1)
            rows.append({"method": m, "f": f,
                         "bias": float(np.mean(errs)),
                         "std": float(np.std(errs))})
    ns_bias = max(abs(r["bias"]) for r in rows if r["method"] == "NS")
    derived = f"NS_unbiased(max_bias={ns_bias:.4f})"
    return rows, derived


# ---------------------------------------------------------------------------
def fig10_deduction_errors() -> Tuple:
    """Fig 10 + Table 3: ColExt error vs number of extrapolated indexes."""
    from repro.core import deduction as D
    schema = make_tpch_like(scale=1.0, z=0, seed=0)
    li = schema.tables["lineitem"]
    col_pool = ("l_shipdate", "l_returnflag", "l_quantity", "l_discount",
                "l_shipmode")
    rows = []
    for m in ("NS", "LDICT"):
        for a in (2, 3, 4):
            errs = []
            for start in range(len(col_pool) - a + 1):
                cols = col_pool[start:start + a]
                parts = []
                for c in cols:
                    _, sc = full_index_sizes(
                        li, IndexDef("lineitem", (c,), compression=m))
                    parts.append(((c,), float(sc)))
                est = D.deduce(li, m, cols, parts)
                _, true = full_index_sizes(
                    li, IndexDef("lineitem", cols, compression=m))
                errs.append(est / true - 1)
            rows.append({"method": m, "a": a, "bias": float(np.mean(errs)),
                         "std": float(np.std(errs))})
    growth = [r["bias"] for r in rows if r["method"] == "LDICT"]
    derived = f"colext_bias_grows_with_a({growth[0]:+.3f}->{growth[-1]:+.3f})"
    return rows, derived


# ---------------------------------------------------------------------------
def fig11_estimation_runtime() -> Tuple:
    """Fig 11: DTAc runtime with vs without deductions.

    Paper: deduction cuts size-estimation overhead ~3x (dominant -> modest).
    """
    schema = make_tpch_like(scale=2.0, z=0, seed=0)
    wl = make_tpch_workload(schema, insert_weight=0.1)
    out = {}
    for use_ded in (True, False):
        adv = DesignAdvisor(wl, AdvisorOptions(use_deduction=use_ded))
        t0 = time.perf_counter()
        cands = adv.generate_candidates()
        cost_pages, _, n_s, n_d = adv.estimate_sizes(cands)
        wall = time.perf_counter() - t0
        out[use_ded] = {"wall_s": wall, "cost_pages": cost_pages,
                        "sampled": n_s, "deduced": n_d}
    rows = [{"deduction": k, **v} for k, v in out.items()]
    speedup = out[False]["cost_pages"] / max(out[True]["cost_pages"], 1e-9)
    derived = f"deduction_cuts_est_cost_{speedup:.1f}x"
    return rows, derived


# ---------------------------------------------------------------------------
def figs12_17_design_quality(scale=1.0) -> Tuple:
    """Figs 12-17: improvement vs space budget for DTA / DTAc / ablations /
    staged, SELECT- and INSERT-intensive.

    Paper: DTAc ~2x better in tight budgets; Skyline+Backtrack both needed;
    INSERT-intensive avoids over-compression."""
    schema = make_tpch_like(scale=scale, z=0, seed=0)
    rows = []
    variants = {
        "DTA": AdvisorOptions.dta(),
        "DTAc(None)": AdvisorOptions(candidate_mode="topk",
                                     enumeration="pure"),
        "Skyline": AdvisorOptions(candidate_mode="skyline",
                                  enumeration="pure"),
        "Backtrack": AdvisorOptions(candidate_mode="topk",
                                    enumeration="backtrack"),
        "DTAc(Both)": AdvisorOptions.dtac(),
    }
    derived_bits = []
    for wname, iw in (("SELECT", 0.1), ("INSERT", 20.0)):
        wl = make_tpch_workload(schema, insert_weight=iw)
        base_size = sum(DesignAdvisor(wl).sizes.size(i)
                        for i in base_configuration(schema).indexes)
        for frac in (0.1, 0.25, 0.5, 1.0):
            budget = frac * base_size
            for name, opts in variants.items():
                rec = DesignAdvisor(wl, opts).recommend(budget)
                rows.append({"workload": wname, "budget_frac": frac,
                             "variant": name,
                             "improvement_pct": 100 * rec.improvement,
                             "n_compressed": sum(
                                 1 for i in rec.config.indexes
                                 if i.compression)})
            st = staged_recommend(wl, budget)
            rows.append({"workload": wname, "budget_frac": frac,
                         "variant": "Staged",
                         "improvement_pct": 100 * st.improvement,
                         "n_compressed": sum(1 for i in st.config.indexes
                                             if i.compression)})
    sel_tight = {r["variant"]: r["improvement_pct"] for r in rows
                 if r["workload"] == "SELECT" and r["budget_frac"] == 0.25}
    derived = (f"tight_budget_DTAc={sel_tight['DTAc(Both)']:.0f}%"
               f"_vs_DTA={sel_tight['DTA']:.0f}%")
    return rows, derived


# ---------------------------------------------------------------------------
def tpu_layout_advisor() -> Tuple:
    """The adaptation benchmark: LayoutPlan choices across job types."""
    from repro.configs import get_config
    from repro.design import plan_layout
    from repro.models.config import pad_for_tp
    rows = []
    cases = [
        ("jamba-1.5-large-398b", "serve", 128, 32768, 16e9, "mem-bound"),
        ("jamba-1.5-large-398b", "train", 256, 4096, 100e9, "loose"),
        ("jamba-1.5-large-398b", "train", 256, 4096, 10e9, "tight"),
        ("tinyllama-1.1b", "train", 256, 4096, 16e9, "small-model"),
    ]
    for arch, kind, b, s, budget, label in cases:
        cfg = pad_for_tp(get_config(arch), 16)
        flops = (6.0 if kind == "train" else 2.0) * cfg.param_count() \
            * (b * s if kind != "serve" else b) / 256
        plan = plan_layout(cfg, kind, b, s, 256, budget,
                           base_flops_per_chip=flops)
        rows.append({"case": f"{arch}/{kind}/{label}",
                     "choices": str(plan.choices),
                     "hbm_gb": plan.hbm_bytes / 1e9})
    derived = "advisor_compresses_only_when_bound"
    return rows, derived


# ---------------------------------------------------------------------------
def workload_compression_quality(n_statements=2000, scale=0.3,
                                 budgets=(32, 64, 128)) -> Tuple:
    """Quality-vs-compression tradeoff of the workload-compression layer:
    for each representative budget, the recommendation's true full-workload
    cost (chunked, never materializing the dense statement matrix) and the
    certified error bound."""
    from repro.core import (chunked_config_costs, make_scaled_workload)
    from repro.core.workload_compression import ClusterIndex

    schema = make_tpch_like(scale=scale, z=0, seed=0)
    wl = make_scaled_workload(schema, n_statements=n_statements, seed=0)
    base = base_configuration(schema)
    budget_bytes = 0.3 * sum(
        DesignAdvisor(wl).sizes.size(i) for i in base.indexes)
    ix = ClusterIndex.from_workload(wl)
    rows: List[Dict] = []
    for b in budgets:
        comp = ix.derive(b)
        adv = DesignAdvisor(comp.workload)
        rec = adv.recommend(budget_bytes)
        true_cost = float(chunked_config_costs(
            wl, adv.sizes, [rec.config])[0])
        eps = comp.error_bound(rec.config, adv.sizes)
        assert abs(true_cost - rec.cost) <= eps + 1e-9 * abs(true_cost)
        rows.append({"budget": b,
                     "n_representatives": comp.n_representatives,
                     "compression_ratio": round(comp.compression_ratio, 1),
                     "true_full_cost": round(true_cost, 2),
                     "bound_rel": round(
                         eps / max(abs(true_cost), 1e-12), 3)})
    derived = "bound_holds_and_tightens_with_budget"
    return rows, derived

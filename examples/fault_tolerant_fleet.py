"""Fault-tolerant fleet walkthrough: crash a tenant, watch the circuit
breaker quarantine it, and see checkpoint restore bring it back with a
bit-identical recommendation.

Three acts:

1. **Transient faults retry themselves.**  A seeded `FaultInjector`
   makes the tenant's first delta fail with a transient `FaultError`;
   the fleet requeues it with deterministic step backoff and the retry
   applies bit-exactly (faulted calls fail BEFORE mutating the session).
2. **Crash, quarantine, restore.**  `crash_tenant` drops a tenant's
   live session mid-flight.  Queued tickets resolve with
   `TenantQuarantined`, submits are rejected — and `readmit_tenant`
   rebuilds the session from its last checkpoint (taken after every
   successful delta), after which its recommendation is exactly the one
   a fresh `DesignAdvisor` produces on its current workload.
3. **Deadline pressure degrades gracefully.**  A recommend that would
   outlive its step deadline is served immediately at a smaller
   workload-compression budget instead of failing — still an exact
   advisor run, with the compression error certificate attached.
4. **Kill the process, recover the fleet.**  The acts above survive
   in-memory session loss; this one survives the process itself.  With
   `store=DurableStore(dir)` every delta is journaled to a per-tenant
   write-ahead log before it is applied and periodically compacted into
   an atomic snapshot.  We drop every live object — the only survivor
   is the directory — scribble a torn tail onto one WAL for good
   measure, and `AdvisorFleetService.recover(dir)` rebuilds both
   tenants with recommendations bit-identical to a fresh
   `DesignAdvisor` on their pre-death workloads.

    PYTHONPATH=src python examples/fault_tolerant_fleet.py
"""
import dataclasses
import tempfile
from pathlib import Path

from repro.core import (AdvisorOptions, DesignAdvisor, DurableStore,
                        FaultInjector, FaultSpec, WorkloadDelta,
                        make_scaled_workload, make_tpch_like)
from repro.serve.advisor_service import (AdvisorFleetService, FleetConfig,
                                         TenantQuarantined)

BUDGET = 2_000_000


def tenant_workload(schema, tid, n=12, seed=0):
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def main():
    schema = make_tpch_like(scale=0.1, seed=0)
    opt = AdvisorOptions.dtac()
    faults = FaultInjector(seed=0, specs={
        "apply_delta": FaultSpec(at=(0,))})   # script act 1's fault
    fleet = AdvisorFleetService(
        FleetConfig(slots=2, degraded_budget=5), faults=faults)

    wls = {}
    for i in range(2):
        tid = f"shop{i}"
        wls[tid] = tenant_workload(schema, tid, seed=10 + i)
        fleet.register_tenant(tid, wls[tid], opt)

    # -- act 1: a transient fault, retried to an exact result ----------
    delta = WorkloadDelta(removed=(wls["shop0"].statements[0].name,))
    tk = fleet.submit_delta("shop0", delta)
    fleet.run_until_drained()
    wls["shop0"] = wls["shop0"].apply_delta(delta)
    print(f"act 1: delta applied after {tk.attempts} attempts "
          f"(retries={fleet.stats['retries']})")

    # -- act 2: crash, quarantine, checkpoint restore ------------------
    fleet.crash_tenant("shop0")
    try:
        fleet.submit_recommend("shop0", BUDGET)
    except TenantQuarantined as e:
        print(f"act 2: quarantined -> {e}")
    fleet.readmit_tenant("shop0")             # restore from checkpoint
    rk = fleet.submit_recommend("shop0", BUDGET)
    fleet.run_until_drained()
    rec = rk.result()
    fresh = DesignAdvisor(wls["shop0"], opt).recommend(BUDGET)
    assert (rec.config == fresh.config and rec.cost == fresh.cost
            and rec.used_bytes == fresh.used_bytes)
    print(f"act 2: restored in {fleet.restore_seconds[-1] * 1e3:.2f} ms; "
          f"post-restore recommendation == fresh DesignAdvisor "
          f"(cost {rec.cost:.1f}, {len(rec.config.indexes)} indexes)")

    # -- act 3: deadline pressure -> degraded-but-exact ----------------
    fleet.submit_recommend("shop0", BUDGET)   # hogs one of the few slots
    fleet.submit_recommend("shop1", BUDGET)
    late = fleet.submit_recommend("shop1", BUDGET, deadline_steps=1)
    fleet.run_until_drained()
    rec = late.result()
    print(f"act 3: degraded={late.degraded}; advised on "
          f"{rec.n_representatives}/{rec.n_statements_full} "
          f"representatives, certified cost error "
          f"<= {rec.compression_error_bound:.3f}")

    s = fleet.stats
    print(f"fleet: retries={s['retries']} quarantines={s['quarantines']} "
          f"restores={s['restores']} degraded={s['degraded_recommends']} "
          f"timeouts={s['timeouts']}")

    # -- act 4: kill the process, recover the fleet from disk ----------
    with tempfile.TemporaryDirectory(prefix="fleet_store_") as d:
        store = DurableStore(d, group_commit=2, compact_after=8)
        durable = AdvisorFleetService(FleetConfig(slots=2), store=store)
        for tid, wl in wls.items():
            durable.register_tenant(tid, wl, opt)
        extra = tenant_workload(schema, "extra", n=4, seed=99).statements
        for j, stmt in enumerate(extra):
            durable.submit_delta("shop0" if j % 2 else "shop1",
                                 WorkloadDelta(added=(stmt,)))
        durable.run_until_drained()
        mirror = {
            tid: durable.tenants[tid].session.workload for tid in wls}
        store.close()
        del durable, store            # "process death": nothing in
        #                               memory survives past this line
        with open(Path(d) / "wal" / "shop0.wal", "ab") as f:
            f.write(b"DWAL" + b"\xff" * 9)   # a torn final append
        recovered = AdvisorFleetService.recover(d)
        assert not recovered.recovery_errors
        for tid in wls:
            rk = recovered.submit_recommend(tid, BUDGET)
            recovered.run_until_drained()
            rec, ref = rk.result(), DesignAdvisor(
                mirror[tid], opt).recommend(BUDGET)
            assert (rec.config == ref.config and rec.cost == ref.cost
                    and rec.used_bytes == ref.used_bytes)
        rs = recovered.stats
        print(f"act 4: recovered {rs['tenants']} tenants from disk "
              f"(wal replay + snapshots; torn tails truncated="
              f"{rs['torn_tail_truncations']}); every post-restart "
              f"recommendation == fresh DesignAdvisor")


if __name__ == "__main__":
    main()

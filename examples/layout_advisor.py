"""The paper's technique as a framework feature: ask the tensor physical-
design advisor for a layout plan per (arch x job x HBM budget).

    PYTHONPATH=src python examples/layout_advisor.py --arch jamba-1.5-large-398b
"""
import argparse

from repro.configs import ARCHS, get_config
from repro.design import plan_layout
from repro.models.config import pad_for_tp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-1.5-large-398b", choices=ARCHS)
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()
    cfg = pad_for_tp(get_config(args.arch), 16)
    print(f"{cfg.name}: {cfg.param_count()/1e9:.1f}B params on "
          f"{args.chips} chips")
    for kind, b, s in (("train", 256, 4096), ("serve", 128, 32768)):
        flops = (6.0 if kind == "train" else 2.0) * cfg.param_count() \
            * (b * s if kind == "train" else b) / args.chips
        for budget in (8e9, 16e9, 64e9):
            plan = plan_layout(cfg, kind, b, s, args.chips, budget,
                               base_flops_per_chip=flops)
            fit = "fits" if plan.hbm_bytes <= budget else "INFEASIBLE"
            print(f"  {kind:5s} @ {budget/1e9:4.0f}GB: {plan.choices} "
                  f"-> {plan.hbm_bytes/1e9:5.1f}GB ({fit})")


if __name__ == "__main__":
    main()

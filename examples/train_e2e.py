"""End-to-end training driver with the compression advisor in the loop.

    PYTHONPATH=src python examples/train_e2e.py                # fast preset
    PYTHONPATH=src python examples/train_e2e.py --preset 100m  # ~100M params

The advisor (the paper's technique) picks the physical layout (optimizer-
moment codec, gradient wire codec) from the HBM budget; the trainer
checkpoints atomically and auto-resumes if re-run.
"""
import argparse

from repro.models.config import ModelConfig
from repro.train.loop import TrainConfig, Trainer

PRESETS = {
    # ~2M params: a couple of minutes on CPU
    "fast": (ModelConfig("fast-lm", "dense", 4, 128, 4, 2, 512, 512,
                         d_head=32), TrainConfig(
        steps=120, batch=8, seq=64, lr=3e-3, checkpoint_every=50,
        checkpoint_dir="/tmp/repro_ckpt_fast", log_every=20)),
    # ~100M params, a few hundred steps (the deliverable driver; slow on CPU)
    "100m": (ModelConfig("lm-100m", "dense", 12, 768, 12, 4, 2048, 32000,
                         d_head=64), TrainConfig(
        steps=300, batch=8, seq=256, lr=6e-4, checkpoint_every=100,
        checkpoint_dir="/tmp/repro_ckpt_100m", log_every=10)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="fast", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    cfg, tc = PRESETS[args.preset]
    if args.steps:
        tc.steps = args.steps
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    trainer = Trainer(cfg, tc)
    if trainer.plan:
        print("advisor layout plan:", trainer.plan.choices)
    out = trainer.run()
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"over {tc.steps} steps; stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's pipeline end-to-end on a mini TPC-H.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (AdvisorOptions, DesignAdvisor, IndexDef, NodeKey,
                        SampleManager, base_configuration, make_tpch_like,
                        make_tpch_workload, sample_cf)
from repro.core.estimation_graph import EstimationPlanner
from repro.core.samplecf import full_index_sizes


def main():
    schema = make_tpch_like(scale=0.5, z=0, seed=0)
    li = schema.tables["lineitem"]

    # 1. SampleCF: estimate a compressed index size from a 5% sample
    mgr = SampleManager(schema.tables, seed=0)
    idx = IndexDef("lineitem", ("l_shipdate", "l_returnflag"),
                   compression="LDICT")
    est = sample_cf(mgr, idx, f=0.05)
    _, true = full_index_sizes(li, idx)
    print(f"SampleCF: est {est.est_bytes/1e3:.0f}KB vs true {true/1e3:.0f}KB "
          f"(err {est.est_bytes/true-1:+.1%}, cost {est.cost_pages:.0f} pages)")

    # 2. Estimation plan (§5): deduce what you can, sample what you must
    targets = [NodeKey("lineitem", ("l_shipdate",), "NS"),
               NodeKey("lineitem", ("l_extendedprice",), "NS"),
               NodeKey("lineitem", ("l_shipdate", "l_extendedprice"), "NS")]
    planner = EstimationPlanner(schema.tables)
    plan = planner.plan(targets, e=0.5, q=0.9)
    print(f"Estimation plan: f={plan.f}, {plan.n_sampled()} sampled, "
          f"{plan.n_deduced()} deduced, cost {plan.total_cost:.0f} pages")

    # 3. Full advisor (DTAc): compression-aware design under a budget
    wl = make_tpch_workload(schema, insert_weight=0.1)
    base_size = sum(DesignAdvisor(wl).sizes.size(i)
                    for i in base_configuration(schema).indexes)
    rec = DesignAdvisor(wl, AdvisorOptions.dtac()).recommend(0.25 * base_size)
    print(f"DTAc @25% budget: {rec.improvement:.1%} improvement, "
          f"{len(rec.config.indexes)-len(schema.tables)} indexes "
          f"({sum(1 for i in rec.config.indexes if i.compression)} compressed)")
    for s in rec.steps[:5]:
        print("   ", s)


if __name__ == "__main__":
    main()

"""Online advisor session: continuous retuning under a drifting workload.

A production advisor does not get called once — the workload drifts
(dashboards come and go, ETL weights shift) and the tool must re-advise
continuously.  This example drives `repro.core.session.AdvisorSession`
through a drifting TPC-H-like workload and prints, per drift round, the
re-advise latency, what a from-scratch `DesignAdvisor` would have cost,
and the estimated runtime improvement of the recommended design.

Run:
    PYTHONPATH=src python examples/online_advisor.py
"""
import dataclasses
import time

import numpy as np

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        WorkloadDelta, base_configuration,
                        make_scaled_workload, make_tpch_like)


def main() -> None:
    schema = make_tpch_like(scale=0.3, z=0, seed=0)
    workload = make_scaled_workload(schema, n_statements=120, seed=0)
    base_size = sum(DesignAdvisor(workload).sizes.size(i)
                    for i in base_configuration(schema).indexes)
    budget = 0.25 * base_size

    session = AdvisorSession(workload, AdvisorOptions.dtac())
    t0 = time.perf_counter()
    rec = session.recommend(budget)
    print(f"cold build: {time.perf_counter() - t0:.2f}s  "
          f"improvement {rec.improvement:.1%}  "
          f"indexes {len(rec.config.indexes)}")

    # a pool of fresh statements to drift in
    drift = [dataclasses.replace(s, name=f"new{i:03d}") for i, s in
             enumerate(make_scaled_workload(schema, n_statements=120,
                                            seed=42).statements)]
    rng = np.random.default_rng(1)
    wl_cur = workload
    k = 0
    for rnd in range(6):
        names = [s.name for s in wl_cur.statements]
        if rnd % 2 == 0:   # churn round: statements enter and leave
            removed = tuple(rng.choice(names, size=3, replace=False))
            added = tuple(drift[k:k + 3])
            k += 3
        else:              # reweight round: the mix shifts
            removed, added = (), ()
        survivors = [n for n in names if n not in set(removed)]
        reweighted = tuple(
            (n, float(rng.uniform(0.5, 2.0)))
            for n in rng.choice(survivors, size=6, replace=False))
        delta = WorkloadDelta(added=added, removed=removed,
                              reweighted=reweighted)
        wl_cur = wl_cur.apply_delta(delta)

        t0 = time.perf_counter()
        session.apply(delta)
        rec = session.recommend(budget)
        t_session = time.perf_counter() - t0

        t0 = time.perf_counter()
        fresh = DesignAdvisor(wl_cur, AdvisorOptions.dtac()).recommend(
            budget)
        t_fresh = time.perf_counter() - t0
        tag = "churn   " if added else "reweight"
        match = "ok" if (rec.config == fresh.config
                         and rec.cost == fresh.cost) else "DIVERGED"
        print(f"round {rnd} [{tag}]  session {t_session * 1000:6.0f}ms  "
              f"fresh {t_fresh * 1000:6.0f}ms  "
              f"({t_fresh / t_session:4.1f}x)  "
              f"improvement {rec.improvement:.1%}  parity {match}")

    stats = session.stats
    print(f"\nsession stats after {stats['rounds']} rounds: "
          f"{stats['replay_hits']} decisions replayed, "
          f"{stats['replay_verified']} verified after group deltas, "
          f"{stats['replay_misses']} re-scored; "
          f"{stats['samplecf_cache_hits']} SampleCF cache hits, "
          f"{stats['selection_hits']} per-query selections reused")


if __name__ == "__main__":
    main()

"""Batched serving demo: continuous batching with per-slot KV positions.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    cfg = ModelConfig("serve-demo", "dense", 2, 64, 4, 2, 128, 256, d_head=16)
    params = MD.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ServeEngine(cfg, params, EngineConfig(batch_slots=3, max_len=64))
    prompts = [[1, 2, 3], [10, 20], [7, 7, 7, 7], [42], [5, 4, 3, 2, 1],
               [99, 98], [11, 12, 13]]
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    eng.run_until_drained()
    print(f"served {len(eng.finished)} requests in {eng.steps} engine steps "
          f"on {eng.ec.batch_slots} slots")
    for uid in sorted(eng.finished):
        r = eng.finished[uid]
        print(f"  req {uid}: prompt {r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()

"""Batched serving demo: continuous batching with per-slot KV positions.

Demonstrates the repaired engine semantics: requests admitted MID-FLIGHT
(while other slots are decoding) leave in-flight outputs untouched —
prefill is slot-isolated via the `active` mask on `decode_step` — and
slots retire on EOS (`EngineConfig.eos_id`) as well as on
`max_new_tokens` and context overflow.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    cfg = ModelConfig("serve-demo", "dense", 2, 64, 4, 2, 128, 256, d_head=16)
    params = MD.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)

    eng = ServeEngine(cfg, params, EngineConfig(batch_slots=3, max_len=64))
    prompts = [[1, 2, 3], [10, 20], [7, 7, 7, 7], [42], [5, 4, 3, 2, 1],
               [99, 98], [11, 12, 13]]
    # staggered submission: each step admits newcomers into free slots
    # while earlier requests keep decoding — slot isolation guarantees
    # the interleaving is invisible to every request's outputs
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
        eng.step()
    eng.run_until_drained()
    print(f"served {len(eng.finished)} requests in {eng.steps} engine steps "
          f"on {eng.ec.batch_slots} slots (admissions interleaved)")
    for uid in sorted(eng.finished):
        r = eng.finished[uid]
        print(f"  req {uid}: prompt {r.prompt} -> {r.out_tokens}")

    # EOS retirement: pick a token request 0 emitted and rerun with it
    # as the stop token — the request retires early, done and untruncated
    eos = eng.finished[0].out_tokens[2]
    eng2 = ServeEngine(cfg, params, EngineConfig(batch_slots=3, max_len=64,
                                                 eos_id=eos))
    eng2.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=6))
    eng2.run_until_drained()
    r = eng2.finished[0]
    print(f"with eos_id={eos}: req 0 -> {r.out_tokens} "
          f"(stopped at EOS, truncated={r.truncated})")


if __name__ == "__main__":
    main()

"""Workload compression: advising on 10k+ statements in sub-second time.

Production workload traces are orders of magnitude bigger than the 200
statements the paper's experiments use.  `AdvisorOptions.compression_budget`
makes the advisor cluster statements by signature into a few weighted
representatives (repro.core.workload_compression), recommend on those,
and attach a certified cost-error bound to the result — while the exact
workload cost of the chosen design stays computable via
`chunked_config_costs` without ever materializing the dense
statements x candidates matrix.

Three things are demonstrated:
  1. compressed recommend at 10k statements, with the error certificate
     checked against the true full-workload cost,
  2. the exact-parity contract — budget None (or >= n) is bit-identical
     to the plain uncompressed advisor,
  3. a long-lived `AdvisorSession` in compressed mode: drift deltas fold
     into the cluster index incrementally, and pure reweights that keep
     the representative set take a rebuild-free fast path.

Run:
    PYTHONPATH=src python examples/scaled_workloads.py
"""
import dataclasses
import time

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        WorkloadDelta, base_configuration,
                        chunked_config_costs, make_scaled_workload,
                        make_tpch_like)


def main() -> None:
    schema = make_tpch_like(scale=0.3, z=0, seed=0)
    wl = make_scaled_workload(schema, n_statements=10_000, seed=0)
    budget = 0.3 * sum(DesignAdvisor(wl).sizes.size(i)
                       for i in base_configuration(schema).indexes)

    # 1. compressed recommend + certified error bound
    opts = AdvisorOptions(compression_budget=128)
    t0 = time.perf_counter()
    adv = DesignAdvisor(wl, opts)
    rec = adv.recommend(budget)
    wall = time.perf_counter() - t0
    true_cost = float(chunked_config_costs(
        wl, adv.inner.sizes, [rec.config])[0])
    print(f"compressed: {rec.n_statements_full} statements -> "
          f"{rec.n_representatives} representatives in {wall:.2f}s")
    print(f"  compressed cost {rec.cost:.1f}  true cost {true_cost:.1f}  "
          f"certified bound {rec.compression_error_bound:.1f} "
          f"({rec.compression_error_rel:.1%} rel)")
    assert abs(true_cost - rec.cost) <= rec.compression_error_bound + 1e-9

    # 2. exact-parity contract on a small slice
    wl_small = make_scaled_workload(schema, n_statements=200, seed=0)
    rec_full = DesignAdvisor(wl_small).recommend(budget)
    rec_off = DesignAdvisor(wl_small, AdvisorOptions(
        compression_budget=None)).recommend(budget)
    rec_big = DesignAdvisor(wl_small, AdvisorOptions(
        compression_budget=10 ** 9)).recommend(budget)
    assert (rec_off.config, rec_off.cost) == (rec_full.config, rec_full.cost)
    assert (rec_big.config, rec_big.cost) == (rec_full.config, rec_full.cost)
    print("exact parity: budget None / >= n match the plain advisor "
          "bit-for-bit")

    # 3. compressed session under drift
    session = AdvisorSession(wl, opts)
    session.recommend(budget)
    names = [s.name for s in wl.statements[:4]]
    session.apply(WorkloadDelta(
        reweighted=tuple((n, 1.0001) for n in names)))   # tiny reweight
    session.recommend(budget)
    extra = make_scaled_workload(schema, n_statements=10, seed=99)
    session.apply(WorkloadDelta(added=tuple(
        dataclasses.replace(s, name=f"drift{i}")
        for i, s in enumerate(extra.statements[:5]))))   # structural drift
    session.recommend(budget)
    st = session.stats
    print(f"session: {st['rounds']} rounds, "
          f"{st['compression_rebuilds']} rebuilds, "
          f"{st['compression_reweights']} reweight fast paths, "
          f"{st['compression_bypasses']} bypasses")


if __name__ == "__main__":
    main()

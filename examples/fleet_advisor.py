"""Fleet advisor quickstart: many tenants, one batched service.

Registers a handful of tenants — most sharing one schema, one on its
own — submits interleaved workload deltas and recommend calls through
the fleet's request queue, and shows the two things the service is for:

* every tenant's recommendation is exactly the one a dedicated
  `DesignAdvisor` would produce on that tenant's current workload, and
* tenants on a common schema amortize sampling and SampleCF estimation
  through the shared per-group cache and the cross-tenant batched
  prefetch.

    PYTHONPATH=src python examples/fleet_advisor.py
"""
import dataclasses

from repro.core import (AdvisorOptions, DesignAdvisor, WorkloadDelta,
                        make_scaled_workload, make_tpch_like)
from repro.serve.advisor_service import (AdvisorFleetService, FleetConfig,
                                         TenantBudget)

BUDGET = 2_000_000


def tenant_workload(schema, tid, n=14, seed=0):
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def main():
    shared_schema = make_tpch_like(scale=0.1, seed=0)
    other_schema = make_tpch_like(scale=0.1, seed=9)
    opt = AdvisorOptions.dtac()

    fleet = AdvisorFleetService(FleetConfig(slots=4))
    wls = {}
    for i in range(4):                      # four tenants, one schema
        tid = f"shop{i}"
        wls[tid] = tenant_workload(shared_schema, tid, seed=10 + i)
        fleet.register_tenant(tid, wls[tid], opt,
                              TenantBudget(max_statements=50))
    wls["solo"] = tenant_workload(other_schema, "solo", seed=99)
    fleet.register_tenant("solo", wls["solo"], opt)

    # interleaved traffic: every tenant drops two statements, then asks
    # for a fresh recommendation; the fleet batches the estimation work
    tickets = {}
    for tid, wl in wls.items():
        delta = WorkloadDelta(removed=(wl.statements[0].name,
                                       wl.statements[1].name))
        fleet.submit_delta(tid, delta)
        wls[tid] = wl.apply_delta(delta)
        tickets[tid] = fleet.submit_recommend(tid, BUDGET)
    fleet.run_until_drained()

    for tid, tk in tickets.items():
        rec = tk.result()
        fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
        exact = (rec.config == fresh.config and rec.cost == fresh.cost
                 and rec.used_bytes == fresh.used_bytes)
        print(f"  {tid}: cost {rec.cost:12.1f}  "
              f"latency {tk.latency * 1e3:6.1f}ms  "
              f"== fresh advisor: {exact}")
        assert exact

    s = fleet.stats
    print(f"\n{s['tenants']} tenants in {s['groups']} share groups, "
          f"{s['retired']} requests over {s['steps']} steps")
    print(f"cross-tenant prefetch: {s['prefetch_targets']} targets sized "
          f"in {s['prefetch_batches']} batches, "
          f"{s['prefetch_hits']} served from the shared cache; "
          f"{s['sampling_calls']} sample draws total")
    print(f"shop0 per-session SampleCF misses: "
          f"{fleet.tenant_stats('shop0')['samplecf_cache_misses']} "
          f"(estimation came from the shared cache)")


if __name__ == "__main__":
    main()

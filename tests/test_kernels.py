"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes / dtypes / blocks, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES_2D = [(8, 128), (32, 256), (256, 512), (64, 384), (128, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]
BLOCKS = [64, 128]


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES_2D)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("block", BLOCKS)
    def test_matches_oracle(self, shape, dtype, block):
        x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
        q_k, s_k = ops.quantize_blockwise(x, block=block)
        q_r, s_r = ref.quantize_blockwise(x, block=block)
        # scales may differ by an ULP across implementations, which can flip
        # a round-half boundary: allow |dq| <= 1 at <=0.1% of positions.
        dq = np.abs(np.asarray(q_k, np.int32) - np.asarray(q_r, np.int32))
        assert dq.max() <= 1
        assert (dq != 0).mean() <= 1e-3
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-5)

    @pytest.mark.parametrize("shape", [(4, 2, 96), (3, 5, 7, 130), (1, 128)])
    def test_arbitrary_rank_and_ragged_last_dim(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(1), shape)
        q, s = ops.quantize_blockwise(x, block=64)
        q_r, s_r = ref.quantize_blockwise(x, block=64)
        assert q.shape == x.shape
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=1e-6)

    def test_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 256)) * 5
        q, s = ops.quantize_blockwise(x)
        out = ops.dequantize_blockwise(q, s)
        # int8 blockwise: error <= scale/2 = absmax/254 per block
        err = np.abs(np.asarray(out - x))
        bound = np.repeat(np.asarray(s), 128, axis=-1)[:, :256] * 0.5 + 1e-6
        assert (err <= bound).all()

    @given(st.integers(0, 10), st.sampled_from([64, 128]))
    @settings(max_examples=10, deadline=None)
    def test_property_idempotent_roundtrip(self, seed, block):
        """quantize(dequantize(quantize(x))) == quantize(x) (fixpoint)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 256))
        q1, s1 = ops.quantize_blockwise(x, block=block)
        x1 = ops.dequantize_blockwise(q1, s1, block=block)
        q2, s2 = ops.quantize_blockwise(x1, block=block)
        x2 = ops.dequantize_blockwise(q2, s2, block=block)
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=1e-5, atol=1e-6)

    def test_zero_input(self):
        q, s = ops.quantize_blockwise(jnp.zeros((8, 128)))
        assert (np.asarray(q) == 0).all()
        out = ops.dequantize_blockwise(q, s)
        assert (np.asarray(out) == 0).all()


class TestDequantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES_2D)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        x = jax.random.normal(jax.random.PRNGKey(3), shape) * 2
        q, s = ref.quantize_blockwise(x)
        out_k = ops.dequantize_blockwise(q, s, dtype=dtype)
        out_r = ref.dequantize_blockwise(q, s, dtype=dtype)
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=1e-6)


class TestDequantMatmul:
    @pytest.mark.parametrize("m,k,n", [(8, 128, 128), (32, 256, 256),
                                       (16, 384, 128), (64, 512, 256)])
    @pytest.mark.parametrize("block", [128])
    def test_matches_oracle(self, m, k, n, block):
        a = jax.random.normal(jax.random.PRNGKey(4), (m, k))
        w = jax.random.normal(jax.random.PRNGKey(5), (k, n))
        qw, s_row = ref.quantize_blockwise(w.T, block=block)  # (N, K)->(N,K/b)
        # convert to (K, N) int8 + (K/block, N) scales layout
        qw = qw.T
        scales = s_row.T
        out_k = ops.dequant_matmul(a, qw, scales, block=block)
        out_r = ref.dequant_matmul(a, qw, scales, block=block)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-4, atol=1e-4)

    def test_close_to_full_precision(self):
        a = jax.random.normal(jax.random.PRNGKey(6), (32, 256))
        w = jax.random.normal(jax.random.PRNGKey(7), (256, 128))
        qw, s_row = ref.quantize_blockwise(w.T)
        out = ops.dequant_matmul(a, qw.T, s_row.T)
        exact = np.asarray(a @ w)
        rel = np.abs(np.asarray(out) - exact) / (np.abs(exact) + 1e-3)
        assert np.median(rel) < 0.02  # int8 ~ 2 decimal digits

    @given(st.integers(0, 5))
    @settings(max_examples=5, deadline=None)
    def test_property_linearity(self, seed):
        """dequant_matmul(a1+a2, w) == dequant_matmul(a1,w)+dequant(a2,w)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a1 = jax.random.normal(k1, (8, 128))
        a2 = jax.random.normal(k2, (8, 128))
        w = jax.random.normal(jax.random.PRNGKey(seed + 99), (128, 128))
        qw, s = ref.quantize_blockwise(w.T)
        qw, s = qw.T, s.T
        lhs = ops.dequant_matmul(a1 + a2, qw, s)
        rhs = ops.dequant_matmul(a1, qw, s) + ops.dequant_matmul(a2, qw, s)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-4)

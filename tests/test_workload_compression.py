"""Workload-compression tests: the certified error bound, the exact-parity
bypass, clustering determinism, incremental ClusterIndex maintenance, the
compressed AdvisorSession mode, and the vectorized scaled-workload generator.

The deterministic suite below always runs; the hypothesis property twins at
the bottom are guarded with a soft import (same pattern as test_session.py).
"""
import dataclasses
import hashlib
import random

import pytest

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        base_configuration, chunked_config_costs,
                        compress_workload, make_scaled_workload,
                        make_scaled_workload_reference, make_tpch_like)
from repro.core.workload import (BulkInsert, Query, Workload, WorkloadDelta)
from repro.core.workload_compression import ClusterIndex


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.2, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_scaled_workload(schema, n_statements=120, seed=7)


@pytest.fixture(scope="module")
def budget_bytes(schema, workload):
    adv = DesignAdvisor(workload)
    base_size = sum(adv.sizes.size(i)
                    for i in base_configuration(schema).indexes)
    return 0.3 * base_size


def _rec_equal(a, b):
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


class TestCompression:
    def test_budget_bounds_representatives(self, workload):
        for budget in (16, 24, 48):
            comp = compress_workload(workload, budget)
            assert comp is not None
            # the coarse tail can only exceed the budget when the budget is
            # below the per-(kind, table) structural floor
            n_tables = len(workload.schema.tables)
            assert comp.n_representatives <= max(budget, 2 * n_tables)
            assert comp.n_full == len(workload.statements)
            # representative weights conserve total workload weight
            assert sum(c.weight for c in comp.clusters) == pytest.approx(
                sum(s.weight for s in workload.statements))

    def test_membership_covers_workload(self, workload):
        comp = compress_workload(workload, 24)
        members = comp.cluster_of()
        assert set(members) == {s.name for s in workload.statements}

    def test_error_bound_holds(self, workload, budget_bytes):
        """|true full-workload cost - compressed cost| <= reported bound."""
        for budget in (16, 32, 64):
            adv = DesignAdvisor(
                workload, AdvisorOptions(compression_budget=budget))
            rec = adv.recommend(budget_bytes)
            assert rec.n_representatives < rec.n_statements_full
            true_cost = float(chunked_config_costs(
                workload, adv.inner.sizes, [rec.config],
                chunk_statements=17)[0])
            assert abs(true_cost - rec.cost) <= rec.compression_error_bound \
                + 1e-9 * abs(true_cost)

    def test_bypass_parity_is_exact(self, workload, budget_bytes):
        """budget >= n_statements (or None) reproduces the uncompressed
        recommendation bit-identically."""
        n = len(workload.statements)
        rec_full = DesignAdvisor(workload).recommend(budget_bytes)
        for budget in (n, n + 1, 10 ** 6):
            assert compress_workload(workload, budget) is None
            rec_b = DesignAdvisor(workload, AdvisorOptions(
                compression_budget=budget)).recommend(budget_bytes)
            assert _rec_equal(rec_b, rec_full)
            assert rec_b.compression_error_bound == 0.0
            assert rec_b.n_representatives == rec_b.n_statements_full == n

    def test_clustering_deterministic_and_order_stable(self, workload):
        comp = compress_workload(workload, 24)
        again = compress_workload(workload, 24)
        assert comp.workload.statements == again.workload.statements
        assert comp.cluster_of() == again.cluster_of()
        for seed in (0, 1):
            shuffled = list(workload.statements)
            random.Random(seed).shuffle(shuffled)
            comp_s = compress_workload(
                Workload(schema=workload.schema, statements=shuffled), 24)
            assert comp_s.workload.statements == comp.workload.statements
            assert comp_s.cluster_of() == comp.cluster_of()

    def test_incremental_index_matches_fresh(self, schema, workload):
        ix = ClusterIndex.from_workload(workload)
        wl = workload
        t = schema.tables["lineitem"]
        cols = [c.name for c in t.columns]
        deltas = [
            WorkloadDelta(reweighted=tuple(
                (s.name, s.weight * 2.5) for s in wl.statements[:6])),
            WorkloadDelta(removed=tuple(
                s.name for s in wl.statements[10:25])),
            WorkloadDelta(added=(
                Query("fresh0", "lineitem",
                      (dataclasses.replace(
                          wl.queries()[0].filters[0]),), (cols[1],),
                      weight=1.5),
                BulkInsert("fresh1", "lineitem", 512, weight=0.2))),
        ]
        for delta in deltas:
            wl = wl.apply_delta(delta)
            ix.apply_delta(delta)
            inc = ix.derive(24)
            fresh = compress_workload(wl, 24)
            assert inc.workload.statements == fresh.workload.statements
            assert inc.cluster_of() == fresh.cluster_of()


class TestCompressedSession:
    def test_session_matches_fresh_advisor(self, schema, workload,
                                           budget_bytes):
        opt = AdvisorOptions(compression_budget=24)
        sess = AdvisorSession(workload, opt)
        wl = workload
        t = schema.tables["lineitem"]
        cols = [c.name for c in t.columns]
        deltas = [
            WorkloadDelta(),     # round 0: initial recommend
            WorkloadDelta(reweighted=tuple(
                (s.name, 3.0) for s in workload.statements[:5])),
            WorkloadDelta(added=(
                Query("x0", "lineitem",
                      (dataclasses.replace(
                          workload.queries()[0].filters[0]),),
                      (cols[2],), weight=1.0),)),
            WorkloadDelta(removed=tuple(
                s.name for s in workload.statements[20:40])),
        ]
        for delta in deltas:
            if delta:
                wl = wl.apply_delta(delta)
                sess.apply(delta)
            got = sess.recommend(budget_bytes)
            want = DesignAdvisor(wl, opt).recommend(budget_bytes)
            assert _rec_equal(got, want)
            assert got.compression_error_bound == \
                want.compression_error_bound

    def test_session_reweight_fast_path(self, workload, budget_bytes):
        opt = AdvisorOptions(compression_budget=24)
        sess = AdvisorSession(workload, opt)
        sess.recommend(budget_bytes)
        # a ranking-preserving nudge keeps the cluster set unchanged, so
        # the session only reweights the inner representatives
        s0 = workload.statements[0]
        delta = WorkloadDelta(reweighted=((s0.name, s0.weight * 1.0001),))
        sess.apply(delta)
        got = sess.recommend(budget_bytes)
        want = DesignAdvisor(workload.apply_delta(delta),
                             opt).recommend(budget_bytes)
        assert _rec_equal(got, want)
        assert sess.stats["compression_reweights"] == 1
        assert sess.stats["compression_rebuilds"] == 1  # only round 0

    def test_session_bypass_mode(self, workload, budget_bytes):
        opt = AdvisorOptions(compression_budget=10 ** 6)
        sess = AdvisorSession(workload, opt)
        got = sess.recommend(budget_bytes)
        want = DesignAdvisor(workload).recommend(budget_bytes)
        assert _rec_equal(got, want)
        assert sess.stats["compression_bypasses"] == 1


class TestScaledWorkloadGenerator:
    def test_structurally_equivalent_to_reference(self, schema):
        for seed in (0, 3):
            new = make_scaled_workload(schema, n_statements=200, seed=seed)
            ref = make_scaled_workload_reference(schema, n_statements=200,
                                                 seed=seed)
            assert [s.name for s in new.statements] == \
                [s.name for s in ref.statements]
            assert [type(s) for s in new.statements] == \
                [type(s) for s in ref.statements]
            for s in new.statements:
                t = schema.tables[s.table]
                if isinstance(s, BulkInsert):
                    assert s.nrows == max(t.nrows // 50, 50)
                    continue
                names = {c.name for c in t.columns}
                assert 1 <= len(s.filters) <= 3
                fcols = [p.col for p in s.filters]
                assert len(set(fcols)) == len(fcols)
                for p in s.filters:
                    mn, mx = t.minmax(p.col)
                    assert mn <= p.lo <= p.hi <= mx
                assert 1 <= len(s.cols_used) <= 4
                assert set(s.cols_used) <= names
                assert 0.5 <= s.weight <= 2.0

    def test_deterministic_and_frozen(self, schema):
        wl = make_scaled_workload(schema, n_statements=200, seed=0)
        again = make_scaled_workload(schema, n_statements=200, seed=0)
        assert wl.statements == again.statements
        fp = hashlib.sha256("\n".join(
            repr(s) for s in wl.statements).encode()).hexdigest()[:16]
        # frozen output of the vectorized generator at (scale=0.2, n=200,
        # seed=0) — benchmark workloads must not drift silently
        assert fp == "e1d567ccb6009d3f", fp


# ---------------------------------------------------------------------------
# Hypothesis property twins (soft import, as in test_session.py)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

    def _noop(*a, **k):
        def deco(fn):
            return fn
        return deco
    given = settings = _noop

    class st:             # minimal stand-in so the decorators parse
        @staticmethod
        def data():
            return None


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="property tests need hypothesis")
@settings(max_examples=6, deadline=None)
@given(st.data())
def test_property_bound_bypass_and_stability(data):
    schema = make_tpch_like(scale=0.1, z=0, seed=0)
    seed = data.draw(st.integers(0, 50), label="workload seed")
    n = data.draw(st.integers(30, 90), label="n_statements")
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    adv0 = DesignAdvisor(wl)
    base_size = sum(adv0.sizes.size(i)
                    for i in base_configuration(schema).indexes)
    budget_bytes = 0.3 * base_size

    # (a) compressed recommend cost within the reported bound of the true
    #     full-workload cost
    budget = data.draw(st.integers(12, max(13, n - 5)),
                       label="compression budget")
    adv = DesignAdvisor(wl, AdvisorOptions(compression_budget=budget))
    rec = adv.recommend(budget_bytes)
    if adv.inner is not None:
        true_cost = float(chunked_config_costs(
            wl, adv.inner.sizes, [rec.config], chunk_statements=16)[0])
        assert abs(true_cost - rec.cost) <= rec.compression_error_bound \
            + 1e-9 * abs(true_cost)

    # (b) budget >= n reproduces the uncompressed recommendation exactly
    rec_full = adv0.recommend(budget_bytes)
    rec_b = DesignAdvisor(wl, AdvisorOptions(
        compression_budget=n)).recommend(budget_bytes)
    assert _rec_equal(rec_b, rec_full)

    # (c) clustering is deterministic and stable under reordering
    comp = compress_workload(wl, min(budget, n - 1))
    if comp is not None:
        shuffled = list(wl.statements)
        random.Random(seed).shuffle(shuffled)
        comp_s = compress_workload(
            Workload(schema=wl.schema, statements=shuffled),
            min(budget, n - 1))
        assert comp_s.workload.statements == comp.workload.statements
        assert comp_s.cluster_of() == comp.cluster_of()

"""Runtime tests: checkpointing (atomic/checksummed/compressed), trainer
fault tolerance (restart, straggler detection), serve engine (continuous
batching), data pipeline determinism, and the design advisor."""
import json
import zlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.data.pipeline import DataConfig, batch_at
from repro.design import CODECS, plan_layout, sample_cf_bytes, skyline
from repro.design.advisor import Choice
from repro.design import codecs as DC
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.train.loop import TrainConfig, Trainer

TINY = ModelConfig("tiny", "dense", 2, 64, 4, 2, 128, 256, d_head=16)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab=100, batch=4, seq=32, seed=7)
        a = batch_at(cfg, 5)
        b = batch_at(cfg, 5)
        assert bool((a["tokens"] == b["tokens"]).all())

    def test_labels_shifted(self):
        cfg = DataConfig(vocab=100, batch=2, seq=16)
        b = batch_at(cfg, 0)
        assert bool((b["labels"][:, :-1] == b["tokens"][:, 1:]).all())

    @given(st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_property_steps_differ(self, step):
        cfg = DataConfig(vocab=1000, batch=2, seq=64)
        a = batch_at(cfg, step)
        b = batch_at(cfg, step + 1)
        assert not bool((a["tokens"] == b["tokens"]).all())


class TestCheckpoint:
    def _mgr(self, tmp_path, **kw):
        return CheckpointManager(CheckpointConfig(str(tmp_path / "ck"), **kw))

    def test_roundtrip(self, tmp_path):
        mgr = self._mgr(tmp_path)
        params = MD.init_params(jax.random.PRNGKey(0), TINY, jnp.float32)
        mgr.save(10, params)
        step, restored, _, _ = mgr.restore_into(params)
        assert step == 10
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-6)

    def test_keep_last_k(self, tmp_path):
        mgr = self._mgr(tmp_path, keep_last_k=2)
        params = {"w": jnp.ones((8, 8))}
        for s in (1, 2, 3, 4):
            mgr.save(s, params)
        dirs = sorted(Path(mgr.dir).glob("step_*"))
        assert len(dirs) == 2
        assert mgr.latest_step() == 4

    def test_checksum_detects_corruption(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(1, {"w": jnp.arange(1024.0)})
        d = next(Path(mgr.dir).glob("step_*"))
        f = next(d.glob("leaf_*.bin"))
        raw = bytearray(f.read_bytes())
        raw[0] ^= 0xFF
        f.write_bytes(bytes(raw))
        with pytest.raises(IOError, match="checksum"):
            mgr.restore()

    def test_compression_actually_shrinks(self, tmp_path):
        mgr = self._mgr(tmp_path)
        # structured data compresses well under zstd
        w = jnp.tile(jnp.arange(128.0), (256, 1))
        mgr.save(1, {"w": w})
        man = json.loads(
            (next(Path(mgr.dir).glob("step_*")) / "manifest.json").read_text())
        leaf = list(man["leaves"].values())[0]
        assert leaf["stored_bytes"] < 0.5 * leaf["raw_bytes"]

    def test_async_save(self, tmp_path):
        mgr = self._mgr(tmp_path, async_save=True)
        mgr.save(5, {"w": jnp.ones((64, 64))})
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_atomic_no_tmp_left(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(1, {"w": jnp.ones((4,))})
        assert not list(Path(mgr.dir).glob("*.tmp"))


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        tc = TrainConfig(steps=30, batch=4, seq=32, lr=1e-2,
                         checkpoint_dir=None, use_design_advisor=False,
                         log_every=1000)
        t = Trainer(TINY, tc)
        out = t.run()
        assert out["final_loss"] < out["first_loss"]

    def test_checkpoint_restart_resumes(self, tmp_path):
        tc = TrainConfig(steps=10, batch=2, seq=16, checkpoint_every=5,
                         checkpoint_dir=str(tmp_path / "ck"),
                         use_design_advisor=False, log_every=1000)
        t1 = Trainer(TINY, tc)
        t1.run()
        assert t1.step == 10
        # new trainer resumes from the latest checkpoint
        t2 = Trainer(TINY, tc)
        assert t2.step == 10
        t2.run(steps=3)
        assert t2.step == 13

    def test_restart_preserves_loss_trajectory(self, tmp_path):
        """Determinism across restart: same data, same params => same loss."""
        ckdir = str(tmp_path / "ck2")
        tc = TrainConfig(steps=6, batch=2, seq=16, checkpoint_every=3,
                         checkpoint_dir=ckdir, use_design_advisor=False,
                         lr=1e-3, log_every=1000)
        t1 = Trainer(TINY, tc)
        t1.run()
        losses_full = [h["loss"] for h in t1.history]
        t2 = Trainer(TINY, tc)  # resumes at step 6
        t2.run(steps=2)
        t3 = Trainer(TINY, tc)  # resumes at step 8
        assert t3.step == 8

    def test_straggler_detection(self):
        import time as _time
        tc = TrainConfig(steps=8, batch=2, seq=16, straggler_factor=1.5,
                         use_design_advisor=False, log_every=1000)
        events = []
        t = Trainer(TINY, tc, on_straggler=lambda s, r: events.append(s))
        orig = t._step_fn

        def slow_step(p, o, b):
            if len(t.history) == 5:
                _time.sleep(1.0)
            return orig(p, o, b)

        t._step_fn = slow_step
        t.run()
        assert t.straggler_events  # the injected slow step was flagged

    def test_q8_moments_trainer_converges(self):
        tc = TrainConfig(steps=25, batch=4, seq=32, lr=1e-2,
                         use_design_advisor=False, log_every=1000)
        t = Trainer(TINY, tc)
        from repro.optim import AdamWConfig, adamw_init
        from repro.train.step import make_train_step
        t.opt_cfg = AdamWConfig(lr=1e-2, state_codec="q8")
        t._step_fn = jax.jit(make_train_step(TINY, t.opt_cfg, remat=False,
                                             attn_impl="full"))
        t.opt_state = adamw_init(t.params, t.opt_cfg)
        out = t.run()
        assert out["final_loss"] < out["first_loss"]


class TestServeEngine:
    def test_continuous_batching_drains(self):
        params = MD.init_params(jax.random.PRNGKey(0), TINY, jnp.float32)
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                     max_len=64))
        for uid in range(5):
            eng.submit(Request(uid=uid, prompt=[1 + uid, 2, 3],
                               max_new_tokens=4))
        eng.run_until_drained()
        assert len(eng.finished) == 5
        for r in eng.finished.values():
            assert len(r.out_tokens) == 4

    def test_slot_isolation(self):
        """A request's output must not depend on its co-batched neighbors."""
        params = MD.init_params(jax.random.PRNGKey(0), TINY, jnp.float32)
        prompt = [5, 6, 7]

        def run_with(others):
            eng = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                         max_len=64))
            eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
            for uid, p in enumerate(others, start=1):
                eng.submit(Request(uid=uid, prompt=p, max_new_tokens=3))
            eng.run_until_drained()
            return eng.finished[0].out_tokens

        alone = run_with([])
        crowded = run_with([[9, 8], [3, 1, 4, 1, 5]])
        assert alone == crowded

    # The interleaved-admission parity suite (mid-flight admission, slot
    # reuse after retirement, EOS retirement, queue overflow, context
    # truncation) lives in tests/test_serve_engine.py — a module with no
    # hypothesis/zstandard imports, so the continuous-batching
    # regressions run in every environment.


class TestDesignAdvisor:
    def test_skyline_pareto(self):
        cands = [Choice("x", "f32", 100, 1.0), Choice("x", "q8", 25, 1.2),
                 Choice("x", "bf16", 50, 1.1), Choice("x", "bad", 60, 1.3)]
        sky = skyline(cands)
        names = {c.codec for c in sky}
        assert "bad" not in names  # dominated by bf16
        assert {"f32", "bf16", "q8"} <= names

    def test_budget_forces_compression(self):
        n = TINY.param_count(padded=True)
        plan_loose = plan_layout(TINY, "train", 8, 64, 1, 1e12,
                                 base_flops_per_chip=1e12)
        # f32 weights+m+v = 12n bytes; 6.5n forces the moments to q8
        plan_tight = plan_layout(TINY, "train", 8, 64, 1,
                                 hbm_budget_bytes=6.5 * n,
                                 base_flops_per_chip=1e12)
        assert plan_loose.choices["adam_m"] == "f32"
        assert plan_tight.choices["adam_m"] == "q8"
        assert plan_tight.hbm_bytes < plan_loose.hbm_bytes

    def test_memory_bound_serving_compresses(self):
        plan = plan_layout(TINY, "serve", 128, 4096, 1, 1e12,
                           base_flops_per_chip=1e6)  # tiny compute
        assert plan.choices["weights"] in ("q8", "bf16")

    def test_compute_bound_training_declines_compression(self):
        """The paper's Example 2 on TPU: compute-bound + loose budget =>
        no compression despite availability."""
        plan = plan_layout(TINY, "train", 256, 4096, 1, 1e15,
                           base_flops_per_chip=1e15)
        assert plan.choices["adam_m"] == "f32"
        assert plan.choices["weights"] == "f32"

    def test_samplecf_zstd_accuracy(self):
        rng = np.random.default_rng(0)
        # compressible: low-entropy rows
        arr = np.repeat(rng.integers(0, 8, (4096, 1)), 64, axis=1) \
            .astype(np.float32)
        est = sample_cf_bytes("zstd", arr, fraction=0.1)
        true = len(DC.encode("zstd", arr)[0])
        assert abs(est / true - 1) < 0.5

    @given(st.sampled_from(["f32", "bf16", "q8", "zstd", "q8+zstd"]))
    @settings(max_examples=10, deadline=None)
    def test_property_codec_roundtrip(self, name):
        rng = np.random.default_rng(1)
        arr = rng.standard_normal((32, 128)).astype(np.float32)
        payload, meta = DC.encode(name, arr)
        out = DC.decode(payload, meta)
        assert out.shape == arr.shape
        if CODECS[name].lossless:
            np.testing.assert_array_equal(out, arr)
        else:
            tol = 0.05 if name.startswith("q8") else 0.01
            assert np.abs(out - arr).max() < tol * np.abs(arr).max() + 0.05

"""Durable crash recovery: WAL framing, atomic snapshots, and the
exact-parity contract surviving real process death.

Four claims under test:

1. The record framing (`frame_record`/`scan_records`) is adversarially
   robust: any byte-level damage is classified as either a torn tail
   (invalid bytes at the physical end — truncated, never an error) or
   mid-log corruption (invalid bytes with valid records after them —
   reported, poisoning only that log), and a single flipped bit can
   never slip past the CRC.
2. `DurableStore` write-ahead semantics: deltas are journaled before
   they are applied, failed applies are compensated with ABORT records,
   compaction atomically rotates a manifest and empties the WAL, and
   `recover()` reconstructs exactly the journaled-and-not-aborted
   suffix past the manifest.
3. The crash-point harness: for a seeded fleet storm, killing the
   process (copy the store directory, truncate the victim WAL) at EVERY
   record boundary — and at arbitrary mid-record byte offsets — then
   `AdvisorFleetService.recover()` yields tenants whose next
   recommendation is exactly `==` a fresh `DesignAdvisor` on the
   recovered workload; torn tails are truncated, corrupt tenants
   quarantined, and recovery itself never raises.
4. The disk fault sites (`disk_write`/`fsync`/`bit_flip`) inject
   exactly their documented semantics and the fleet's retry path keeps
   both the live session and the durable log replay-consistent.

The deterministic suite runs everywhere; the byte-fuzz property at the
bottom is hypothesis-gated like the other property modules.
"""
import dataclasses
import pickle
import shutil
import zlib
from pathlib import Path

import pytest

from repro.core import (AdvisorOptions, DesignAdvisor, DurableStore,
                        FaultError, FaultInjector, FaultSpec, LogCorrupt,
                        SessionSnapshot, Workload, WorkloadDelta,
                        make_scaled_workload, make_tpch_like)
from repro.core.durability import (REC_ABORT, REC_DELTA, REC_MANIFEST,
                                   WAL_MAGIC, _HEADER, frame_record,
                                   scan_records)
from repro.serve.advisor_service import (AdvisorFleetService, FleetConfig,
                                         TenantBudget, TenantQuarantined)

OPT = AdvisorOptions()
BUDGET = 2e6


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.05, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_scaled_workload(schema, n_statements=6, seed=1)


@pytest.fixture(scope="module")
def pool(schema):
    return [dataclasses.replace(s, name=f"p{i:02d}") for i, s in
            enumerate(make_scaled_workload(schema, n_statements=16,
                                           seed=6).statements)]


def assert_identical(rec_s, rec_f):
    assert rec_s.config == rec_f.config
    assert rec_s.cost == rec_f.cost
    assert rec_s.used_bytes == rec_f.used_bytes


def names(wl: Workload):
    return [s.name for s in wl.statements]


def drain_recommend(fleet, tid, budget=BUDGET):
    t = fleet.submit_recommend(tid, budget)
    fleet.run_until_drained()
    return t.result(300)


def assert_fleet_parity(fleet, tid, budget=BUDGET):
    """The recovered tenant's next recommendation == a fresh advisor on
    the recovered workload — the PR contract, verbatim."""
    rec = drain_recommend(fleet, tid, budget)
    wl = fleet.tenants[tid].session.workload
    assert_identical(rec, DesignAdvisor(wl, OPT).recommend(budget))
    return wl


# ---------------------------------------------------------------------------
# Record framing
# ---------------------------------------------------------------------------

class TestFraming:
    def test_roundtrip(self):
        recs = [(REC_DELTA, b"hello"), (REC_ABORT, pickle.dumps(3)),
                (REC_MANIFEST, b"\x00" * 200)]
        blob = b"".join(frame_record(t, p) for t, p in recs)
        scan = scan_records(blob)
        assert scan.records == recs
        assert scan.good_end == len(blob)
        assert not scan.torn_tail and scan.corrupt_at is None

    def test_empty(self):
        scan = scan_records(b"")
        assert scan.records == [] and scan.good_end == 0
        assert not scan.torn_tail and scan.corrupt_at is None

    def test_torn_tail_every_prefix(self):
        """Truncating anywhere inside the final record is a torn tail,
        truncated back to the last whole record — for EVERY offset."""
        r1 = frame_record(REC_DELTA, b"first")
        r2 = frame_record(REC_DELTA, b"second record payload")
        blob = r1 + r2
        for cut in range(len(r1) + 1, len(blob)):
            scan = scan_records(blob[:cut])
            assert scan.records == [(REC_DELTA, b"first")]
            assert scan.good_end == len(r1)
            assert scan.torn_tail and scan.corrupt_at is None

    def test_single_bit_flip_never_passes(self):
        """Flip every bit of a two-record log in turn: the scan must
        classify every flip as torn/corrupt, never parse it clean."""
        blob = frame_record(REC_DELTA, b"abcdef") + \
            frame_record(REC_ABORT, b"xy")
        clean = scan_records(blob)
        for byte in range(len(blob)):
            for bit in range(8):
                bad = bytearray(blob)
                bad[byte] ^= 1 << bit
                scan = scan_records(bytes(bad))
                assert (scan.records != clean.records or scan.torn_tail
                        or scan.corrupt_at is not None)

    def test_mid_log_corruption_vs_torn_tail(self):
        r1 = frame_record(REC_DELTA, b"one")
        r2 = frame_record(REC_DELTA, b"two")
        # damage in r1 with r2 intact after it -> corruption at 0
        bad = bytearray(r1 + r2)
        bad[_HEADER.size] ^= 0xFF
        scan = scan_records(bytes(bad))
        assert scan.corrupt_at == 0 and not scan.torn_tail
        assert scan.records == []
        # same damage with nothing valid after -> torn tail
        scan2 = scan_records(bytes(bad[:len(r1)]))
        assert scan2.torn_tail and scan2.corrupt_at is None

    def test_garbage_tail_with_magic_bytes(self):
        """A torn write that happens to start with the magic must still
        be a torn tail, not corruption."""
        r1 = frame_record(REC_DELTA, b"good")
        scan = scan_records(r1 + WAL_MAGIC + b"\xff" * 7)
        assert scan.records == [(REC_DELTA, b"good")]
        assert scan.torn_tail and scan.corrupt_at is None


# ---------------------------------------------------------------------------
# DurableStore write path + recovery
# ---------------------------------------------------------------------------

class TestDurableStore:
    def test_register_log_recover_roundtrip(self, tmp_path, pool):
        store = DurableStore(tmp_path)
        store.register("a", b"snap-a", meta={"k": 1})
        d0 = WorkloadDelta(added=(pool[0],))
        d1 = WorkloadDelta(added=(pool[1],), removed=(pool[0].name,))
        assert store.log_delta("a", d0) == 1
        assert store.log_delta("a", d1) == 2
        store.close()
        rec = DurableStore(tmp_path).recover()
        assert set(rec) == {"a"}
        rt = rec["a"]
        assert rt.snapshot_bytes == b"snap-a" and rt.meta == {"k": 1}
        assert rt.deltas == [d0, d1] and rt.last_seq == 2
        assert not rt.torn_tail and rt.error is None

    def test_abort_compensates(self, tmp_path, pool):
        store = DurableStore(tmp_path)
        store.register("a", b"s")
        store.log_delta("a", WorkloadDelta(added=(pool[0],)))
        seq = store.log_delta("a", WorkloadDelta(added=(pool[1],)))
        store.log_abort("a", seq)
        store.close()
        rt = DurableStore(tmp_path).recover()["a"]
        assert rt.deltas == [WorkloadDelta(added=(pool[0],))]
        assert rt.last_seq == 2        # aborted seqs stay consumed

    def test_checkpoint_truncates_and_bounds_replay(self, tmp_path, pool):
        store = DurableStore(tmp_path)
        store.register("a", b"v0")
        store.log_delta("a", WorkloadDelta(added=(pool[0],)))
        store.checkpoint("a", b"v1")
        assert (tmp_path / "wal" / "a.wal").stat().st_size == 0
        d2 = WorkloadDelta(added=(pool[1],))
        store.log_delta("a", d2)
        store.close()
        rt = DurableStore(tmp_path).recover()["a"]
        assert rt.snapshot_bytes == b"v1"
        assert rt.deltas == [d2]       # pre-checkpoint delta not replayed

    def test_maybe_compact_threshold_and_laziness(self, tmp_path, pool):
        store = DurableStore(tmp_path, compact_after=2)
        store.register("a", b"v0")
        calls = []

        def snap_fn():
            calls.append(1)
            return b"v1"

        store.log_delta("a", WorkloadDelta(added=(pool[0],)))
        assert store.maybe_compact("a", snap_fn) is False and not calls
        store.log_delta("a", WorkloadDelta(added=(pool[1],)))
        assert store.maybe_compact("a", snap_fn) is True and len(calls) == 1
        assert store.compactions == 1
        assert (tmp_path / "wal" / "a.wal").stat().st_size == 0

    def test_group_commit_batches_fsyncs(self, tmp_path, pool):
        store = DurableStore(tmp_path, group_commit=4)
        store.register("a", b"s")
        base = store.fsyncs
        for i in range(8):
            store.log_delta("a", WorkloadDelta(added=(pool[i],)))
        assert store.fsyncs - base == 2    # 8 appends, every 4th syncs
        store.log_delta("a", WorkloadDelta(added=(pool[8],)))
        store.sync("a")                    # force the straggler
        assert store.fsyncs - base == 3
        store.close()
        assert len(DurableStore(tmp_path).recover()["a"].deltas) == 9

    def test_duplicate_register_rejected(self, tmp_path):
        store = DurableStore(tmp_path)
        store.register("a", b"s")
        with pytest.raises(ValueError, match="already registered"):
            store.register("a", b"s2")

    def test_unknown_tenant_rejected(self, tmp_path, pool):
        store = DurableStore(tmp_path)
        with pytest.raises(KeyError, match="not registered"):
            store.log_delta("ghost", WorkloadDelta(added=(pool[0],)))

    def test_tenant_id_quoting(self, tmp_path, pool):
        """Hostile tenant ids become safe filenames and round-trip."""
        tid = "../weird/tenant id?*"
        store = DurableStore(tmp_path)
        store.register(tid, b"s")
        store.log_delta(tid, WorkloadDelta(added=(pool[0],)))
        store.close()
        for p in (tmp_path / "wal").iterdir():
            assert p.parent == tmp_path / "wal"      # no traversal
        assert set(DurableStore(tmp_path).recover()) == {tid}

    def test_torn_tail_physically_truncated(self, tmp_path, pool):
        store = DurableStore(tmp_path)
        store.register("a", b"s")
        store.log_delta("a", WorkloadDelta(added=(pool[0],)))
        store.close()
        wal = tmp_path / "wal" / "a.wal"
        good = wal.stat().st_size
        with open(wal, "ab") as f:
            f.write(b"DWAL\xff\xff")
        store2 = DurableStore(tmp_path)
        rt = store2.recover()["a"]
        assert rt.torn_tail and rt.error is None
        assert store2.torn_tail_truncations == 1
        assert wal.stat().st_size == good     # tail is gone on disk

    def test_recover_primes_store_for_more_journaling(self, tmp_path,
                                                      pool):
        store = DurableStore(tmp_path)
        store.register("a", b"s")
        store.log_delta("a", WorkloadDelta(added=(pool[0],)))
        store.close()
        store2 = DurableStore(tmp_path)
        rt = store2.recover()["a"]
        assert store2.log_delta("a", WorkloadDelta(added=(pool[1],))) \
            == rt.last_seq + 1
        store2.close()
        assert len(DurableStore(tmp_path).recover()["a"].deltas) == 2


# ---------------------------------------------------------------------------
# Crash-point harness: kill + recover at every record boundary
# ---------------------------------------------------------------------------

def run_small_storm(root, workload, pool, n_deltas=3,
                    compact_after=None, faults=None):
    """Two tenants; the victim (t0) takes `n_deltas` deltas.  Returns
    the expected per-prefix workloads for t0 (index i == state after i
    deltas)."""
    store = DurableStore(root, compact_after=compact_after, faults=faults)
    fleet = AdvisorFleetService(FleetConfig(slots=2), faults=faults,
                                store=store)
    fleet.register_tenant("t0", workload, OPT)
    fleet.register_tenant("t1", workload, OPT)
    prefixes = [workload]
    for i in range(n_deltas):
        d = WorkloadDelta(added=(pool[i],))
        tk = fleet.submit_delta("t0", d)
        fleet.run_until_drained()
        assert tk.exception(30) is None
        prefixes.append(prefixes[-1].apply_delta(d))
    store.close()
    return prefixes


class TestCrashPointHarness:
    def test_every_record_boundary_recovers_to_exact_parity(
            self, tmp_path, workload, pool):
        """THE acceptance criterion: kill the store at every WAL record
        boundary; recovery must rebuild t0 at exactly the journaled
        prefix, with its next recommendation `==` a fresh DesignAdvisor
        on that workload, and t1 untouched."""
        base = tmp_path / "base"
        prefixes = run_small_storm(base, workload, pool, n_deltas=3)
        bounds = DurableStore(base).wal_record_boundaries("t0")
        assert len(bounds) == 4            # 0 + one per delta record
        for i, cut in enumerate(bounds):
            trial = tmp_path / f"cut{i}"
            shutil.copytree(base, trial)
            with open(trial / "wal" / "t0.wal", "r+b") as f:
                f.truncate(cut)
            fleet = AdvisorFleetService.recover(trial)
            assert fleet.recovery_errors == {}
            wl = assert_fleet_parity(fleet, "t0")
            assert names(wl) == names(prefixes[i])
            assert fleet.tenants["t1"].quarantined_at is None
            assert names(fleet.tenants["t1"].session.workload) \
                == names(workload)

    def test_mid_record_kills_truncate_to_last_boundary(
            self, tmp_path, workload, pool):
        """Kills INSIDE a record land on the preceding boundary: the
        torn tail is truncated and the tenant recovers at the last
        wholly-journaled prefix (workload-level parity; the full
        recommend contract is pinned per boundary above)."""
        base = tmp_path / "base"
        prefixes = run_small_storm(base, workload, pool, n_deltas=2)
        bounds = DurableStore(base).wal_record_boundaries("t0")
        size = bounds[-1]
        cuts = sorted({bounds[1] + 1, (bounds[1] + size) // 2, size - 1})
        for i, cut in enumerate(cuts):
            assert bounds[1] < cut < size
            trial = tmp_path / f"mid{i}"
            shutil.copytree(base, trial)
            with open(trial / "wal" / "t0.wal", "r+b") as f:
                f.truncate(cut)
            store = DurableStore(trial)
            fleet = AdvisorFleetService.recover(store)
            assert fleet.recovery_errors == {}
            assert store.torn_tail_truncations == 1
            assert names(fleet.tenants["t0"].session.workload) \
                == names(prefixes[1])

    def test_bit_flip_quarantines_only_victim(self, tmp_path, workload,
                                              pool):
        """Mid-log corruption — an injected silent bit flip — must
        quarantine ONLY the victim (on its last valid prefix, ready for
        readmission) while every other tenant recovers to parity."""
        root = tmp_path / "s"
        faults = FaultInjector(seed=5, specs={
            "bit_flip": FaultSpec(at=(0,))})     # first t0 append flips
        run_small_storm(root, workload, pool, n_deltas=2, faults=faults)
        fleet = AdvisorFleetService.recover(root)
        assert isinstance(fleet.recovery_errors["t0"], LogCorrupt)
        assert fleet.tenants["t0"].quarantined_at is not None
        with pytest.raises(TenantQuarantined):
            fleet.submit_recommend("t0", BUDGET)
        assert_fleet_parity(fleet, "t1")
        # readmission restores from the valid prefix (the registration
        # snapshot: the flipped record was t0's first delta)
        fleet.readmit_tenant("t0")
        wl = assert_fleet_parity(fleet, "t0")
        assert names(wl) == names(workload)

    def test_corrupt_snapshot_makes_observable_husk(self, tmp_path,
                                                    workload, pool):
        root = tmp_path / "s"
        run_small_storm(root, workload, pool, n_deltas=1)
        snap = root / "snap" / "t0.snap"
        data = bytearray(snap.read_bytes())
        data[len(data) // 2] ^= 0xFF
        snap.write_bytes(bytes(data))
        fleet = AdvisorFleetService.recover(root)
        assert "t0" in fleet.recovery_errors
        t0 = fleet.tenants["t0"]
        assert t0.session is None and t0.quarantined_at is not None
        # no checkpoint to readmit from -> a clear error, not a crash
        with pytest.raises(Exception, match="re-register"):
            fleet.readmit_tenant("t0")
        assert_fleet_parity(fleet, "t1")

    def test_recovery_after_compaction_cycles(self, tmp_path, workload,
                                              pool):
        """Parity holds when the log has been compacted mid-storm: the
        manifest covers a prefix and the WAL only the suffix."""
        root = tmp_path / "s"
        prefixes = run_small_storm(root, workload, pool, n_deltas=3,
                                   compact_after=2)
        store = DurableStore(root)
        fleet = AdvisorFleetService.recover(store)
        assert fleet.recovery_errors == {}
        wl = assert_fleet_parity(fleet, "t0")
        assert names(wl) == names(prefixes[3])
        # 3 deltas with compact_after=2 -> one compaction happened, so
        # the WAL holds exactly the post-compaction suffix
        assert len(store.recover()["t0"].deltas) <= 1


# ---------------------------------------------------------------------------
# Disk fault sites through the fleet
# ---------------------------------------------------------------------------

class TestDiskFaultSites:
    def test_short_write_is_retryable_and_replay_consistent(
            self, tmp_path, workload, pool):
        faults = FaultInjector(seed=3, specs={
            "disk_write": FaultSpec(at=(1,))})
        store = DurableStore(tmp_path, faults=faults)
        fleet = AdvisorFleetService(FleetConfig(slots=1), faults=faults,
                                    store=store)
        fleet.register_tenant("t0", workload, OPT)
        tks = [fleet.submit_delta("t0", WorkloadDelta(added=(pool[i],)))
               for i in range(3)]
        fleet.run_until_drained()
        assert all(t.exception(30) is None for t in tks)
        assert fleet.stats["retries"] == 1
        assert store.short_writes_injected == 1
        store.close()
        f2 = AdvisorFleetService.recover(tmp_path)
        assert f2.recovery_errors == {}
        wl = assert_fleet_parity(f2, "t0")
        assert len(wl.statements) == len(workload.statements) + 3

    def test_fsync_failure_appends_abort_then_retry_succeeds(
            self, tmp_path, workload, pool):
        faults = FaultInjector(seed=3, specs={
            "fsync": FaultSpec(at=(1,))})
        store = DurableStore(tmp_path, faults=faults)
        fleet = AdvisorFleetService(FleetConfig(slots=1), faults=faults,
                                    store=store)
        fleet.register_tenant("t0", workload, OPT)
        tks = [fleet.submit_delta("t0", WorkloadDelta(added=(pool[i],)))
               for i in range(3)]
        fleet.run_until_drained()
        assert all(t.exception(30) is None for t in tks)
        assert store.wal_aborts == 1
        store.close()
        # the aborted seq is skipped, the retried journal entry applies:
        # exactly 3 deltas land despite 4 DELTA records in history
        rt = DurableStore(tmp_path).recover()["t0"]
        assert len(rt.deltas) == 3
        f2 = AdvisorFleetService.recover(tmp_path)
        wl = assert_fleet_parity(f2, "t0")
        assert len(wl.statements) == len(workload.statements) + 3

    def test_failed_apply_is_abort_compensated(self, tmp_path, workload,
                                               pool):
        """A delta that journals but fails validation must not resurrect
        at recovery (the write-ahead rule's compensation path)."""
        store = DurableStore(tmp_path)
        fleet = AdvisorFleetService(FleetConfig(slots=1), store=store)
        fleet.register_tenant("t0", workload, OPT)
        bad = WorkloadDelta(removed=("no_such_statement",))
        tk = fleet.submit_delta("t0", bad)
        ok = fleet.submit_delta("t0", WorkloadDelta(added=(pool[0],)))
        fleet.run_until_drained()
        assert tk.exception(30) is not None
        assert ok.exception(30) is None
        assert store.wal_aborts == 1
        store.close()
        f2 = AdvisorFleetService.recover(tmp_path)
        wl = assert_fleet_parity(f2, "t0")
        assert len(wl.statements) == len(workload.statements) + 1

    def test_durability_counters_in_fleet_stats(self, tmp_path, workload,
                                                pool):
        store = DurableStore(tmp_path, compact_after=2)
        fleet = AdvisorFleetService(FleetConfig(slots=1), store=store)
        fleet.register_tenant("t0", workload, OPT)
        for i in range(4):
            fleet.submit_delta("t0", WorkloadDelta(added=(pool[i],)))
        fleet.run_until_drained()
        s = fleet.stats
        assert s["wal_appends"] == 4
        assert s["compactions"] == 2
        assert s["fsyncs"] > 0
        assert s["recoveries"] == 0 and s["torn_tail_truncations"] == 0
        storeless = AdvisorFleetService(FleetConfig(slots=1))
        assert storeless.stats["wal_appends"] == 0

    def test_readmit_checkpoints_durable_state(self, tmp_path, workload,
                                               pool):
        """Readmission after an in-memory crash realigns the durable log
        with the restored checkpoint, so the NEXT process death recovers
        the same state the fleet actually serves."""
        store = DurableStore(tmp_path)
        fleet = AdvisorFleetService(FleetConfig(slots=1), store=store)
        fleet.register_tenant("t0", workload, OPT)
        fleet.submit_delta("t0", WorkloadDelta(added=(pool[0],)))
        fleet.run_until_drained()
        fleet.crash_tenant("t0")
        fleet.readmit_tenant("t0")
        live = names(fleet.tenants["t0"].session.workload)
        store.close()
        f2 = AdvisorFleetService.recover(tmp_path)
        assert names(f2.tenants["t0"].session.workload) == live


# ---------------------------------------------------------------------------
# Byte-offset fuzz (hypothesis-gated)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def _noop(*a, **k):
        def deco(fn):
            return fn
        return deco
    given = settings = _noop

    class st:                                         # noqa: N801
        @staticmethod
        def integers(**k):
            return None

        @staticmethod
        def booleans():
            return None


_FUZZ_STATE = {}


def _fuzz_base(tmp_path_factory, workload, pool):
    """One shared storm directory for every fuzz example."""
    if "root" not in _FUZZ_STATE:
        root = tmp_path_factory.mktemp("fuzz") / "base"
        _FUZZ_STATE["prefixes"] = run_small_storm(root, workload, pool,
                                                  n_deltas=3)
        _FUZZ_STATE["root"] = root
        _FUZZ_STATE["size"] = (root / "wal" / "t0.wal").stat().st_size
        _FUZZ_STATE["trial"] = 0
    return _FUZZ_STATE


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(offset=st.integers(min_value=0, max_value=10_000),
       flip=st.booleans(), bit=st.integers(min_value=0, max_value=7))
def test_property_arbitrary_byte_damage_never_crashes_recovery(
        tmp_path_factory, workload, pool, offset, flip, bit):
    """Damage the victim WAL at an ARBITRARY byte offset — truncate
    there, or flip one bit there — and recovery must (a) never raise,
    (b) leave t1 at exact parity, and (c) leave t0 either healthy on a
    valid prefix of the journaled history or quarantined with the error
    recorded.  This is the acceptance criterion's fuzz clause."""
    state = _fuzz_base(tmp_path_factory, workload, pool)
    size = state["size"]
    offset = offset % (size + 1)
    state["trial"] += 1
    trial = state["root"].parent / f"t{state['trial']}"
    if trial.exists():
        shutil.rmtree(trial)
    shutil.copytree(state["root"], trial)
    wal = trial / "wal" / "t0.wal"
    if flip and offset < size:
        data = bytearray(wal.read_bytes())
        data[offset] ^= 1 << bit
        wal.write_bytes(bytes(data))
    else:
        with open(wal, "r+b") as f:
            f.truncate(offset)
    fleet = AdvisorFleetService.recover(trial)       # must not raise
    assert fleet.tenants["t1"].quarantined_at is None
    assert names(fleet.tenants["t1"].session.workload) == names(workload)
    t0 = fleet.tenants["t0"]
    if t0.quarantined_at is not None:
        assert "t0" in fleet.recovery_errors
    else:
        got = names(t0.session.workload)
        allowed = [names(p) for p in state["prefixes"]]
        assert got in allowed
    shutil.rmtree(trial)

"""Property + unit tests: sharding rules, model-layer invariants, and the
sharded code path on a 1x1 mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import (DistConfig, param_specs,
                                        serve_state_specs)
from repro.launch.mesh import make_smoke_mesh
from repro.models import layers as L
from repro.models import model as MD
from repro.models.config import ModelConfig, MoEConfig, pad_for_tp


class TestShardingRules:
    @pytest.mark.parametrize("arch", ARCHS)
    @pytest.mark.parametrize("mode", ["tp", "fsdp"])
    def test_specs_cover_every_param(self, arch, mode):
        """Every leaf gets a spec, ranks match, and no spec axis is used on
        a non-divisible dim (the lowering-safety invariant)."""
        cfg = pad_for_tp(get_config(arch), 16)
        mesh = make_smoke_mesh()
        dist = DistConfig(parallel_mode=mode)
        shapes = MD.params_shape(cfg, jnp.bfloat16)
        specs = param_specs(shapes, cfg, dist, mesh)
        n = 0
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(specs, is_leaf=lambda x:
                                              isinstance(x, P))):
            assert len(spec) <= leaf.ndim
            n += 1
        assert n > 0

    def test_kv_seq_shard_spec(self):
        cfg = pad_for_tp(get_config("yi-9b"), 16, pad_kv=False)
        mesh = make_smoke_mesh()
        dist = DistConfig(kv_seq_shard=True)
        state = jax.eval_shape(
            lambda: MD.init_serve_state(cfg, 8, 128))
        specs = serve_state_specs(state, cfg, dist, mesh, batch=8)
        kspec = specs["kv"]["k"]
        # (L, B, S, Kv, Dh): seq dim gets the model axis, kv heads stay None
        assert kspec[3] is None

    def test_fsdp_mode_has_no_tp_axis(self):
        cfg = pad_for_tp(get_config("yi-9b"), 16)
        dist = DistConfig(parallel_mode="fsdp")
        assert dist.tp_axis is None
        assert "model" in dist.dp_axes


class TestModelInvariants:
    CFG = ModelConfig("t", "dense", 2, 64, 4, 2, 128, 256, d_head=16)

    def test_chunked_scan_equals_plain_scan(self):
        def step(c, x):
            return c * 0.9 + x, c
        xs = jnp.arange(512.0).reshape(512, 1)
        c1, y1 = jax.lax.scan(step, jnp.zeros((1,)), xs)
        c2, y2 = L.chunked_scan(step, jnp.zeros((1,)), xs, chunk=128)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)

    def test_chunked_attention_matches_full(self):
        p = L.init_attention(jax.random.PRNGKey(0), self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
        full = L.attention_full(p, x, self.CFG)
        chunked = L.attention_chunked(p, x, self.CFG, q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                                   rtol=2e-4, atol=2e-5)

    @given(st.integers(0, 4))
    @settings(max_examples=5, deadline=None)
    def test_property_rope_preserves_norm(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, 2, 16))
        pos = jnp.arange(8)[None]
        y = L.apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)

    def test_rope_relative_position_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

        def dot_at(i, j):
            qi = L.apply_rope(q, jnp.array([[i]]), 1e4)
            kj = L.apply_rope(k, jnp.array([[j]]), 1e4)
            return float(jnp.sum(qi * kj))

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4

    def test_moe_capacity_drops_are_bounded(self):
        """With cf high enough, no tokens drop and MoE output is dense."""
        cfg = ModelConfig("m", "moe", 1, 64, 4, 2, 128, 256, d_head=16,
                          moe=MoEConfig(4, 2, 32, capacity_factor=4.0))
        p = L.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        out = L.moe_mlp(p, x, cfg.moe)
        assert out.shape == x.shape
        # every token got at least one expert (no all-zero rows)
        norms = jnp.linalg.norm(out.reshape(-1, 64), axis=-1)
        assert bool((norms > 0).all())

    def test_padded_heads_function_preserving(self):
        """Zero-weight padded q/kv heads must not change the output."""
        base = ModelConfig("b", "dense", 1, 64, 4, 4, 128, 256, d_head=16)
        padded = pad_for_tp(base, 8)  # 4 -> 8 heads
        assert padded.heads == 8
        p_base = L.init_attention(jax.random.PRNGKey(0), base)
        # embed base weights into the padded layout, zeros elsewhere
        p_pad = {
            "wq": jnp.zeros((64, 8, 16)).at[:, :4].set(p_base["wq"]),
            "wk": jnp.zeros((64, 8, 16)).at[:, :4].set(p_base["wk"]),
            "wv": jnp.zeros((64, 8, 16)).at[:, :4].set(p_base["wv"]),
            "wo": jnp.zeros((8, 16, 64)).at[:4].set(p_base["wo"]),
        }
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
        np.testing.assert_allclose(
            np.asarray(L.attention_full(p_base, x, base)),
            np.asarray(L.attention_full(p_pad, x, padded)),
            rtol=1e-4, atol=1e-5)

    def test_sharded_train_step_on_1x1_mesh(self):
        """The full jit(step, in_shardings=...) path on the CPU mesh."""
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import activation_specs
        from repro.optim import AdamWConfig, adamw_init
        from repro.train.step import make_train_step

        cfg = self.CFG
        mesh = make_smoke_mesh()
        dist = DistConfig()
        with mesh:
            params = MD.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
            specs = param_specs(jax.eval_shape(lambda: params), cfg, dist,
                                mesh)
            params = jax.tree.map(
                lambda t, sp: jax.device_put(t, NamedSharding(mesh, sp)),
                params, specs)
            opt = adamw_init(params, AdamWConfig())
            act = activation_specs(dist)
            step = jax.jit(make_train_step(
                cfg, AdamWConfig(), remat=True, attn_impl="full",
                act_specs={"hidden": act["hidden"],
                           "logits": act["logits"]}))
            batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
                     "labels": jnp.zeros((2, 16), jnp.int32)}
            p2, o2, loss = step(params, opt, batch)
            assert np.isfinite(float(loss))


class TestQuantizedServing:
    """The advisor's 'weights: q8' choice executed through the fused
    dequant-matmul path (paper A.2: decompress-on-read, fused)."""

    @pytest.mark.parametrize("kind,d,f", [("swiglu", 128, 256),
                                          ("relu2", 128, 384)])
    def test_quantized_mlp_close_to_fp(self, kind, d, f):
        cfg = ModelConfig("q", "dense", 1, d, 4, 2, f, 256, d_head=32,
                          mlp=kind)
        p = L.init_mlp(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d)) * 0.5
        full = L.mlp(p, x, kind)
        pq = L.quantize_mlp(p)
        quant = L.mlp_quantized(pq, x, kind)
        err = np.abs(np.asarray(full - quant))
        scale = np.abs(np.asarray(full)).mean() + 1e-6
        assert err.mean() / scale < 0.05  # int8 weight-only quant error

    def test_quantized_mlp_pallas_interpret_matches_ref(self):
        cfg = ModelConfig("q", "dense", 1, 128, 4, 2, 256, 256, d_head=32)
        p = L.init_mlp(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 128))
        pq = L.quantize_mlp(p)
        a = L.mlp_quantized(pq, x, "swiglu", use_pallas=False)
        b = L.mlp_quantized(pq, x, "swiglu", use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)

    def test_memory_halves(self):
        cfg = ModelConfig("q", "dense", 1, 256, 4, 2, 512, 256, d_head=64)
        p = L.init_mlp(jax.random.PRNGKey(0), cfg)
        pq = L.quantize_mlp(p)
        raw = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(p))
        q = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(pq))
        assert q < 0.35 * raw  # int8 + f32 block scales ~ 0.26x of f32

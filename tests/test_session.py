"""Online advisor sessions: delta-sequence parity with fresh advisors.

The correctness contract under test: after ANY sequence of
add/remove/reweight deltas, `AdvisorSession.recommend` returns a
recommendation IDENTICAL — config, cost (==, not approx), used_bytes — to
a fresh `DesignAdvisor` built on the resulting workload.  Every session
stage either runs the one-shot advisor's code or replays cached values
that are pure functions of the same inputs, so the assertions are exact.

The deterministic suite runs everywhere; the randomized delta-sequence
property at the bottom is hypothesis-gated like the other property
modules.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        WorkloadDelta, base_configuration,
                        make_scaled_workload, make_tpch_like,
                        make_tpch_workload)
from repro.core.advisor import staged_recommend
from repro.core.workload import BulkInsert, Query


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.15, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_scaled_workload(schema, n_statements=40, seed=2)


@pytest.fixture(scope="module")
def drift_pool(schema):
    return [dataclasses.replace(s, name=f"d{i:03d}") for i, s in
            enumerate(make_scaled_workload(schema, n_statements=60,
                                           seed=9).statements)]


@pytest.fixture(scope="module")
def base_size(schema, workload):
    adv = DesignAdvisor(workload)
    return sum(adv.sizes.size(i) for i in base_configuration(schema).indexes)


def assert_identical(rec_s, rec_f):
    assert rec_s.config == rec_f.config
    assert rec_s.cost == rec_f.cost
    assert rec_s.used_bytes == rec_f.used_bytes
    assert rec_s.base_cost == rec_f.base_cost
    assert rec_s.n_sampled == rec_f.n_sampled
    assert rec_s.n_deduced == rec_f.n_deduced
    assert rec_s.estimation_cost_pages == rec_f.estimation_cost_pages
    assert rec_s.pool_size == rec_f.pool_size
    assert rec_s.candidate_count == rec_f.candidate_count


# ---------------------------------------------------------------------------
# Workload delta API
# ---------------------------------------------------------------------------

class TestWorkloadDelta:
    def test_apply_delta_order_semantics(self, workload, drift_pool):
        delta = WorkloadDelta(added=(drift_pool[0], drift_pool[1]),
                              removed=(workload.statements[3].name,),
                              reweighted=((workload.statements[0].name,
                                           7.5),))
        out = workload.apply_delta(delta)
        names = [s.name for s in out.statements]
        survivors = [s.name for s in workload.statements
                     if s.name != workload.statements[3].name]
        assert names == survivors + [drift_pool[0].name, drift_pool[1].name]
        assert out.statements[0].weight == 7.5
        # functional: the source workload is untouched
        assert workload.statements[0].weight != 7.5

    def test_apply_delta_validation(self, workload, drift_pool):
        with pytest.raises(KeyError):
            workload.apply_delta(WorkloadDelta(removed=("nope",)))
        with pytest.raises(KeyError):
            workload.apply_delta(WorkloadDelta(reweighted=(("nope", 1.0),)))
        with pytest.raises(ValueError):
            workload.apply_delta(WorkloadDelta(
                added=(workload.statements[0],)))   # name already taken
        name = workload.statements[1].name
        with pytest.raises(ValueError):
            workload.apply_delta(WorkloadDelta(
                removed=(name,), reweighted=((name, 1.0),)))

    def test_delta_truthiness(self):
        assert not WorkloadDelta()
        assert WorkloadDelta(removed=("x",))

    def test_duplicate_added_object_rejected(self, workload, drift_pool):
        q = drift_pool[40]
        with pytest.raises(ValueError):
            workload.apply_delta(WorkloadDelta(added=(q, q)))

    def test_bad_delta_leaves_session_unchanged(self, workload, drift_pool,
                                                base_size):
        """A delta that fails validation must not partially mutate the
        session: the next recommend still matches a fresh advisor."""
        budget = 0.25 * base_size
        opt = AdvisorOptions.dtac()
        sess = AdvisorSession(workload, opt)
        sess.recommend(budget)
        bad_table = dataclasses.replace(drift_pool[41], table="nope")
        for delta in (
                WorkloadDelta(removed=(workload.statements[0].name,),
                              added=(bad_table,)),
                WorkloadDelta(removed=(workload.statements[0].name,
                                       "unknown")),
                WorkloadDelta(added=(drift_pool[42], drift_pool[42]))):
            with pytest.raises((KeyError, ValueError)):
                sess.apply(delta)
        assert_identical(sess.recommend(budget),
                         DesignAdvisor(workload, opt).recommend(budget))

    def test_session_rejects_recycled_names(self, workload, drift_pool):
        sess = AdvisorSession(workload)
        gone = workload.statements[0]
        sess.remove_statements([gone.name])
        with pytest.raises(ValueError):
            sess.add_statements([gone])


# ---------------------------------------------------------------------------
# Deterministic delta-sequence parity
# ---------------------------------------------------------------------------

class TestSessionParity:
    def test_cold_recommend_matches_fresh(self, workload, base_size):
        budget = 0.25 * base_size
        rec_s = AdvisorSession(workload, AdvisorOptions.dtac()).recommend(
            budget)
        rec_f = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(
            budget)
        assert_identical(rec_s, rec_f)

    def test_scripted_delta_sequence(self, workload, drift_pool, base_size):
        """add -> remove -> reweight -> mixed, parity after EVERY round."""
        budget = 0.3 * base_size
        opt = AdvisorOptions.dtac()
        sess = AdvisorSession(workload, opt)
        sess.recommend(budget)
        wl = workload
        deltas = [
            WorkloadDelta(added=tuple(drift_pool[0:3])),
            WorkloadDelta(removed=(wl.statements[5].name,
                                   wl.statements[11].name)),
            WorkloadDelta(reweighted=((wl.statements[0].name, 4.0),
                                      (wl.statements[1].name, 0.25))),
            WorkloadDelta(added=tuple(drift_pool[3:5]),
                          removed=(wl.statements[2].name, "d000"),
                          reweighted=((wl.statements[3].name, 2.0),)),
        ]
        for delta in deltas:
            wl = wl.apply_delta(delta)
            sess.apply(delta)
            assert_identical(sess.recommend(budget),
                             DesignAdvisor(wl, opt).recommend(budget))

    def test_parity_across_budgets_after_drift(self, workload, drift_pool,
                                               base_size):
        opt = AdvisorOptions.dtac()
        sess = AdvisorSession(workload, opt)
        sess.recommend(0.2 * base_size)
        delta = WorkloadDelta(added=tuple(drift_pool[5:8]),
                              removed=(workload.statements[7].name,))
        wl = workload.apply_delta(delta)
        sess.apply(delta)
        for frac in (0.0, 0.15, 0.5):
            assert_identical(sess.recommend(frac * base_size),
                             DesignAdvisor(wl, opt).recommend(
                                 frac * base_size))

    def test_insert_heavy_parity(self, schema, base_size, drift_pool):
        wl = make_tpch_workload(schema, insert_weight=30.0)
        opt = AdvisorOptions.dtac()
        sess = AdvisorSession(wl, opt)
        budget = 0.4 * base_size
        sess.recommend(budget)
        delta = WorkloadDelta(
            added=(BulkInsert("ins_x", "lineitem", 500, weight=20.0),
                   drift_pool[10]),
            reweighted=(("load_orders", 5.0),))
        wl2 = wl.apply_delta(delta)
        sess.apply(delta)
        assert_identical(sess.recommend(budget),
                         DesignAdvisor(wl2, opt).recommend(budget))

    def test_dta_session_parity(self, workload, drift_pool, base_size):
        """No-compression sessions drift too (estimation stage is empty)."""
        opt = AdvisorOptions.dta()
        sess = AdvisorSession(workload, opt)
        budget = 0.3 * base_size
        sess.recommend(budget)
        delta = WorkloadDelta(added=tuple(drift_pool[20:22]),
                              removed=(workload.statements[9].name,))
        wl = workload.apply_delta(delta)
        sess.apply(delta)
        assert_identical(sess.recommend(budget),
                         DesignAdvisor(wl, opt).recommend(budget))

    def test_scalar_path_session_parity(self, schema, base_size):
        """use_engine=False exercises the scalar optimizer path (with its
        memo purge on re-registered sizes)."""
        wl = make_scaled_workload(schema, n_statements=12, seed=4)
        opt = AdvisorOptions(use_engine=False, use_batched_planner=False,
                             use_batched_estimation=False)
        sess = AdvisorSession(wl, opt)
        budget = 0.3 * base_size
        sess.recommend(budget)
        drift = [dataclasses.replace(s, name=f"x{i}") for i, s in
                 enumerate(make_scaled_workload(schema, n_statements=6,
                                                seed=8).statements)]
        delta = WorkloadDelta(added=tuple(drift[:2]),
                              removed=(wl.statements[1].name,),
                              reweighted=((wl.statements[0].name, 3.0),))
        wl2 = wl.apply_delta(delta)
        sess.apply(delta)
        assert_identical(sess.recommend(budget),
                         DesignAdvisor(wl2, opt).recommend(budget))


# ---------------------------------------------------------------------------
# Incrementality: the session must WORK less, not just match
# ---------------------------------------------------------------------------

class TestSessionIncrementality:
    def test_counters_show_delta_proportional_work(self, workload,
                                                   drift_pool, base_size):
        budget = 0.25 * base_size
        sess = AdvisorSession(workload, AdvisorOptions.dtac())
        sess.recommend(budget)
        cold = dict(sess.stats)
        assert cold["replay_misses"] > 0          # cold round computes
        delta = WorkloadDelta(added=tuple(drift_pool[30:32]),
                              removed=(workload.statements[6].name,),
                              reweighted=((workload.statements[0].name,
                                           2.5),))
        sess.apply(delta)
        sess.recommend(budget)
        warm = dict(sess.stats)
        d_hits = (warm["replay_hits"] + warm["replay_verified"]
                  - cold["replay_hits"] - cold["replay_verified"])
        d_misses = warm["replay_misses"] - cold["replay_misses"]
        # the graph-cache/replay counters: most decisions replayed
        assert d_hits > 0 and d_misses < d_hits, (d_hits, d_misses)
        assert warm["rec_hits"] > 0
        # statement rows were appended/dropped, not rebuilt
        assert warm["engine_rows_added"] == 2
        assert warm["engine_rows_removed"] == 1
        # SampleCF ran only for genuinely new compressed candidates
        assert warm["samplecf_cache_hits"] > 0
        # per-query selections mostly reused THIS round (the cold round
        # necessarily missed on every query)
        d_sel_hits = warm["selection_hits"] - cold["selection_hits"]
        d_sel_miss = warm["selection_misses"] - cold["selection_misses"]
        assert d_sel_hits > d_sel_miss, (d_sel_hits, d_sel_miss)

    def test_reweight_only_round_reuses_everything(self, workload,
                                                   base_size):
        budget = 0.25 * base_size
        sess = AdvisorSession(workload, AdvisorOptions.dtac())
        sess.recommend(budget)
        cold = dict(sess.stats)
        sess.reweight({workload.statements[0].name: 9.0})
        sess.recommend(budget)
        warm = dict(sess.stats)
        # weights don't touch candidates, sizes, or the deduction graph
        assert warm["replay_misses"] == cold["replay_misses"]
        assert warm["samplecf_cache_misses"] == cold["samplecf_cache_misses"]
        assert warm["selection_misses"] == cold["selection_misses"]
        assert warm["engine_cols_refreshed"] == cold["engine_cols_refreshed"]

    def test_sample_manager_is_order_independent(self, schema):
        from repro.core import SampleManager
        a = SampleManager(schema.tables, seed=3)
        b = SampleManager(schema.tables, seed=3)
        # draw in different orders; contents must match per (table, f)
        sa1 = a.get_sample("orders", 0.05)
        sa2 = a.get_sample("lineitem", 0.05)
        sb2 = b.get_sample("lineitem", 0.05)
        sb1 = b.get_sample("orders", 0.05)
        for col in sa1.values:
            np.testing.assert_array_equal(sa1.values[col], sb1.values[col])
        for col in sa2.values:
            np.testing.assert_array_equal(sa2.values[col], sb2.values[col])


# ---------------------------------------------------------------------------
# staged_recommend options threading (Example 1 baseline)
# ---------------------------------------------------------------------------

class TestStagedOptions:
    def test_staged_honors_custom_e_q(self, workload, base_size):
        opt = AdvisorOptions(e=1.0, q=0.8)
        rec = staged_recommend(workload, 0.3 * base_size, options=opt)
        assert rec.cost <= rec.base_cost + 1e-9

    def test_staged_scalar_engine_close_to_batched(self, workload,
                                                   base_size):
        b = 0.3 * base_size
        rec_b = staged_recommend(workload, b)
        rec_s = staged_recommend(workload, b,
                                 options=AdvisorOptions(use_engine=False))
        assert rec_b.config == rec_s.config
        assert abs(rec_b.cost - rec_s.cost) <= 1e-6 * max(rec_s.cost, 1.0)


# ---------------------------------------------------------------------------
# Randomized delta sequences (hypothesis property).  Guarded with a
# soft import — NOT importorskip — so the deterministic suite above
# always runs even without hypothesis installed.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

    def _noop(*a, **k):
        def deco(fn):
            return fn
        return deco
    given = settings = _noop

    class st:             # minimal stand-in so the decorators parse
        @staticmethod
        def data():
            return None


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="property tests need hypothesis")
@settings(max_examples=8, deadline=None)
@given(st.data())
def test_property_random_delta_sequences(data):
    """Randomized add/remove/reweight sequences keep the session
    bit-identical to fresh advisors, and the replay counters keep showing
    mostly-cached work."""
    schema = make_tpch_like(scale=0.1, z=0, seed=0)
    wl = make_scaled_workload(schema, n_statements=14, seed=1)
    pool = [dataclasses.replace(s, name=f"p{i:02d}") for i, s in
            enumerate(make_scaled_workload(schema, n_statements=20,
                                           seed=6).statements)]
    base_size = sum(DesignAdvisor(wl).sizes.size(i)
                    for i in base_configuration(schema).indexes)
    budget = 0.3 * base_size
    opt = AdvisorOptions.dtac()
    sess = AdvisorSession(wl, opt)
    assert_identical(sess.recommend(budget),
                     DesignAdvisor(wl, opt).recommend(budget))
    pool_at = 0
    for _ in range(data.draw(st.integers(1, 3), label="rounds")):
        names = [s.name for s in wl.statements]
        n_add = data.draw(st.integers(0, 2), label="n_add")
        n_rm = data.draw(st.integers(0, min(2, len(names) - 4)),
                         label="n_rm")
        rm = data.draw(st.permutations(names), label="rm")[:n_rm]
        added = tuple(pool[pool_at:pool_at + n_add])
        pool_at += n_add
        rw_names = [n for n in names if n not in set(rm)]
        n_rw = data.draw(st.integers(0, 3), label="n_rw")
        rw = tuple(
            (n, data.draw(st.floats(0.1, 5.0, allow_nan=False),
                          label="w"))
            for n in data.draw(st.permutations(rw_names),
                               label="rw")[:n_rw])
        delta = WorkloadDelta(added=added, removed=tuple(rm),
                              reweighted=rw)
        wl = wl.apply_delta(delta)
        sess.apply(delta)
        assert_identical(sess.recommend(budget),
                         DesignAdvisor(wl, opt).recommend(budget))
    stats = sess.stats
    assert stats["replay_hits"] + stats["replay_verified"] > 0

"""Unified accelerator backend: one knob, loud fallbacks, exact contracts.

Covers the backend plumbing the per-kernel suite (test_pallas_parity)
does not: AdvisorOptions(backend=...) overriding every per-module knob,
`core.backend.resolve`'s warn-once + counted fallback, WhatIfOptimizer's
engine REBUILD on backend switch (formerly an AssertionError), the
jax engine kernels against their numpy twins, session and fleet parity
under backend="jax", the fleet COST-phase stacked costing (bitwise equal
to per-job scoring on both backends), and the batched delta append.

Hypothesis-free so the module always runs; jax-dependent tests skip
where jax is genuinely absent.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (AdvisorOptions, CostEngine, DesignAdvisor,
                        WorkloadDelta, base_configuration,
                        make_scaled_workload, make_tpch_like,
                        make_tpch_workload)
from repro.core import backend as bk
from repro.core import candidates as cand
from repro.core.cost_engine import batched_candidate_costs
from repro.core.estimation_engine import EstimationEngine
from repro.core.session import AdvisorSession
from repro.core.whatif import WhatIfOptimizer
from repro.serve.advisor_service import AdvisorFleetService, FleetConfig

needs_jax = pytest.mark.skipif(not bk.HAVE_JAX, reason="needs jax")

BUDGET = 2_000_000


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.2, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_tpch_workload(schema, insert_weight=0.1)


def tenant_workload(schema, tid, n=12, seed=0):
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def identical(a, b):
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


class TestUnifiedKnob:
    def test_backend_overrides_per_module_knobs(self):
        opt = AdvisorOptions(backend="jax")
        assert opt.engine_backend == "jax"
        assert opt.estimation_backend == "jax"
        assert opt.planner_backend == "jax"
        opt = AdvisorOptions(backend="numpy", engine_backend="jax")
        assert opt.engine_backend == "numpy"

    def test_none_keeps_per_module_knobs(self):
        opt = AdvisorOptions(engine_backend="jax")
        assert opt.backend is None
        assert opt.engine_backend == "jax"
        assert opt.planner_backend == "numpy"

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            AdvisorOptions(backend="cuda")
        with pytest.raises(ValueError, match="unknown backend"):
            bk.resolve("tpu")

    @needs_jax
    def test_advisor_threads_backend_everywhere(self, workload):
        adv = DesignAdvisor(workload, AdvisorOptions(backend="jax"))
        rec = adv.recommend(BUDGET)
        assert rec.config is not None
        assert adv.build_engine().backend == "jax"
        assert adv.opt.planner_backend == "jax"
        assert adv.opt.estimation_backend == "jax"


class TestFallbackIsLoud:
    def test_warns_once_per_site_and_counts(self, workload, monkeypatch):
        monkeypatch.setattr(bk, "HAVE_JAX", False)
        monkeypatch.setattr(bk, "_warned_sites", set())
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = CostEngine(workload, DesignAdvisor(workload).sizes,
                             backend="jax")
            eng2 = CostEngine(workload, DesignAdvisor(workload).sizes,
                              backend="jax")
        assert eng.backend == "numpy"
        assert eng.stats()["backend_fallbacks"] == 1
        assert eng2.stats()["backend_fallbacks"] == 1
        fallback = [x for x in w
                    if issubclass(x.category, bk.BackendFallbackWarning)]
        assert len(fallback) == 1  # once per site, not per engine
        assert "cost_engine" in str(fallback[0].message)

    def test_estimation_engine_fallback_counts(self, schema, monkeypatch):
        monkeypatch.setattr(bk, "HAVE_JAX", False)
        monkeypatch.setattr(bk, "_warned_sites", set())
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = EstimationEngine(schema.tables, backend="jax")
        assert eng.backend == "numpy"
        assert eng.stats()["backend_fallbacks"] == 1
        assert any(issubclass(x.category, bk.BackendFallbackWarning)
                   for x in w)

    def test_numpy_never_falls_back(self, workload):
        eng = CostEngine(workload, DesignAdvisor(workload).sizes)
        assert eng.stats()["backend_fallbacks"] == 0


class TestWhatIfEngineRebuild:
    @needs_jax
    def test_backend_switch_rebuilds_instead_of_raising(self, workload):
        adv = DesignAdvisor(workload)
        w = WhatIfOptimizer(workload, adv.sizes)
        e1 = w.engine("numpy")
        assert e1.backend == "numpy"
        e2 = w.engine("jax")
        assert e2.backend == "jax" and e2 is not e1
        assert w.engine() is e2            # bare call reuses, never rebuilds
        e3 = w.engine("jax")
        assert e3 is e2                    # same backend: no rebuild
        e4 = w.engine("numpy")
        assert e4.backend == "numpy" and e4 is not e2
        base = base_configuration(workload.schema)
        assert np.isfinite(e4.config_cost(base))


@needs_jax
class TestJaxEngineKernels:
    """jax engine kernels vs the numpy float64 twins (float32 tolerance;
    the numpy backend remains the bit-parity reference)."""

    def test_candidate_query_costs_close(self, workload, schema):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
        adv.estimate_sizes(raw)
        e_np = CostEngine(workload, adv.sizes)
        e_jx = CostEngine(workload, adv.sizes, backend="jax")
        np.testing.assert_allclose(
            e_jx.candidate_query_costs(q, base, raw),
            e_np.candidate_query_costs(q, base, raw), rtol=2e-6)

    def test_score_replace_clustered_close(self, workload, schema):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
        adv.estimate_sizes(raw)
        secs = [i for i in raw if not i.clustered][:3]
        cls = [i for i in raw if i.clustered]
        if not cls:
            pytest.skip("no clustered candidates on this table")
        e_np = CostEngine(workload, adv.sizes)
        e_jx = CostEngine(workload, adv.sizes, backend="jax")
        for eng in (e_np, e_jx):
            eng.register(base.indexes)
            eng.register(raw)
        t = q.table
        sids = [e_np.blocks[t].id_of(i) for i in secs]
        cids = [e_np.blocks[t].id_of(i) for i in cls]
        qn, un = e_np.score_replace_clustered(t, sids, cids)
        qj, uj = e_jx.score_replace_clustered(t, sids, cids)
        np.testing.assert_allclose(qj, qn, rtol=2e-6)
        np.testing.assert_allclose(uj, un, rtol=2e-6)


class TestStackedCostBatch:
    """The fleet COST phase's stacked scorer vs per-job scoring."""

    def _jobs(self, workload, schema, backend):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        eng = CostEngine(workload, adv.sizes, backend=backend)
        jobs, per_job = [], []
        for q in workload.queries()[:4]:
            raw = cand.syntactically_relevant(q, schema.tables[q.table])
            raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
            adv.estimate_sizes(raw)
            jobs.append(eng.cost_job_arrays(q, base, raw))
            per_job.append(eng.candidate_query_costs(q, base, raw))
        return jobs, per_job

    def test_numpy_stack_bitwise_equals_per_job(self, workload, schema):
        jobs, per_job = self._jobs(workload, schema, "numpy")
        costs = batched_candidate_costs(jobs, backend="numpy")
        for i, want in enumerate(per_job):
            np.testing.assert_array_equal(costs[i, :len(want)], want)

    @needs_jax
    def test_jax_stack_bitwise_equals_per_job(self, workload, schema):
        jobs, per_job = self._jobs(workload, schema, "jax")
        costs = batched_candidate_costs(jobs, backend="jax")
        for i, want in enumerate(per_job):
            np.testing.assert_array_equal(costs[i, :len(want)], want)

    def test_requires_secondary_free_base(self, workload, schema):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        eng = CostEngine(workload, adv.sizes)
        sec = next(i for i in raw if not i.clustered)
        with pytest.raises(ValueError, match="secondary-free"):
            eng.cost_job_arrays(q, base.add(sec), raw)


@needs_jax
class TestSessionJaxParity:
    def test_session_equals_fresh_advisor_jax(self, schema):
        opt = dataclasses.replace(AdvisorOptions.dtac(), backend="jax")
        wl = make_scaled_workload(schema, n_statements=12, seed=11)
        sess = AdvisorSession(wl, opt)
        for rnd in range(3):
            extra = make_scaled_workload(schema, n_statements=2,
                                         seed=300 + rnd)
            added = [dataclasses.replace(s, name=f"r{rnd}_{s.name}")
                     for s in extra.statements]
            sess.add_statements(added)
            wl = wl.apply_delta(WorkloadDelta(added=tuple(added)))
            rec = sess.recommend(BUDGET)
            fresh = DesignAdvisor(wl, opt).recommend(BUDGET)
            assert identical(rec, fresh), rnd

    def test_peeked_cost_jobs_consumed_exactly(self, schema):
        """peek_cost_jobs + accept_cost_results with per-job engine
        values reproduces the un-peeked recommendation bitwise."""
        opt = dataclasses.replace(AdvisorOptions.dtac(), backend="jax")
        wl = make_scaled_workload(schema, n_statements=10, seed=21)
        plain = AdvisorSession(wl, opt).recommend(BUDGET)
        sess = AdvisorSession(wl, opt)
        jobs = sess.peek_cost_jobs()
        assert jobs  # fresh session: every selection is stale
        base = base_configuration(schema)
        res = {q.name: sess.engine.candidate_query_costs(q, base, cands)
               for q, cands in jobs}
        assert sess.accept_cost_results(sess.workload_version, res) == \
            len(res)
        rec = sess.recommend(BUDGET)
        assert identical(rec, plain)
        assert sess.cost_prefetch_consumed == len(res)

    def test_stale_cost_results_dropped(self, schema):
        opt = AdvisorOptions.dtac()
        wl = make_scaled_workload(schema, n_statements=8, seed=22)
        sess = AdvisorSession(wl, opt)
        ver = sess.workload_version
        sess.peek_cost_jobs()
        extra = make_scaled_workload(schema, n_statements=1, seed=400)
        sess.add_statements([dataclasses.replace(s, name=f"x_{s.name}")
                             for s in extra.statements])
        assert sess.accept_cost_results(ver, {"q": np.zeros(3)}) == 0
        rec = sess.recommend(BUDGET)
        fresh = DesignAdvisor(sess.workload, opt).recommend(BUDGET)
        assert identical(rec, fresh)
        assert sess.cost_prefetch_consumed == 0


class TestFleetCostPrefetchParity:
    @pytest.mark.parametrize("backend", [
        "numpy", pytest.param("jax", marks=needs_jax)])
    def test_fleet_parity_with_cost_prefetch(self, schema, backend):
        opt = dataclasses.replace(AdvisorOptions.dtac(), backend=backend)
        fleet = AdvisorFleetService(FleetConfig(slots=3))
        wls = {}
        for i in range(3):
            tid = f"t{i}"
            wls[tid] = tenant_workload(schema, tid, seed=60 + i)
            fleet.register_tenant(tid, wls[tid], opt)
        rng_seed = 500
        for rnd in range(2):
            tks = {}
            for i, tid in enumerate(list(wls)):
                extra = make_scaled_workload(
                    schema, n_statements=2, seed=rng_seed + rnd * 10 + i)
                added = [dataclasses.replace(s,
                                             name=f"{tid}_r{rnd}_{s.name}")
                         for s in extra.statements]
                d = WorkloadDelta(added=tuple(added))
                wls[tid] = wls[tid].apply_delta(d)
                fleet.submit_delta(tid, d)
                tks[tid] = fleet.submit_recommend(tid, BUDGET)
            fleet.run_until_drained()
            for tid, tk in tks.items():
                fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
                assert identical(tk.result(), fresh), (backend, rnd, tid)
        st = fleet.stats
        assert st["cost_prefetch_batches"] > 0
        assert st["cost_prefetch_jobs"] > 0
        consumed = sum(t.session.cost_prefetch_consumed
                       for t in fleet.tenants.values())
        assert consumed == st["cost_prefetch_jobs"]


class TestBatchedDeltaAppend:
    def test_grouped_append_bitwise_equals_sequential(self, schema):
        wl = make_scaled_workload(schema, n_statements=10, seed=31)
        adv = DesignAdvisor(wl)
        extra = make_scaled_workload(schema, n_statements=6, seed=32)
        added = tuple(dataclasses.replace(s, name=f"n_{s.name}")
                      for s in extra.statements)
        e1 = CostEngine(wl, adv.sizes)
        e2 = CostEngine(wl, adv.sizes)
        e1.apply_delta(WorkloadDelta(added=added))
        for s in added:                       # one-at-a-time reference
            e2.apply_delta(WorkloadDelta(added=(s,)))
        for t, b1 in e1.blocks.items():
            b2 = e2.blocks[t]
            assert b1.n == b2.n
            for name in ("cov", "seek", "ridr", "scanc", "upd"):
                np.testing.assert_array_equal(
                    getattr(b1, name)[:, :b1.n], getattr(b2, name)[:, :b2.n],
                    err_msg=(t, name))
            for name in ("size", "beta", "alpha", "nrows_idx"):
                np.testing.assert_array_equal(
                    getattr(b1, name)[:b1.n], getattr(b2, name)[:b2.n],
                    err_msg=(t, name))

"""Unit + property tests for compression methods, SampleCF and deduction."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (METHODS, IndexDef, SampleManager, make_tpch_like,
                        sample_cf)
from repro.core import compression as C
from repro.core import deduction as D
from repro.core.relation import ColumnDef, Table, build_index_data
from repro.core.samplecf import full_index_sizes


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.5, z=0, seed=0)


@pytest.fixture(scope="module")
def lineitem(schema):
    return schema.tables["lineitem"]


ALL_COLS = ("l_shipdate", "l_returnflag", "l_extendedprice", "l_quantity")


class TestCompressionMethods:
    @pytest.mark.parametrize("method", list(METHODS))
    def test_cf_at_most_one_plus_meta(self, lineitem, method):
        idx = IndexDef("lineitem", ALL_COLS, compression=method)
        s, sc = full_index_sizes(lineitem, idx)
        # per-page metadata can push slightly above 1 only for PAGE methods
        assert sc <= s * 1.02

    @pytest.mark.parametrize("method", ["NS", "GDICT"])
    def test_ord_ind_order_invariance(self, lineitem, method):
        """ORD-IND: same column SET => same compressed size (Figure 2)."""
        a = IndexDef("lineitem", ("l_shipdate", "l_returnflag"), compression=method)
        b = IndexDef("lineitem", ("l_returnflag", "l_shipdate"), compression=method)
        _, sa = full_index_sizes(lineitem, a)
        _, sb = full_index_sizes(lineitem, b)
        assert sa == sb

    def test_ord_dep_order_matters(self):
        """ORD-DEP methods are sensitive to key order (Figure 2) — and LDICT
        and RLE prefer OPPOSITE orders on the same data: leading with the
        high-cardinality wide column groups its duplicates into pages
        (LDICT wins), while leading with the low-cardinality column creates
        the longest runs (RLE wins)."""
        rng = np.random.default_rng(0)
        t = Table("t", [ColumnDef("a", 4), ColumnDef("b", 4)], {
            "a": rng.integers(0, 5, 30000),       # low cardinality
            "b": rng.integers(0, 5000, 30000)})   # high cardinality
        sizes = {}
        for method in ("LDICT", "RLE"):
            for cols in (("a", "b"), ("b", "a")):
                idx = IndexDef("t", cols, compression=method)
                sizes[(method, cols)] = full_index_sizes(t, idx)[1]
        assert sizes[("LDICT", ("b", "a"))] < sizes[("LDICT", ("a", "b"))]
        assert sizes[("RLE", ("a", "b"))] < sizes[("RLE", ("b", "a"))]

    def test_ns_unbiased_small_values(self):
        t = Table("t", [ColumnDef("a", 8)], {"a": np.arange(1000) % 7})
        idx = IndexDef("t", ("a",), compression="NS")
        s, sc = full_index_sizes(t, idx)
        assert sc < 0.5 * s  # 8-byte width, tiny values => big NS win

    @given(st.integers(1, 6), st.integers(2, 40), st.integers(0, 3))
    @settings(max_examples=25, deadline=None)
    def test_property_rle_runs(self, width, ndv, seed):
        """RLE on a sorted column beats RLE on a shuffled one (or ties)."""
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, ndv, 5000).astype(np.int64)
        srt = np.sort(vals)[:, None]
        shuf = vals[:, None]
        m = C.METHODS["RLE"]
        assert m.compressed_bytes(srt, [width]) <= m.compressed_bytes(shuf, [width])

    @given(st.integers(0, 5))
    @settings(max_examples=6, deadline=None)
    def test_property_gdict_permutation_invariant(self, seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, 50, 3000).astype(np.int64)
        m = C.METHODS["GDICT"]
        a = m.compressed_bytes(vals[:, None], [4])
        b = m.compressed_bytes(rng.permutation(vals)[:, None], [4])
        assert a == b


class TestBatchKernelProperties:
    """Hypothesis properties for the five *_bytes_batch codec kernels
    (deterministic seed-parametrized twins run unguarded in
    tests/test_estimation_engine.py)."""

    @staticmethod
    def _random_stack(rng, m, n):
        widths = rng.integers(1, 9, m)
        cols = np.stack([
            rng.integers(0, min(1 << (8 * int(w)), 1 << 62), n)
            for w in widths])
        return cols, widths

    @given(st.sampled_from(sorted(METHODS)), st.integers(1, 5),
           st.integers(2, 300), st.integers(1, 80), st.integers(0, 99))
    @settings(max_examples=60, deadline=None)
    def test_property_batch_equals_scalar(self, method, m, n, rpp, seed):
        """Exact batch-vs-scalar equality on random column stacks."""
        rng = np.random.default_rng(seed)
        cols, widths = self._random_stack(rng, m, n)
        got = C.BATCH_KERNELS[method](cols, widths, rpp)
        want = [C.METHODS[method]._fn(cols[i], int(widths[i]), rpp)
                for i in range(m)]
        assert got.tolist() == want

    @given(st.sampled_from(sorted(METHODS)), st.integers(2, 300),
           st.integers(1, 80), st.integers(0, 99))
    @settings(max_examples=60, deadline=None)
    def test_property_compressed_leq_cap(self, method, n, rpp, seed):
        """Compressed payload never exceeds the per-page uncompressed cap
        (page methods pay PAGE_META per page; GDICT's dictionary pointers
        are bounded by 3 bytes per row)."""
        rng = np.random.default_rng(seed)
        cols, widths = self._random_stack(rng, 3, n)
        got = C.BATCH_KERNELS[method](cols, widths, rpp)
        npages = -(-n // rpp)
        for i in range(3):
            w = int(widths[i])
            if method == "NS":
                cap = n * w
            elif method == "GDICT":
                cap = n * w + n * 3
            else:
                cap = n * w + npages * C.PAGE_META
            assert got[i] <= cap

    @given(st.sampled_from(["NS", "GDICT"]), st.integers(2, 300),
           st.integers(1, 80), st.integers(0, 99))
    @settings(max_examples=40, deadline=None)
    def test_property_ord_ind_permutation_invariant(self, method, n, rpp,
                                                    seed):
        """ORD-IND batch kernels are invariant under row permutation."""
        rng = np.random.default_rng(seed)
        cols, widths = self._random_stack(rng, 3, n)
        perm = np.stack([rng.permutation(cols[i]) for i in range(3)])
        a = C.BATCH_KERNELS[method](cols, widths, rpp)
        b = C.BATCH_KERNELS[method](perm, widths, rpp)
        assert a.tolist() == b.tolist()

    @given(st.sampled_from(["LDICT", "PREFIX", "RLE"]), st.integers(2, 8),
           st.integers(2, 50), st.integers(2, 64))
    @settings(max_examples=60, deadline=None)
    def test_property_ord_dep_sensitive_to_order(self, method, w, ndv, rpp):
        """ORD-DEP kernels are STRICTLY sensitive to the sort order: a
        run-grouped layout (each page one value) always beats a perfect
        interleave of the same multiset (each page >= 2 values)."""
        vals = np.arange(ndv, dtype=np.int64) * (1 << (8 * (w - 1))) \
            if w < 8 else np.arange(ndv, dtype=np.int64) << 55
        grouped = np.repeat(vals, rpp)[None, :]
        inter = np.tile(vals, rpp)[None, :]
        widths = np.array([w])
        g = C.BATCH_KERNELS[method](grouped, widths, rpp)[0]
        i = C.BATCH_KERNELS[method](inter, widths, rpp)[0]
        assert g < i


class TestSampleCF:
    def test_amortized_sampling(self, schema):
        mgr = SampleManager(schema.tables, seed=0)
        i1 = IndexDef("lineitem", ("l_shipdate",), compression="NS")
        i2 = IndexDef("lineitem", ("l_returnflag",), compression="NS")
        sample_cf(mgr, i1, 0.05)
        sample_cf(mgr, i2, 0.05)
        assert mgr.sampling_calls == 1  # §4.1: one sample per (table, f)

    @pytest.mark.parametrize("method,tol", [("NS", 0.02), ("LDICT", 0.25)])
    def test_accuracy(self, schema, lineitem, method, tol):
        mgr = SampleManager(schema.tables, seed=3)
        idx = IndexDef("lineitem", ("l_shipdate", "l_returnflag"),
                       compression=method)
        _, true = full_index_sizes(lineitem, idx)
        est = sample_cf(mgr, idx, 0.05)
        assert abs(est.est_bytes / true - 1) < tol

    def test_uncompressed_cf_is_one(self, schema):
        mgr = SampleManager(schema.tables, seed=0)
        idx = IndexDef("lineitem", ("l_shipdate",))
        est = sample_cf(mgr, idx, 0.05)
        assert est.cf == 1.0


class TestDeduction:
    def test_colset_exact_for_ordind(self, lineitem):
        a = IndexDef("lineitem", ("l_shipdate", "l_quantity"), compression="NS")
        b = IndexDef("lineitem", ("l_quantity", "l_shipdate"), compression="NS")
        _, sa = full_index_sizes(lineitem, a)
        assert D.colset_deduce(sa) == full_index_sizes(lineitem, b)[1]

    def test_colext_ordind_additive(self, lineitem):
        """R(I_AB) = R(I_A) + R(I_B) for NS (§4.2)."""
        cols = ("l_shipdate", "l_extendedprice")
        parts = []
        for c in cols:
            _, sc = full_index_sizes(
                lineitem, IndexDef("lineitem", (c,), compression="NS"))
            parts.append(((c,), float(sc)))
        est = D.colext_ordind_deduce(lineitem, cols, parts)
        _, true = full_index_sizes(
            lineitem, IndexDef("lineitem", cols, compression="NS"))
        # NS reductions are per-value; composite rows pay ROW_OVERHEAD once,
        # so additive deduction is near-exact up to that bookkeeping.
        assert abs(est / true - 1) < 0.15

    def test_colext_orddep_fragmentation_penalty(self, lineitem):
        """Deduced R must shrink when a leading column fragments runs."""
        f_lead = D.replaced_fraction(lineitem, ("l_returnflag",), "l_returnflag")
        f_frag = D.replaced_fraction(
            lineitem, ("l_extendedprice", "l_returnflag"), "l_returnflag")
        assert f_frag < f_lead

    def test_colext_orddep_accuracy(self, lineitem):
        cols = ("l_returnflag", "l_shipdate")
        parts = []
        for c in cols:
            _, sc = full_index_sizes(
                lineitem, IndexDef("lineitem", (c,), compression="LDICT"))
            parts.append(((c,), float(sc)))
        est = D.colext_orddep_deduce(lineitem, cols, parts)
        _, true = full_index_sizes(
            lineitem, IndexDef("lineitem", cols, compression="LDICT"))
        assert abs(est / true - 1) < 0.30  # Table 3: larger but bounded error

    def test_dice_formula_branch(self):
        """L <= 1 path: expected distinct sides of a |Y|-sided dice."""
        rng = np.random.default_rng(0)
        t = Table("t", [ColumnDef("hi", 4), ColumnDef("lo", 2)], {
            "hi": rng.permutation(np.arange(20000)),  # unique => L < 1
            "lo": rng.integers(0, 100, 20000)})
        dv = D._dv_per_page(t, ("hi", "lo"), "lo")
        tpp = D.tuples_per_page(t, ("hi", "lo"))
        expected = 100 - 100 * (1 - 1 / 100) ** tpp
        assert abs(dv - expected) < 1e-9

"""CPU-interpret parity suite for the Pallas advisor kernels (tier 1).

Pins the two contracts the unified "jax" backend rests on:

* `kernels.codec_bytes.batched_codec_bytes` is BIT-IDENTICAL to the
  frozen NumPy codec references (`compression.BATCH_KERNELS`) for every
  input — inside the int32 exactness envelope via the uint32-plane
  kernels, outside it via the kernels' own NumPy routing — so the
  estimation stage under backend="jax" registers exactly the sizes the
  numpy backend registers.

* `kernels.planner_score` computes float32 values whose *internal*
  consistency is exact: the fused kernel's probability equals
  `prob_within` recomputed from its own (cm, cs) outputs bitwise (the
  replay / session-vs-fresh contract), and EXACT (mean=1, std=0) K-pads
  are the exact multiplicative identity (K-pad invariance, bitwise).
  Against the float64 NumPy reference the kernels are only
  float32-close — documented, since erf and arithmetic differ — which
  is why the numpy backend remains the advisor's parity reference.

Runs in Pallas interpret mode on CPU (no accelerator required); the CI
jax job executes exactly this file plus the backend-unification tests.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="parity suite needs jax")

from repro.core import compression as comp
from repro.core import errors as err
from repro.kernels import codec_bytes as ck
from repro.kernels import planner_score as ps

try:  # soft import: property twins only run where hypothesis exists
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except Exception:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYP = False

METHODS = ("NS", "GDICT", "LDICT", "PREFIX", "RLE")
RNG = np.random.default_rng(7)


def ref_bytes(method, cols, widths, rpp):
    return comp.BATCH_KERNELS[method](np.asarray(cols, dtype=np.int64),
                                      np.asarray(widths, dtype=np.int64),
                                      rpp)


def assert_codec_exact(method, cols, widths, rpp):
    cols = np.asarray(cols, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    got = ck.batched_codec_bytes(method, cols, widths, rpp)
    want = ref_bytes(method, cols, widths, rpp)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int64


# ---------------------------------------------------------------------------
# codec-bytes kernels: bit equality against the frozen NumPy references
# ---------------------------------------------------------------------------

class TestCodecBitEquality:
    @pytest.mark.parametrize("method", METHODS)
    @pytest.mark.parametrize("shape,rpp", [
        ((1, 1), 1),        # single value, single-row pages
        ((3, 7), 3),        # partial last page
        ((5, 64), 16),      # exact pages
        ((8, 129), 128),    # one row past a lane boundary
        ((17, 200), 1000),  # rpp > nrows: one page
        ((4, 333), 1),      # rpp=1: every row its own page
    ])
    def test_random_small_values(self, method, shape, rpp):
        cols = RNG.integers(0, 1 << 16, size=shape)
        widths = RNG.integers(1, 9, size=shape[0])
        assert_codec_exact(method, cols, widths, rpp)

    @pytest.mark.parametrize("method", METHODS)
    def test_values_beyond_32_and_56_bits(self, method):
        # magnitudes crossing both uint32 planes: the kernels must stay
        # exact where float64 NS bit-lengths would already be unsafe
        cols = np.stack([
            RNG.integers(0, 1 << 62, size=96),
            np.full(96, (1 << 56) + 12345, dtype=np.int64),
            np.full(96, (1 << 32) - 1, dtype=np.int64),
            np.arange(96, dtype=np.int64) + (1 << 40),
        ])
        widths = np.array([8, 8, 8, 8])
        assert_codec_exact(method, cols, widths, 32)

    @pytest.mark.parametrize("method", METHODS)
    def test_degenerate_columns(self, method):
        cols = np.stack([
            np.zeros(50, dtype=np.int64),                 # all zero
            np.full(50, 9, dtype=np.int64),               # all equal
            np.repeat(np.arange(10), 5),                  # long runs
            np.arange(50, dtype=np.int64),                # all distinct
            np.sort(RNG.integers(0, 64, size=50)),        # sorted, dup-heavy
        ])
        widths = np.array([1, 2, 4, 8, 3])
        assert_codec_exact(method, cols, widths, 7)

    @pytest.mark.parametrize("method", METHODS)
    def test_out_of_envelope_routes_to_numpy(self, method):
        # width > 8 and negative values both leave the proven int32
        # envelope; the kernel must route to NumPy and stay exact
        wide = RNG.integers(0, 1 << 20, size=(3, 40))
        assert not ck.in_envelope(wide, np.array([16, 9, 32]))
        assert_codec_exact(method, wide, np.array([16, 9, 32]), 8)
        neg = RNG.integers(-1000, 1000, size=(2, 30))
        neg[0, 0] = -5
        assert not ck.in_envelope(neg, np.array([4, 4]))
        assert_codec_exact(method, neg, np.array([4, 4]), 8)

    @pytest.mark.parametrize("method", METHODS)
    def test_empty_stack(self, method):
        got = ck.batched_codec_bytes(
            method, np.zeros((0, 5), dtype=np.int64),
            np.zeros(0, dtype=np.int64), 4)
        assert got.shape == (0,)

    def test_dispatcher_routes_jax_backend(self):
        cols = RNG.integers(0, 1 << 10, size=(6, 90))
        widths = RNG.integers(1, 9, size=6)
        for method in METHODS:
            np.testing.assert_array_equal(
                comp.batched_bytes(method, cols, widths, 11, backend="jax"),
                comp.batched_bytes(method, cols, widths, 11))

    if HAVE_HYP:
        @settings(max_examples=30, deadline=None)
        @given(st.integers(1, 6), st.integers(1, 80), st.integers(1, 96),
               st.integers(0, 2 ** 63 - 1), st.integers(1, 8))
        def test_property_twin(self, m, n, rpp, top, w):
            cols = np.remainder(
                np.arange(m * n, dtype=np.uint64) * np.uint64(2654435761),
                np.uint64(top) + np.uint64(1)).astype(np.int64).reshape(m, n)
            widths = np.full(m, w, dtype=np.int64)
            for method in METHODS:
                assert_codec_exact(method, cols, widths, rpp)


# ---------------------------------------------------------------------------
# planner kernels: float32 closeness to the f64 reference, exact internal
# consistency (the replay contract), exact K-pad invariance
# ---------------------------------------------------------------------------

def random_rvs(nc, k, nf, seed):
    rng = np.random.default_rng(seed)
    m = rng.uniform(0.6, 1.4, size=(nc, k, nf))
    s = rng.uniform(0.0, 0.3, size=(nc, k, nf))
    s[rng.random(s.shape) < 0.2] = 0.0  # exercise the indicator branch
    dm = rng.uniform(0.8, 1.2, size=(nc, 1))
    msq = dm * dm
    vt = msq + rng.uniform(0.0, 0.1, size=(nc, 1))
    return m, s, dm, vt, msq


def staged_reference(m, s, dm, vt, mq, mask, e):
    """Float64 NumPy re-expression of compose + prob (the goodman fold)."""
    e_prod = m[:, 0, :].copy()
    v_term = s[:, 0, :] ** 2 + e_prod ** 2
    e2 = e_prod ** 2
    for kk in range(1, m.shape[1]):
        mk, sk = m[:, kk, :], s[:, kk, :]
        e_prod = e_prod * mk
        v_term = v_term * (sk * sk + mk * mk)
        e2 = e2 * (mk * mk)
    cm = e_prod * dm
    cs = np.sqrt(np.maximum(v_term * vt - e2 * mq, 0.0))
    p = np.zeros_like(cm)
    ii = mask.nonzero()
    p[ii] = err.prob_within_batch(cm[ii], cs[ii], e)
    return cm, cs, p


class TestProbWithin:
    def test_indicator_branch_exact(self):
        e = 0.1
        lo, hi = 1.0 / (1.0 + e), 1.0 + e
        means = np.array([0.2, lo, 1.0, hi, 1.6, np.float64(np.float32(lo))])
        stds = np.zeros_like(means)
        got = ps.prob_within(means, stds, e)
        # std=0: pure indicator; f32 rounding of the bounds could only
        # matter at the exact boundary, where both sides round the same
        assert set(np.unique(got)) <= {0.0, 1.0}
        np.testing.assert_array_equal(
            got[[0, 2, 4]], err.prob_within_batch(means, stds, e)[[0, 2, 4]])

    @pytest.mark.parametrize("n,e", [(1, 0.05), (7, 0.1), (128, 0.2),
                                     (129, 0.1), (1000, 0.15)])
    def test_erf_branch_close(self, n, e):
        rng = np.random.default_rng(n)
        means = rng.uniform(0.5, 1.5, size=n)
        stds = rng.uniform(1e-6, 0.5, size=n)
        got = ps.prob_within(means, stds, e)
        want = err.prob_within_batch(means, stds, e)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_shapes_and_empty(self):
        assert ps.prob_within(np.zeros(0), np.zeros(0), 0.1).shape == (0,)
        m2 = np.full((3, 4), 1.0)
        s2 = np.zeros((3, 4))
        assert ps.prob_within(m2, s2, 0.1).shape == (3, 4)

    if HAVE_HYP:
        @settings(max_examples=40, deadline=None)
        @given(st.floats(0.3, 2.5), st.floats(0.0, 1.0), st.floats(0.02, 0.5))
        def test_property_twin(self, mean, std, e):
            got = float(ps.prob_within(np.array([mean]), np.array([std]),
                                       e)[0])
            want = float(err.prob_within_batch(np.array([mean]),
                                               np.array([std]), e)[0])
            assert abs(got - want) <= 3e-5
            assert 0.0 <= got <= 1.0


class TestFusedScore:
    E, Q = 0.1, 0.9

    def test_staged_f64_reference_close(self):
        nc, k, nf = 11, 3, 5
        m, s, dm, vt, mq = random_rvs(nc, k, nf, seed=1)
        mask67 = np.ones((nc, nf), dtype=bool)
        mask67[2] = False
        cm, cs, p, _, _ = ps.fused_score(m, s, dm, vt, mq, mask67, None,
                                         None, self.E, self.Q)
        cm_r, cs_r, p_r = staged_reference(m, s, dm, vt, mq, mask67, self.E)
        np.testing.assert_allclose(cm, cm_r, rtol=1e-5)
        np.testing.assert_allclose(cs, cs_r, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(p, p_r, atol=5e-5)
        # masked-out rows are exactly zero on both sides
        assert (p[2] == 0.0).all()

    def test_prob_consistency_bitwise(self):
        """THE replay contract: recomputing the probability from the fused
        kernel's own (cm, cs) through prob_within reproduces its p
        bitwise (same _prob_expr, float32-exact in-and-out)."""
        nc, k, nf = 9, 2, 4
        m, s, dm, vt, mq = random_rvs(nc, k, nf, seed=2)
        mask67 = np.ones((nc, nf), dtype=bool)
        cm, cs, p, _, _ = ps.fused_score(m, s, dm, vt, mq, mask67, None,
                                         None, self.E, self.Q)
        again = ps.prob_within(cm, cs, self.E)
        np.testing.assert_array_equal(p, again)

    def test_kpad_invariance_bitwise(self):
        """EXACT (mean=1, std=0) K-pads are the exact float32
        multiplicative identity: folding K=2 padded to K=5 is bitwise
        the K=2 fold."""
        nc, nf = 6, 3
        m, s, dm, vt, mq = random_rvs(nc, 2, nf, seed=3)
        mask67 = np.ones((nc, nf), dtype=bool)
        pad_m = np.concatenate([m, np.ones((nc, 3, nf))], axis=1)
        pad_s = np.concatenate([s, np.zeros((nc, 3, nf))], axis=1)
        a = ps.fused_score(m, s, dm, vt, mq, mask67, None, None,
                           self.E, self.Q)
        b = ps.fused_score(pad_m, pad_s, dm, vt, mq, mask67, None, None,
                           self.E, self.Q)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_winner_indices_match_host_argmax(self):
        nc, k, nf = 14, 2, 6
        m, s, dm, vt, mq = random_rvs(nc, k, nf, seed=4)
        mask67 = np.zeros((nc, nf), dtype=bool)
        mask67[: nc // 2] = True
        pre9 = np.zeros((nc, nf), dtype=bool)
        pre9[nc // 2:] = True
        extra = np.abs(np.random.default_rng(5).normal(size=(nc, nf))) + 0.1
        cm, cs, p, w6, w9 = ps.fused_score(m, s, dm, vt, mq, mask67, pre9,
                                           extra, self.E, 0.2)
        sat = p >= 0.2
        for f in range(nf):
            elig = mask67[:, f] & sat[:, f]
            if elig.any():
                pe = np.where(elig, p[:, f], -1.0)
                assert w6[f] == int(np.flatnonzero(pe == pe.max())[0])
            else:
                assert w6[f] == 2 ** 31 - 1
                ok9 = pre9[:, f] & sat[:, f]
                if ok9.any():
                    xe = np.where(ok9, extra[:, f], np.inf)
                    assert w9[f] == int(np.flatnonzero(xe == xe.min())[0])

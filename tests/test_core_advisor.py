"""Integration + property tests for candidate selection, enumeration, DTAc."""
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (AdvisorOptions, DesignAdvisor, IndexDef,
                        base_configuration, make_tpch_like,
                        make_tpch_workload, storage_used)
from repro.core import candidates as cand
from repro.core.advisor import staged_recommend


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.5, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_tpch_workload(schema, insert_weight=0.1)


@pytest.fixture(scope="module")
def base_size(schema, workload):
    adv = DesignAdvisor(workload)
    return sum(adv.sizes.size(i) for i in base_configuration(schema).indexes)


class TestSkyline:
    def test_skyline_no_dominated_points(self, workload, schema):
        adv = DesignAdvisor(workload)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
        base = base_configuration(schema)
        adv.estimate_sizes(raw)
        costed = cand.cost_candidates(q, raw, base, adv.optimizer, adv.sizes)
        sky = cand.select_skyline(costed)
        for a in sky:
            for b in sky:
                if a is b:
                    continue
                assert not (b.cost <= a.cost and b.size <= a.size
                            and (b.cost < a.cost or b.size < a.size))

    def test_skyline_superset_of_best(self, workload, schema):
        """The lowest-cost configuration is always on the skyline."""
        adv = DesignAdvisor(workload)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        base = base_configuration(schema)
        costed = cand.cost_candidates(q, raw, base, adv.optimizer, adv.sizes)
        sky = cand.select_skyline(costed)
        best = min(costed, key=lambda c: (c.cost, c.size))
        assert any(c.index.key == best.index.key and c.cost == best.cost
                   for c in sky)

    def test_skyline_keeps_small_slow_candidates(self, workload, schema):
        """§6.1: skyline retains compressed candidates that top-k prunes."""
        adv = DesignAdvisor(workload)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
        adv.estimate_sizes(raw)
        base = base_configuration(schema)
        costed = cand.cost_candidates(q, raw, base, adv.optimizer, adv.sizes)
        sky = {c.index.key for c in cand.select_skyline(costed)}
        topk = {c.index.key for c in cand.select_topk(costed, 2)}
        assert len(sky - topk) > 0


class TestEnumeration:
    @pytest.mark.parametrize("variant", ["pure", "density", "backtrack"])
    def test_budget_respected(self, workload, base_size, variant):
        opts = AdvisorOptions(enumeration=variant)
        rec = DesignAdvisor(workload, opts).recommend(0.3 * base_size)
        assert rec.used_bytes <= 0.3 * base_size + 1e-6

    def test_monotone_no_worse_than_base(self, workload, base_size):
        rec = DesignAdvisor(workload).recommend(0.2 * base_size)
        assert rec.cost <= rec.base_cost

    def test_backtrack_no_worse_than_pure(self, workload, base_size):
        for frac in (0.1, 0.3):
            bt = DesignAdvisor(workload, AdvisorOptions(
                enumeration="backtrack")).recommend(frac * base_size)
            pure = DesignAdvisor(workload, AdvisorOptions(
                enumeration="pure")).recommend(frac * base_size)
            assert bt.cost <= pure.cost + 1e-9

    def test_one_clustered_per_table(self, workload, base_size, schema):
        rec = DesignAdvisor(workload).recommend(0.5 * base_size)
        for t in schema.tables:
            n = sum(1 for i in rec.config.indexes
                    if i.table == t and i.clustered)
            assert n == 1

    @given(st.floats(0.05, 1.0))
    @settings(max_examples=8, deadline=None)
    def test_property_budget_always_respected(self, workload, base_size,
                                              frac):
        rec = DesignAdvisor(workload).recommend(frac * base_size)
        assert rec.used_bytes <= frac * base_size + 1e-6
        assert rec.cost <= rec.base_cost + 1e-9


class TestAdvisorEndToEnd:
    def test_dtac_beats_dta_tight_budget(self, workload, base_size):
        b = 0.2 * base_size
        dtac = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(b)
        dta = DesignAdvisor(workload, AdvisorOptions.dta()).recommend(b)
        assert dtac.improvement > dta.improvement

    def test_dtac_beats_staged(self, workload, base_size):
        """Example 1: decoupling index choice from compression is poor."""
        b = 0.25 * base_size
        dtac = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(b)
        staged = staged_recommend(workload, b)
        assert dtac.cost <= staged.cost + 1e-9

    def test_zero_budget_still_improves(self, workload):
        """App. D.2: 0% budget => compress base tables to fund indexes."""
        rec = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(0.0)
        assert rec.improvement > 0.0
        assert rec.used_bytes <= 0.0 + 1e-6

    def test_insert_intensive_avoids_compression(self, schema, base_size):
        """Fig. 15/17: heavy INSERTs => fewer compressed indexes chosen."""
        sel = make_tpch_workload(schema, insert_weight=0.1)
        ins = make_tpch_workload(schema, insert_weight=50.0)
        b = 1.0 * base_size
        rec_sel = DesignAdvisor(sel, AdvisorOptions.dtac()).recommend(b)
        rec_ins = DesignAdvisor(ins, AdvisorOptions.dtac()).recommend(b)
        n_sel = sum(1 for i in rec_sel.config.indexes if i.compression)
        n_ins = sum(1 for i in rec_ins.config.indexes if i.compression)
        assert n_ins <= n_sel

    def test_deduction_reduces_estimation_cost(self, workload):
        with_d = DesignAdvisor(workload, AdvisorOptions(use_deduction=True))
        no_d = DesignAdvisor(workload, AdvisorOptions(use_deduction=False))
        r1 = with_d.recommend(1e9)
        r2 = no_d.recommend(1e9)
        assert r1.estimation_cost_pages <= r2.estimation_cost_pages

    def test_improvement_monotone_in_budget(self, workload, base_size):
        r = [DesignAdvisor(workload).recommend(f * base_size).improvement
             for f in (0.1, 0.5, 1.0)]
        assert r[0] <= r[2] + 0.02  # small tolerance for greedy noise

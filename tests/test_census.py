"""Census validation: on configs whose layer stack is fully UNROLLED (no
while loops), XLA cost_analysis is trustworthy — the analytic census must
agree with it.  This is the calibration that justifies using the census for
the full-scale roofline (where scans make cost_analysis undercount ~L x;
demonstrated in test_while_loop_undercount)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.census import census, forward_flops
from repro.launch.roofline import cost_analysis_dict as _cost_analysis
from repro.models import model as MD
from repro.models.config import ModelConfig, MoEConfig


def _fwd_flops_compiled(cfg, b, s, unroll):
    old = MD.SCAN_UNROLL
    MD.SCAN_UNROLL = unroll
    try:
        params = jax.eval_shape(
            lambda: MD.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def f(p, t):
            return MD.forward(p, cfg, tokens=t, attn_impl="full")

        comp = jax.jit(f).lower(params, toks).compile()
        return float(_cost_analysis(comp)["flops"])
    finally:
        MD.SCAN_UNROLL = old


class TestWhileLoopUndercount:
    def test_cost_analysis_ignores_trip_count(self):
        """The defect that motivates the census (EXPERIMENTS.md)."""
        def make(n):
            def f(x, w):
                def body(c, _):
                    return c @ w, None
                return jax.lax.scan(body, x, None, length=n)[0]
            return f
        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        f5 = _cost_analysis(jax.jit(make(5)).lower(x, w).compile())["flops"]
        f10 = _cost_analysis(jax.jit(make(10)).lower(x, w).compile())["flops"]
        assert f5 == f10  # trip count is NOT multiplied


class TestCensusValidation:
    @pytest.mark.parametrize("layers,d,heads,kv,ff", [
        (2, 128, 4, 2, 256), (4, 256, 8, 4, 512)])
    def test_dense_forward_matches_unrolled(self, layers, d, heads, kv, ff):
        cfg = ModelConfig("t", "dense", layers, d, heads, kv, ff, 512,
                          d_head=d // heads)
        b, s = 2, 128
        compiled = _fwd_flops_compiled(cfg, b, s, unroll=layers)
        analytic = sum(forward_flops(cfg, b, s, s, False).values())
        assert abs(analytic / compiled - 1) < 0.15, \
            f"census {analytic:.3e} vs compiled {compiled:.3e}"

    def test_undercount_magnitude_with_loops(self):
        """With the scan NOT unrolled, cost_analysis loses ~(L-1)/L of the
        layer FLOPs — the error the census corrects."""
        cfg = ModelConfig("t", "dense", 8, 128, 4, 2, 256, 512, d_head=32)
        rolled = _fwd_flops_compiled(cfg, 2, 128, unroll=1)
        unrolled = _fwd_flops_compiled(cfg, 2, 128, unroll=8)
        assert unrolled > 3.0 * rolled

    def test_moe_forward_matches_unrolled(self):
        cfg = ModelConfig("t", "moe", 2, 128, 4, 2, 256, 512, d_head=32,
                          moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=128,
                                        capacity_factor=1.25))
        b, s = 2, 128
        compiled = _fwd_flops_compiled(cfg, b, s, unroll=2)
        analytic = sum(forward_flops(cfg, b, s, s, False).values())
        # MoE dispatch gather/scatter adds non-matmul flops; allow 30%
        assert abs(analytic / compiled - 1) < 0.30

    def test_train_flops_factor(self):
        """Train census ~= 4x forward (bwd 2x + remat recompute 1x)."""
        cfg = ModelConfig("t", "dense", 2, 128, 4, 2, 256, 512, d_head=32)
        c = census(cfg, "train", 4, 128, n_chips=1, tp=1)
        f = sum(forward_flops(cfg, 4, 128, 128, False).values())
        assert 3.5 * f < c.flops < 4.6 * f

    def test_decode_flops_scale_with_batch_not_seq(self):
        cfg = ModelConfig("t", "dense", 2, 128, 4, 2, 256, 512, d_head=32)
        a = census(cfg, "decode", 8, 1024, n_chips=1, tp=1)
        b = census(cfg, "decode", 16, 1024, n_chips=1, tp=1)
        assert 1.8 < b.flops / a.flops < 2.2

    def test_collectives_zero_on_single_chip(self):
        cfg = ModelConfig("t", "dense", 2, 128, 4, 2, 256, 512, d_head=32)
        c = census(cfg, "train", 4, 128, n_chips=1, tp=1)
        assert c.wire_bytes == 0.0

    def test_grad_compression_cuts_wire_bytes(self):
        cfg = ModelConfig("t", "dense", 2, 128, 4, 2, 256, 512, d_head=32)
        a = census(cfg, "train", 64, 128, n_chips=256, tp=16)
        b = census(cfg, "train", 64, 128, n_chips=256, tp=16,
                   grad_compression="q8")
        assert b.wire_bytes < a.wire_bytes  # int8 gradients on the wire

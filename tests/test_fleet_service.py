"""Fleet advisor service: multi-tenant continuous batching invariants.

The load-bearing assertion is exact parity: whatever the interleaving of
tenant deltas and recommends through the shared slots, every tenant's
recommendation equals — config, cost, used_bytes — a fresh
`DesignAdvisor` on that tenant's current workload.  The rest pins the
amortization machinery (schema-fingerprint grouping, shared SampleCF
cache, cross-tenant prefetch) and the isolation surface (admission
control, per-tenant budgets, failure containment).

Kept free of hypothesis/zstandard imports so the fleet regressions run
in every environment.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (AdvisorOptions, DesignAdvisor, WorkloadDelta,
                        make_scaled_workload, make_tpch_like)
from repro.core.samplecf import schema_fingerprint
from repro.serve.advisor_service import (AdvisorFleetService, FleetConfig,
                                         TenantBudget,
                                         TenantBudgetExceeded)
from repro.serve.engine import QueueFull

BUDGET = 2_000_000


def tenant_workload(schema, tid: str, n: int = 14, seed: int = 0):
    """A per-tenant workload with tenant-prefixed statement names."""
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def identical(a, b) -> bool:
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.1, seed=0)


def make_fleet(schema, n_tenants, opt=None, fc=None):
    fleet = AdvisorFleetService(fc or FleetConfig(slots=3))
    wls = {}
    for i in range(n_tenants):
        tid = f"t{i}"
        wls[tid] = tenant_workload(schema, tid, seed=50 + i)
        fleet.register_tenant(tid, wls[tid], opt or AdvisorOptions.dtac())
    return fleet, wls


class TestFleetParity:
    def test_batched_recommends_match_fresh_advisor(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 5, opt)
        tickets = {tid: fleet.submit_recommend(tid, BUDGET) for tid in wls}
        fleet.run_until_drained()
        for tid, tk in tickets.items():
            fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
            assert identical(tk.result(), fresh), tid
        assert fleet.stats["groups"] == 1  # same schema: one share group

    def test_interleaved_delta_storm_parity(self, schema):
        """THE fleet contract: exact per-tenant parity under interleaved
        per-tenant deltas and recommends sharing slots and caches."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 4, opt)
        rng = np.random.default_rng(3)
        for rnd in range(3):
            tks = {}
            for i, tid in enumerate(list(wls)):
                wl = wls[tid]
                names = [s.name for s in wl.statements]
                removed = tuple(rng.choice(names, size=2, replace=False))
                pool = make_scaled_workload(
                    schema, n_statements=2,
                    seed=900 + rnd * 10 + i).statements
                added = tuple(
                    dataclasses.replace(s, name=f"{tid}_r{rnd}_{j}")
                    for j, s in enumerate(pool))
                rw = tuple((n, float(rng.uniform(0.5, 2.0)))
                           for n in rng.choice(
                               [n for n in names if n not in removed],
                               size=3, replace=False))
                delta = WorkloadDelta(added=added, removed=removed,
                                      reweighted=rw)
                fleet.submit_delta(tid, delta)
                wls[tid] = wl.apply_delta(delta)
                tks[tid] = fleet.submit_recommend(tid, BUDGET)
            fleet.run_until_drained()
            for tid, tk in tks.items():
                fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
                assert identical(tk.result(), fresh), (rnd, tid)

    def test_per_tenant_fifo(self, schema):
        """A tenant's requests execute in its submission order: a
        recommend submitted after a delta sees the post-delta workload
        even though both were queued before the loop ran."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 1, opt)
        wl = wls["t0"]
        delta = WorkloadDelta(
            removed=(wl.statements[0].name, wl.statements[1].name))
        fleet.submit_delta("t0", delta)
        tk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fresh = DesignAdvisor(wl.apply_delta(delta), opt).recommend(BUDGET)
        assert identical(tk.result(), fresh)


class TestSharing:
    def test_fingerprint_grouping(self, schema):
        """Tenants group by schema CONTENT + seed, not by object
        identity; different content lands in different groups."""
        other = make_tpch_like(scale=0.1, seed=1)
        assert schema_fingerprint(schema, 0) == \
            schema_fingerprint(make_tpch_like(scale=0.1, seed=0), 0)
        assert schema_fingerprint(schema, 0) != schema_fingerprint(other, 0)
        assert schema_fingerprint(schema, 0) != schema_fingerprint(schema, 1)

        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=2))
        fleet.register_tenant("a", tenant_workload(schema, "a"), opt)
        fleet.register_tenant(
            "b", tenant_workload(make_tpch_like(scale=0.1, seed=0), "b",
                                 seed=9), opt)
        fleet.register_tenant("c", tenant_workload(other, "c"), opt)
        assert fleet.stats["groups"] == 2
        assert fleet.tenants["a"].group is fleet.tenants["b"].group
        assert fleet.tenants["a"].group is not fleet.tenants["c"].group

    def test_shared_cache_amortizes_sampling(self, schema):
        """Evidence the sharing pays: co-scheduled tenants on one schema
        are served almost entirely from the cross-tenant prefetch (zero
        per-session SampleCF misses), and the group's sampling cost is
        paid once, not per tenant."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 4, opt,
                                fc=FleetConfig(slots=4))
        for tid in wls:
            fleet.submit_recommend(tid, BUDGET)
        fleet.run_until_drained()
        s = fleet.stats
        assert s["groups"] == 1
        assert s["prefetch_targets"] > 0
        for tid in wls:
            ts = fleet.tenant_stats(tid)
            # every sampled estimate came from the shared prefetched cache
            assert ts["samplecf_cache_misses"] == 0
        # one SampleManager: the shared fleet draws strictly fewer
        # samples than the same tenants run in isolated fleets (tenants'
        # plans may pick different fractions f, so the shared count is
        # bounded by distinct (table, f) pairs, not by one tenant's)
        separate = 0
        for tid, wl in wls.items():
            solo = AdvisorFleetService(FleetConfig(slots=1))
            solo.register_tenant(tid, wl, opt)
            solo.submit_recommend(tid, BUDGET)
            solo.run_until_drained()
            separate += solo.stats["sampling_calls"]
        assert s["sampling_calls"] < separate

    def test_prefetch_off_still_exact(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=2, prefetch=False))
        tks = {tid: fleet.submit_recommend(tid, BUDGET) for tid in wls}
        fleet.run_until_drained()
        for tid, tk in tks.items():
            fresh = DesignAdvisor(wls[tid], AdvisorOptions.dtac()
                                  ).recommend(BUDGET)
            assert identical(tk.result(), fresh)


class TestIsolation:
    def test_queue_admission_control(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=1, max_queue=2))
        fleet.submit_recommend("t0", BUDGET)
        fleet.submit_recommend("t1", BUDGET)
        with pytest.raises(QueueFull):
            fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fleet.submit_recommend("t0", BUDGET)  # capacity freed

    def test_per_tenant_pending_cap(self, schema):
        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        fleet.register_tenant("a", tenant_workload(schema, "a"), opt,
                              TenantBudget(max_pending=1))
        fleet.register_tenant("b", tenant_workload(schema, "b", seed=9),
                              opt)
        fleet.submit_recommend("a", BUDGET)
        with pytest.raises(QueueFull):
            fleet.submit_recommend("a", BUDGET)
        fleet.submit_recommend("b", BUDGET)  # other tenants unaffected
        fleet.run_until_drained()

    def test_statement_budget_enforced_before_apply(self, schema):
        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        wl = tenant_workload(schema, "a")
        fleet.register_tenant("a", wl, opt,
                              TenantBudget(max_statements=len(
                                  wl.statements) + 1))
        added = tuple(
            dataclasses.replace(s, name=f"a_x{j}") for j, s in enumerate(
                make_scaled_workload(schema, n_statements=3,
                                     seed=7).statements))
        tk = fleet.submit_delta("a", WorkloadDelta(added=added))
        fleet.run_until_drained()
        assert isinstance(tk.exception(), TenantBudgetExceeded)
        # the violating delta never touched the session
        assert len(fleet.tenants["a"].session.workload.statements) == \
            len(wl.statements)
        tk2 = fleet.submit_recommend("a", BUDGET)
        fleet.run_until_drained()
        fresh = DesignAdvisor(wl, opt).recommend(BUDGET)
        assert identical(tk2.result(), fresh)

    def test_failed_delta_isolated_to_tenant(self, schema):
        """An invalid delta resolves ONE ticket with the error; the
        tenant's workload is unchanged and co-batched tenants are
        untouched."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=2))
        bad = fleet.submit_delta(
            "t0", WorkloadDelta(removed=("no_such_statement",)))
        ok = fleet.submit_recommend("t1", BUDGET)
        fleet.run_until_drained()
        assert isinstance(bad.exception(), KeyError)
        fresh = DesignAdvisor(wls["t1"], opt).recommend(BUDGET)
        assert identical(ok.result(), fresh)
        tk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fresh0 = DesignAdvisor(wls["t0"], opt).recommend(BUDGET)
        assert identical(tk.result(), fresh0)

    def test_duplicate_tenant_rejected(self, schema):
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        fleet.register_tenant("a", tenant_workload(schema, "a"))
        with pytest.raises(ValueError):
            fleet.register_tenant("a", tenant_workload(schema, "a"))

"""Fleet advisor service: multi-tenant continuous batching invariants.

The load-bearing assertion is exact parity: whatever the interleaving of
tenant deltas and recommends through the shared slots, every tenant's
recommendation equals — config, cost, used_bytes — a fresh
`DesignAdvisor` on that tenant's current workload.  The rest pins the
amortization machinery (schema-fingerprint grouping, shared SampleCF
cache, cross-tenant prefetch) and the isolation surface (admission
control, per-tenant budgets, failure containment).

Kept free of hypothesis/zstandard imports so the fleet regressions run
in every environment.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (AdvisorOptions, DesignAdvisor, FaultError,
                        FaultInjector, FaultSpec, WorkloadDelta,
                        make_scaled_workload, make_tpch_like)
from repro.core.samplecf import schema_fingerprint
from repro.serve.advisor_service import (AdvisorFleetService, DrainStalled,
                                         FleetConfig, TenantBudget,
                                         TenantBudgetExceeded,
                                         TenantQuarantined, TicketTimeout)
from repro.serve.engine import QueueFull

BUDGET = 2_000_000


def tenant_workload(schema, tid: str, n: int = 14, seed: int = 0):
    """A per-tenant workload with tenant-prefixed statement names."""
    wl = make_scaled_workload(schema, n_statements=n, seed=seed)
    return dataclasses.replace(
        wl, statements=[dataclasses.replace(s, name=f"{tid}_{s.name}")
                        for s in wl.statements])


def identical(a, b) -> bool:
    return (a.config == b.config and a.cost == b.cost
            and a.used_bytes == b.used_bytes)


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.1, seed=0)


def make_fleet(schema, n_tenants, opt=None, fc=None):
    fleet = AdvisorFleetService(fc or FleetConfig(slots=3))
    wls = {}
    for i in range(n_tenants):
        tid = f"t{i}"
        wls[tid] = tenant_workload(schema, tid, seed=50 + i)
        fleet.register_tenant(tid, wls[tid], opt or AdvisorOptions.dtac())
    return fleet, wls


class TestFleetParity:
    def test_batched_recommends_match_fresh_advisor(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 5, opt)
        tickets = {tid: fleet.submit_recommend(tid, BUDGET) for tid in wls}
        fleet.run_until_drained()
        for tid, tk in tickets.items():
            fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
            assert identical(tk.result(), fresh), tid
        assert fleet.stats["groups"] == 1  # same schema: one share group

    def test_interleaved_delta_storm_parity(self, schema):
        """THE fleet contract: exact per-tenant parity under interleaved
        per-tenant deltas and recommends sharing slots and caches."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 4, opt)
        rng = np.random.default_rng(3)
        for rnd in range(3):
            tks = {}
            for i, tid in enumerate(list(wls)):
                wl = wls[tid]
                names = [s.name for s in wl.statements]
                removed = tuple(rng.choice(names, size=2, replace=False))
                pool = make_scaled_workload(
                    schema, n_statements=2,
                    seed=900 + rnd * 10 + i).statements
                added = tuple(
                    dataclasses.replace(s, name=f"{tid}_r{rnd}_{j}")
                    for j, s in enumerate(pool))
                rw = tuple((n, float(rng.uniform(0.5, 2.0)))
                           for n in rng.choice(
                               [n for n in names if n not in removed],
                               size=3, replace=False))
                delta = WorkloadDelta(added=added, removed=removed,
                                      reweighted=rw)
                fleet.submit_delta(tid, delta)
                wls[tid] = wl.apply_delta(delta)
                tks[tid] = fleet.submit_recommend(tid, BUDGET)
            fleet.run_until_drained()
            for tid, tk in tks.items():
                fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
                assert identical(tk.result(), fresh), (rnd, tid)

    def test_per_tenant_fifo(self, schema):
        """A tenant's requests execute in its submission order: a
        recommend submitted after a delta sees the post-delta workload
        even though both were queued before the loop ran."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 1, opt)
        wl = wls["t0"]
        delta = WorkloadDelta(
            removed=(wl.statements[0].name, wl.statements[1].name))
        fleet.submit_delta("t0", delta)
        tk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fresh = DesignAdvisor(wl.apply_delta(delta), opt).recommend(BUDGET)
        assert identical(tk.result(), fresh)


class TestSharing:
    def test_fingerprint_grouping(self, schema):
        """Tenants group by schema CONTENT + seed, not by object
        identity; different content lands in different groups."""
        other = make_tpch_like(scale=0.1, seed=1)
        assert schema_fingerprint(schema, 0) == \
            schema_fingerprint(make_tpch_like(scale=0.1, seed=0), 0)
        assert schema_fingerprint(schema, 0) != schema_fingerprint(other, 0)
        assert schema_fingerprint(schema, 0) != schema_fingerprint(schema, 1)

        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=2))
        fleet.register_tenant("a", tenant_workload(schema, "a"), opt)
        fleet.register_tenant(
            "b", tenant_workload(make_tpch_like(scale=0.1, seed=0), "b",
                                 seed=9), opt)
        fleet.register_tenant("c", tenant_workload(other, "c"), opt)
        assert fleet.stats["groups"] == 2
        assert fleet.tenants["a"].group is fleet.tenants["b"].group
        assert fleet.tenants["a"].group is not fleet.tenants["c"].group

    def test_shared_cache_amortizes_sampling(self, schema):
        """Evidence the sharing pays: co-scheduled tenants on one schema
        are served almost entirely from the cross-tenant prefetch (zero
        per-session SampleCF misses), and the group's sampling cost is
        paid once, not per tenant."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 4, opt,
                                fc=FleetConfig(slots=4))
        for tid in wls:
            fleet.submit_recommend(tid, BUDGET)
        fleet.run_until_drained()
        s = fleet.stats
        assert s["groups"] == 1
        assert s["prefetch_targets"] > 0
        for tid in wls:
            ts = fleet.tenant_stats(tid)
            # every sampled estimate came from the shared prefetched cache
            assert ts["samplecf_cache_misses"] == 0
        # one SampleManager: the shared fleet draws strictly fewer
        # samples than the same tenants run in isolated fleets (tenants'
        # plans may pick different fractions f, so the shared count is
        # bounded by distinct (table, f) pairs, not by one tenant's)
        separate = 0
        for tid, wl in wls.items():
            solo = AdvisorFleetService(FleetConfig(slots=1))
            solo.register_tenant(tid, wl, opt)
            solo.submit_recommend(tid, BUDGET)
            solo.run_until_drained()
            separate += solo.stats["sampling_calls"]
        assert s["sampling_calls"] < separate

    def test_prefetch_off_still_exact(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=2, prefetch=False))
        tks = {tid: fleet.submit_recommend(tid, BUDGET) for tid in wls}
        fleet.run_until_drained()
        for tid, tk in tks.items():
            fresh = DesignAdvisor(wls[tid], AdvisorOptions.dtac()
                                  ).recommend(BUDGET)
            assert identical(tk.result(), fresh)


class TestIsolation:
    def test_queue_admission_control(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=1, max_queue=2))
        fleet.submit_recommend("t0", BUDGET)
        fleet.submit_recommend("t1", BUDGET)
        with pytest.raises(QueueFull):
            fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fleet.submit_recommend("t0", BUDGET)  # capacity freed

    def test_per_tenant_pending_cap(self, schema):
        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        fleet.register_tenant("a", tenant_workload(schema, "a"), opt,
                              TenantBudget(max_pending=1))
        fleet.register_tenant("b", tenant_workload(schema, "b", seed=9),
                              opt)
        fleet.submit_recommend("a", BUDGET)
        with pytest.raises(QueueFull):
            fleet.submit_recommend("a", BUDGET)
        fleet.submit_recommend("b", BUDGET)  # other tenants unaffected
        fleet.run_until_drained()

    def test_statement_budget_enforced_before_apply(self, schema):
        opt = AdvisorOptions.dtac()
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        wl = tenant_workload(schema, "a")
        fleet.register_tenant("a", wl, opt,
                              TenantBudget(max_statements=len(
                                  wl.statements) + 1))
        added = tuple(
            dataclasses.replace(s, name=f"a_x{j}") for j, s in enumerate(
                make_scaled_workload(schema, n_statements=3,
                                     seed=7).statements))
        tk = fleet.submit_delta("a", WorkloadDelta(added=added))
        fleet.run_until_drained()
        assert isinstance(tk.exception(), TenantBudgetExceeded)
        # the violating delta never touched the session
        assert len(fleet.tenants["a"].session.workload.statements) == \
            len(wl.statements)
        tk2 = fleet.submit_recommend("a", BUDGET)
        fleet.run_until_drained()
        fresh = DesignAdvisor(wl, opt).recommend(BUDGET)
        assert identical(tk2.result(), fresh)

    def test_failed_delta_isolated_to_tenant(self, schema):
        """An invalid delta resolves ONE ticket with the error; the
        tenant's workload is unchanged and co-batched tenants are
        untouched."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=2))
        bad = fleet.submit_delta(
            "t0", WorkloadDelta(removed=("no_such_statement",)))
        ok = fleet.submit_recommend("t1", BUDGET)
        fleet.run_until_drained()
        assert isinstance(bad.exception(), KeyError)
        fresh = DesignAdvisor(wls["t1"], opt).recommend(BUDGET)
        assert identical(ok.result(), fresh)
        tk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        fresh0 = DesignAdvisor(wls["t0"], opt).recommend(BUDGET)
        assert identical(tk.result(), fresh0)

    def test_duplicate_tenant_rejected(self, schema):
        fleet = AdvisorFleetService(FleetConfig(slots=1))
        fleet.register_tenant("a", tenant_workload(schema, "a"))
        with pytest.raises(ValueError):
            fleet.register_tenant("a", tenant_workload(schema, "a"))


class TestDurability:
    """Deadlines, retries, quarantine/restore, bounded caches — the
    parity contract through the failure surface."""

    def test_transient_fault_retried_to_success(self, schema):
        """A delta failing with a transient FaultError is requeued with
        step backoff and retried bit-exactly."""
        opt = AdvisorOptions.dtac()
        inj = FaultInjector(specs={"apply_delta": FaultSpec(at=(0,))})
        fleet = AdvisorFleetService(FleetConfig(slots=2), faults=inj)
        wl = tenant_workload(schema, "t0", seed=50)
        fleet.register_tenant("t0", wl, opt)
        delta = WorkloadDelta(removed=(wl.statements[0].name,))
        tk = fleet.submit_delta("t0", delta)
        rk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        assert tk.result()["applied"] is True
        assert tk.attempts == 2                  # one fault, one success
        assert fleet.stats["retries"] == 1
        assert fleet.stats["failures"] == 0
        fresh = DesignAdvisor(wl.apply_delta(delta), opt).recommend(BUDGET)
        assert identical(rk.result(), fresh)

    def test_retry_exhaustion_quarantines_then_restore(self, schema):
        """A persistent fault exhausts the bounded retries, trips the
        circuit breaker, flushes the tenant's queue with
        TenantQuarantined and rejects submits; checkpoint readmission
        brings the tenant back `==` a fresh advisor."""
        opt = AdvisorOptions.dtac()
        inj = FaultInjector(specs={"apply_delta": 1.0})  # always fires
        fc = FleetConfig(slots=1, retry_backoff=(1, 2),
                         quarantine_after=1)
        fleet = AdvisorFleetService(fc, faults=inj)
        wl = tenant_workload(schema, "t0", seed=50)
        fleet.register_tenant("t0", wl, opt)
        tk = fleet.submit_delta(
            "t0", WorkloadDelta(removed=(wl.statements[0].name,)))
        queued = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        assert isinstance(tk.exception(), FaultError)
        assert tk.attempts == 3                 # 1 + len(retry_backoff)
        assert isinstance(queued.exception(), TenantQuarantined)
        s = fleet.stats
        assert s["quarantines"] == 1 and s["quarantined_tenants"] == 1
        with pytest.raises(TenantQuarantined):
            fleet.submit_recommend("t0", BUDGET)
        fleet.readmit_tenant("t0")
        assert fleet.stats["restores"] == 1
        rk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        # the faulted delta never applied: parity vs the ORIGINAL workload
        fresh = DesignAdvisor(wl, opt).recommend(BUDGET)
        assert identical(rk.result(), fresh)

    def test_crash_then_auto_readmit_parity(self, schema):
        """crash_tenant drops the session; the quarantine_steps cooldown
        restores it from the post-delta checkpoint, so the recovered
        tenant recommends against its CURRENT workload."""
        opt = AdvisorOptions.dtac()
        fc = FleetConfig(slots=2, quarantine_steps=2)
        fleet = AdvisorFleetService(fc)
        wl = tenant_workload(schema, "t0", seed=50)
        fleet.register_tenant("t0", wl, opt)
        delta = WorkloadDelta(removed=(wl.statements[0].name,
                                       wl.statements[1].name))
        fleet.submit_delta("t0", delta)
        fleet.run_until_drained()
        wl = wl.apply_delta(delta)
        fleet.crash_tenant("t0")
        assert fleet.tenants["t0"].session is None
        for _ in range(10):                     # idle ticks drive cooldown
            if fleet.tenants["t0"].quarantined_at is None:
                break
            fleet.step()
        assert fleet.tenants["t0"].quarantined_at is None
        ts = fleet.tenant_stats("t0")
        assert ts["restores"] == 1 and ts["n_statements"] == \
            len(wl.statements)
        rk = fleet.submit_recommend("t0", BUDGET)
        fleet.run_until_drained()
        assert identical(rk.result(),
                         DesignAdvisor(wl, opt).recommend(BUDGET))
        assert len(fleet.restore_seconds) == 1

    def test_deadline_expires_queued_request(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 1, opt, fc=FleetConfig(slots=1))
        first = fleet.submit_recommend("t0", BUDGET)
        late = fleet.submit_recommend("t0", BUDGET, deadline_steps=1)
        fleet.run_until_drained()
        assert identical(first.result(),
                         DesignAdvisor(wls["t0"], opt).recommend(BUDGET))
        with pytest.raises(TicketTimeout, match="t0.*deadline"):
            late.result()
        assert fleet.stats["timeouts"] == 1

    def test_deadline_pressure_degrades_recommend(self, schema):
        """With degraded_budget set, an expiring recommend is served NOW
        at the smaller workload-compression budget — exact for that
        budget, certificate attached — instead of failing."""
        opt = AdvisorOptions.dtac()
        fc = FleetConfig(slots=1, degraded_budget=6)
        fleet = AdvisorFleetService(fc)
        wl0 = tenant_workload(schema, "t0", seed=50)
        wl1 = tenant_workload(schema, "t1", seed=51)
        fleet.register_tenant("t0", wl0, opt)
        fleet.register_tenant("t1", wl1, opt)
        fleet.submit_recommend("t0", BUDGET)      # occupies the one slot
        tk = fleet.submit_recommend("t1", BUDGET, deadline_steps=1)
        fleet.run_until_drained()
        assert tk.degraded is True
        assert fleet.stats["degraded_recommends"] == 1
        dopt = dataclasses.replace(opt, compression_budget=6)
        fresh = DesignAdvisor(wl1, dopt).recommend(BUDGET)
        rec = tk.result()
        assert identical(rec, fresh)
        # the certificate rides along: the degraded answer is an exact
        # advisor run on <= 6 representatives, error bound included
        assert 0 < rec.n_representatives <= 6
        assert rec.compression_error_bound >= 0.0

    def test_drain_stall_raises_with_pending_counts(self, schema):
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 1, opt, fc=FleetConfig(slots=1))
        tk = fleet.submit_recommend("t0", BUDGET)
        with pytest.raises(DrainStalled) as ei:
            fleet.run_until_drained(max_steps=0)
        assert ei.value.queued == 1
        assert ei.value.pending_by_tenant == {"t0": 1}
        fleet.run_until_drained()                 # work was NOT lost
        assert identical(tk.result(),
                         DesignAdvisor(wls["t0"], opt).recommend(BUDGET))

    def test_prefetch_failure_counted_not_fatal(self, schema):
        """A failing prefetch batch is counted, attached to the affected
        tickets, and the recommends still resolve bit-exactly (the warm-
        up is pure optimization)."""
        opt = AdvisorOptions.dtac()
        inj = FaultInjector(specs={"prefetch": 1.0})
        fleet = AdvisorFleetService(FleetConfig(slots=2), faults=inj)
        wls = {}
        for i in range(2):
            tid = f"t{i}"
            wls[tid] = tenant_workload(schema, tid, seed=50 + i)
            fleet.register_tenant(tid, wls[tid], opt)
        tks = {tid: fleet.submit_recommend(tid, BUDGET) for tid in wls}
        fleet.run_until_drained()
        s = fleet.stats
        assert s["prefetch_failures"] >= 1
        assert s["prefetch_batches"] == 0         # every batch faulted
        assert any(isinstance(tk.prefetch_error, FaultError)
                   for tk in tks.values())
        for tid, tk in tks.items():
            assert identical(tk.result(),
                             DesignAdvisor(wls[tid], opt).recommend(BUDGET))

    def test_result_default_timeout_names_tenant_and_kind(self, schema):
        """A ticket awaited while the loop is not running fails fast
        with a message saying WHOSE request is stuck, not a silent
        forever-block."""
        opt = AdvisorOptions.dtac()
        fleet, _ = make_fleet(schema, 1, opt)
        tk = fleet.submit_recommend("t0", BUDGET)
        with pytest.raises(TicketTimeout, match="'t0' recommend"):
            tk.result(timeout=0.01)
        fleet.run_until_drained()
        tk.result()                               # resolves normally now

    def test_bounded_group_cache_keeps_parity(self, schema):
        """A tight share-group LRU forces evictions across drift rounds;
        every recommendation stays `==` the fresh advisor."""
        opt = AdvisorOptions.dtac()
        fleet, wls = make_fleet(schema, 2, opt,
                                fc=FleetConfig(slots=2, cache_entries=8))
        for rnd in range(2):
            tks = {}
            for i, tid in enumerate(list(wls)):
                added = tuple(dataclasses.replace(s, name=f"{tid}_b{rnd}{j}")
                              for j, s in enumerate(make_scaled_workload(
                                  schema, n_statements=2,
                                  seed=700 + rnd * 10 + i).statements))
                delta = WorkloadDelta(added=added)
                fleet.submit_delta(tid, delta)
                wls[tid] = wls[tid].apply_delta(delta)
                tks[tid] = fleet.submit_recommend(tid, BUDGET)
            fleet.run_until_drained()
            for tid, tk in tks.items():
                fresh = DesignAdvisor(wls[tid], opt).recommend(BUDGET)
                assert identical(tk.result(), fresh), (rnd, tid)
        s = fleet.stats
        assert s["shared_cache_entries"] <= 8
        assert s["shared_cache_evictions"] > 0


class TestDurableStoreWiring:
    """The fleet x DurableStore integration surface (the store's own
    semantics and the crash-point harness live in test_durability.py):
    journal-before-apply ordering, budget metadata round-tripping, and
    store-backed fleets behaving identically to store-less ones."""

    def test_store_backed_fleet_same_answers_as_storeless(self, schema,
                                                          tmp_path):
        from repro.core import DurableStore
        opt = AdvisorOptions.dtac()
        plain, wls = make_fleet(schema, 2, opt)
        store = DurableStore(tmp_path, compact_after=2)
        durable = AdvisorFleetService(FleetConfig(slots=3), store=store)
        for tid, wl in wls.items():
            durable.register_tenant(tid, wl, opt)
        added = tuple(dataclasses.replace(s, name=f"d{j}")
                      for j, s in enumerate(make_scaled_workload(
                          schema, n_statements=2, seed=900).statements))
        results = {}
        for fleet in (plain, durable):
            fleet.submit_delta("t0", WorkloadDelta(added=added))
            tk = fleet.submit_recommend("t0", BUDGET)
            fleet.run_until_drained()
            results[fleet] = tk.result()
        assert identical(results[plain], results[durable])
        assert durable.stats["wal_appends"] == 1

    def test_budget_metadata_survives_recovery(self, schema, tmp_path):
        from repro.core import DurableStore
        store = DurableStore(tmp_path)
        fleet = AdvisorFleetService(FleetConfig(slots=1), store=store)
        wl = tenant_workload(schema, "t0", seed=50)
        budget = TenantBudget(max_statements=len(wl.statements) + 1,
                              max_pending=7)
        fleet.register_tenant("t0", wl, AdvisorOptions.dtac(),
                              budget=budget)
        store.close()
        f2 = AdvisorFleetService.recover(tmp_path)
        got = f2.tenants["t0"].budget
        assert got.max_statements == budget.max_statements
        assert got.max_pending == budget.max_pending
        # and the cap is live: the oversize delta is rejected before it
        # is ever journaled, so the next recovery replays nothing
        added = tuple(dataclasses.replace(s, name=f"x{j}")
                      for j, s in enumerate(make_scaled_workload(
                          schema, n_statements=3, seed=901).statements))
        tk = f2.submit_delta("t0", WorkloadDelta(added=added))
        f2.run_until_drained()
        assert isinstance(tk.exception(30), TenantBudgetExceeded)
        f2.store.close()
        f3 = AdvisorFleetService.recover(tmp_path)
        assert len(f3.tenants["t0"].session.workload.statements) \
            == len(wl.statements)

"""Batched cost-engine tests: scalar/batched parity on the paper workloads,
staged_recommend (Example 1), and SizeProvider.fallback_hits accounting.

Deliberately hypothesis-free so this module always runs (the property-test
modules skip when hypothesis is not installed).
"""
import numpy as np
import pytest

from repro.core import (AdvisorOptions, CostEngine, DesignAdvisor,
                        base_configuration, make_scaled_workload,
                        make_tpch_like, make_tpch_workload)
from repro.core import candidates as cand
from repro.core.advisor import staged_recommend
from repro.core.cost_engine import HAVE_JAX
from repro.core.enumeration import greedy_enumerate, greedy_enumerate_scalar
from repro.core.whatif import Configuration


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.3, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_tpch_workload(schema, insert_weight=0.1)


@pytest.fixture(scope="module")
def base_size(schema, workload):
    adv = DesignAdvisor(workload)
    return sum(adv.sizes.size(i) for i in base_configuration(schema).indexes)


def _rel_err(a, b):
    return abs(a - b) / max(abs(b), 1e-12)


class TestConfigCostParity:
    def test_base_config_cost_matches_scalar(self, workload):
        adv = DesignAdvisor(workload)
        base = base_configuration(workload.schema)
        engine = CostEngine(workload, adv.sizes)
        assert _rel_err(engine.config_cost(base),
                        adv.optimizer.workload_cost(base)) < 1e-12

    def test_single_index_configs_match_scalar(self, workload, schema):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        q = workload.queries()[0]
        raw = cand.syntactically_relevant(q, schema.tables[q.table])
        raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
        adv.estimate_sizes(raw)
        engine = CostEngine(workload, adv.sizes)
        configs = []
        for idx in raw:
            if idx.clustered:
                old = base.clustered(idx.table)
                configs.append(base.replace(old, idx))
            else:
                configs.append(base.add(idx))
        batched = engine.config_costs(configs)
        scalar = [adv.optimizer.workload_cost(c) for c in configs]
        np.testing.assert_allclose(batched, scalar, rtol=1e-12)

    def test_workload_cost_batch_api(self, workload):
        adv = DesignAdvisor(workload)
        base = base_configuration(workload.schema)
        out = adv.optimizer.workload_cost_batch([base, base])
        assert out.shape == (2,)
        assert _rel_err(out[0], adv.optimizer.workload_cost(base)) < 1e-12

    def test_cost_candidates_engine_matches_scalar(self, workload, schema):
        adv = DesignAdvisor(workload)
        base = base_configuration(schema)
        engine = CostEngine(workload, adv.sizes)
        for q in workload.queries()[:6]:
            raw = cand.syntactically_relevant(q, schema.tables[q.table])
            raw = cand.expand_with_compression(raw, ("NS", "LDICT"))
            got = cand.cost_candidates(q, raw, base, adv.optimizer,
                                       adv.sizes, engine=engine)
            want = cand.cost_candidates(q, raw, base, adv.optimizer,
                                        adv.sizes)
            assert [c.index.key for c in got] == [c.index.key for c in want]
            np.testing.assert_allclose([c.cost for c in got],
                                       [c.cost for c in want], rtol=1e-12)
            np.testing.assert_allclose([c.size for c in got],
                                       [c.size for c in want], rtol=1e-12)


class TestEnumerationParity:
    @pytest.mark.parametrize("variant", ["pure", "density", "backtrack"])
    @pytest.mark.parametrize("frac", [0.0, 0.15, 0.4, 1.0])
    def test_greedy_matches_scalar(self, workload, schema, base_size,
                                   variant, frac):
        adv = DesignAdvisor(workload, AdvisorOptions(use_engine=False))
        pq, merged_all, all_cands = adv._candidate_universe()
        adv.estimate_sizes(all_cands)
        base = base_configuration(schema)
        pool = {}
        for q in workload.queries():
            for c in cand.select_skyline(cand.cost_candidates(
                    q, pq[q.name], base, adv.optimizer, adv.sizes)):
                pool.setdefault(c.index.key, c.index)
        for idx in merged_all:
            pool.setdefault(idx.key, idx)
        pool = list(pool.values())
        budget = frac * base_size
        res_s = greedy_enumerate_scalar(adv.optimizer, adv.sizes, pool,
                                        base, budget, variant=variant)
        engine = CostEngine(workload, adv.sizes)
        res_b = greedy_enumerate(adv.optimizer, adv.sizes, pool, base,
                                 budget, variant=variant, engine=engine)
        assert res_b.config == res_s.config
        assert _rel_err(res_b.cost, res_s.cost) < 1e-6
        assert _rel_err(res_b.used_bytes or 1.0,
                        res_s.used_bytes or 1.0) < 1e-6

    @pytest.mark.parametrize("frac", [0.0, 0.2, 0.6])
    def test_recommend_matches_scalar_end_to_end(self, workload, base_size,
                                                 frac):
        budget = frac * base_size
        rec_b = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(
            budget)
        rec_s = DesignAdvisor(workload, AdvisorOptions(
            use_engine=False)).recommend(budget)
        assert rec_b.config == rec_s.config
        assert _rel_err(rec_b.cost, rec_s.cost) < 1e-6
        assert _rel_err(rec_b.base_cost, rec_s.base_cost) < 1e-6

    def test_recommend_matches_scalar_scaled_workload(self, schema):
        # seed chosen to avoid degenerate equal-cost optima: some seeds
        # (e.g. 1, 3) produce two clustered orderings whose total costs
        # agree to the last ulp, where scalar/batched summation order
        # legitimately breaks the tie differently
        wl = make_scaled_workload(schema, n_statements=60, seed=5)
        adv = DesignAdvisor(wl)
        base_size = sum(adv.sizes.size(i)
                        for i in base_configuration(schema).indexes)
        rec_b = DesignAdvisor(wl, AdvisorOptions.dtac()).recommend(
            0.25 * base_size)
        rec_s = DesignAdvisor(wl, AdvisorOptions(use_engine=False)).recommend(
            0.25 * base_size)
        assert rec_b.config == rec_s.config
        assert _rel_err(rec_b.cost, rec_s.cost) < 1e-6

    def test_insert_heavy_parity(self, schema, base_size):
        wl = make_tpch_workload(schema, insert_weight=50.0)
        rec_b = DesignAdvisor(wl, AdvisorOptions.dtac()).recommend(
            0.5 * base_size)
        rec_s = DesignAdvisor(wl, AdvisorOptions(use_engine=False)).recommend(
            0.5 * base_size)
        assert rec_b.config == rec_s.config
        assert _rel_err(rec_b.cost, rec_s.cost) < 1e-6


class TestJaxBackend:
    @pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
    def test_jax_backend_close_to_numpy(self, workload, base_size):
        budget = 0.3 * base_size
        rec_np = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(
            budget)
        rec_jx = DesignAdvisor(workload, AdvisorOptions(
            engine_backend="jax")).recommend(budget)
        # jax defaults to f32 for the scoring kernel: loose tolerance only
        assert _rel_err(rec_jx.cost, rec_np.cost) < 1e-3
        assert rec_jx.cost <= rec_jx.base_cost


class TestStagedRecommend:
    """Example 1: select-then-compress is a valid but inferior baseline."""

    def test_staged_improves_over_base(self, workload, base_size):
        rec = staged_recommend(workload, 0.25 * base_size)
        assert rec.cost <= rec.base_cost + 1e-9
        assert rec.improvement >= 0.0

    def test_staged_never_beats_dtac(self, workload, base_size):
        for frac in (0.15, 0.3):
            b = frac * base_size
            dtac = DesignAdvisor(workload, AdvisorOptions.dtac()).recommend(b)
            staged = staged_recommend(workload, b)
            assert dtac.cost <= staged.cost + 1e-9

    def test_staged_keeps_one_clustered_per_table(self, workload, schema,
                                                  base_size):
        rec = staged_recommend(workload, 0.3 * base_size)
        for t in schema.tables:
            n = sum(1 for i in rec.config.indexes
                    if i.table == t and i.clustered)
            assert n == 1


class TestSizeProviderAccounting:
    def test_recommend_registers_all_compressed_candidates(self, workload):
        """A full recommend() must size every compressed candidate through
        the §4-§5 estimation framework — zero analytic-prior fallbacks."""
        adv = DesignAdvisor(workload, AdvisorOptions.dtac())
        all_cands = adv.generate_candidates()
        rec = adv.recommend(1e12)
        assert adv.sizes.fallback_hits == 0
        for idx in all_cands:
            if idx.compression is None or idx.predicate is not None:
                continue
            assert adv.sizes._key(idx) in adv.sizes._sizes, idx.label()
        assert adv.sizes.fallback_hits == 0
        assert rec.cost <= rec.base_cost + 1e-9

    def test_fallback_hits_counts_unregistered(self, workload, schema):
        adv = DesignAdvisor(workload)
        idx = cand.syntactically_relevant(
            workload.queries()[0],
            schema.tables[workload.queries()[0].table])[0]
        compressed = idx.with_compression("NS")
        assert adv.sizes.fallback_hits == 0
        s1 = adv.sizes.size(compressed)       # unregistered -> prior fallback
        assert adv.sizes.fallback_hits == 1
        assert s1 == pytest.approx(
            adv.sizes.analytic_uncompressed(compressed)
            * adv.sizes.DEFAULT_CF_PRIOR)
        adv.sizes.register(compressed, 123.0)
        assert adv.sizes.size(compressed) == 123.0
        assert adv.sizes.fallback_hits == 1   # no new fallback

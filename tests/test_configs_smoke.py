"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, all_configs, get_config, smoke_config
from repro.models import model as MD
from repro.models.config import pad_for_tp
from repro.optim import AdamWConfig, adamw_init, adamw_update


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = MD.init_params(rng, cfg, jnp.float32)

    b, s = 2, 16
    if cfg.frontend == "tokens":
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                    cfg.vocab)
        embeds = None
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                    cfg.vocab)
        embeds = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, s, cfg.d_model)) * 0.02

    logits = MD.forward(params, cfg, tokens=tokens, embeds=embeds)
    assert logits.shape == (b, s, cfg.vocab_p)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    # one train step
    loss, grads = jax.value_and_grad(MD.loss_fn)(params, cfg, tokens, tokens,
                                                 embeds)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    opt_state = adamw_init(params, AdamWConfig())
    new_params, _ = adamw_update(params, grads, opt_state, AdamWConfig())
    # parameters actually moved
    moved = any(bool(jnp.any(a != b2))
                for a, b2 in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = MD.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    state = MD.init_serve_state(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, state2 = MD.decode_step(params, state, cfg, tok)
    assert logits.shape == (2, 1, cfg.vocab_p)
    assert not bool(jnp.isnan(logits).any())
    assert state2["pos"].shape == (2,)  # per-slot positions
    assert bool((state2["pos"] == 1).all())


class TestFullConfigs:
    """Analytic checks on the published (full) configs — no allocation."""

    @pytest.mark.parametrize("arch,expected_b,tol", [
        ("rwkv6-7b", 7e9, 0.35),
        ("yi-34b", 34e9, 0.15),
        ("tinyllama-1.1b", 1.1e9, 0.15),
        ("nemotron-4-15b", 15e9, 0.25),
        ("yi-9b", 9e9, 0.15),
        ("jamba-1.5-large-398b", 398e9, 0.10),
        ("pixtral-12b", 12e9, 0.25),
        ("granite-moe-3b-a800m", 3.3e9, 0.25),
        ("qwen3-moe-235b-a22b", 235e9, 0.10),
        ("musicgen-medium", 1.5e9, 0.35),
    ])
    def test_param_count_matches_published(self, arch, expected_b, tol):
        cfg = get_config(arch)
        n = cfg.param_count()
        assert abs(n / expected_b - 1) < tol, f"{arch}: {n/1e9:.1f}B"

    @pytest.mark.parametrize("arch", ARCHS)
    def test_tp16_divisibility_after_padding(self, arch):
        cfg = pad_for_tp(get_config(arch), 16)
        assert cfg.d_model % 16 == 0
        assert cfg.vocab_p % 16 == 0
        assert cfg.d_ff % 16 == 0
        if cfg.mixer == "attn" or cfg.hybrid is not None:
            assert cfg.heads % 16 == 0
            assert cfg.kv_heads % 16 == 0
        if cfg.moe is not None:
            assert cfg.moe.experts % 16 == 0

    @pytest.mark.parametrize("arch", ARCHS)
    def test_eval_shape_full_config(self, arch):
        """Full config parameter skeletons build without allocation."""
        cfg = pad_for_tp(get_config(arch), 16)
        shapes = MD.params_shape(cfg, jnp.bfloat16)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert n > 0.8 * cfg.param_count()

"""Tests for the estimation-plan graph search (§5), errors, and AE (App. B)."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (EstimationPlanner, IndexDef, NodeKey, SampleManager,
                        State, make_tpch_like)
from repro.core import distinct as DV
from repro.core import errors as E
from repro.core.estimation_graph import F_GRID, FORCE_ALL_Q
from repro.core.planner_engine import assert_plan_identical
from repro.core.samplecf import full_index_sizes
from repro.core.synopses import MVDef, SynopsisManager


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.5, z=0, seed=0)


class TestErrors:
    def test_goodman_product_variance(self):
        a, b = E.ErrorRV(1.0, 0.1), E.ErrorRV(1.1, 0.2)
        got = E.compose([a, b])
        want_var = (0.01 + 1.0) * (0.04 + 1.21) - 1.0 * 1.21
        assert math.isclose(got.var, want_var, rel_tol=1e-9)
        assert math.isclose(got.mean, 1.1, rel_tol=1e-9)

    def test_prob_within_monotone_in_e(self):
        rv = E.ErrorRV(1.0, 0.2)
        ps = [E.prob_within(rv, e) for e in (0.1, 0.3, 0.5, 1.0)]
        assert all(a <= b for a, b in zip(ps, ps[1:]))

    def test_samplecf_error_shrinks_with_f(self):
        a = E.samplecf_error("LDICT", 0.01)
        b = E.samplecf_error("LDICT", 0.10)
        assert b.std < a.std

    def test_bias_correction_normalizes_mean(self):
        raw = E.samplecf_error("LDICT", 0.01, corrected=False)
        cor = E.samplecf_error("LDICT", 0.01, corrected=True)
        assert raw.mean > 1.0 and cor.mean == 1.0 and cor.std < raw.std

    @given(st.floats(0.01, 0.99), st.floats(0.0, 0.5))
    @settings(max_examples=50, deadline=None)
    def test_property_prob_within_bounds(self, f, bias):
        rv = E.ErrorRV(1.0 + bias, 0.05)
        p = E.prob_within(rv, 0.5)
        assert 0.0 <= p <= 1.0

    rv_strategy = st.tuples(st.floats(0.2, 2.5), st.floats(0.0, 0.6))

    @given(st.lists(rv_strategy, min_size=0, max_size=7))
    @settings(max_examples=60, deadline=None)
    def test_property_compose_batch_bit_identical(self, pairs):
        """compose_batch == scalar compose, bit-for-bit, on 1-D stacks."""
        rvs = [E.ErrorRV(m, s) for m, s in pairs]
        want = E.compose(rvs)
        means = np.array([m for m, _ in pairs])
        stds = np.array([s for _, s in pairs])
        gm, gs = E.compose_batch(means, stds)
        assert float(gm) == want.mean and float(gs) == want.std

    @given(st.lists(st.lists(rv_strategy, min_size=3, max_size=3),
                    min_size=1, max_size=5))
    @settings(max_examples=40, deadline=None)
    def test_property_compose_batch_rows(self, rows):
        """Row-stacked compose_batch == per-row scalar compose; EXACT
        padding is a bitwise no-op."""
        means = np.array([[m for m, _ in row] for row in rows])
        stds = np.array([[s for _, s in row] for row in rows])
        pad_m = np.concatenate([means, np.ones((len(rows), 2))], axis=1)
        pad_s = np.concatenate([stds, np.zeros((len(rows), 2))], axis=1)
        gm, gs = E.compose_batch(means, stds, axis=1)
        pm, ps = E.compose_batch(pad_m, pad_s, axis=1)
        assert np.array_equal(gm, pm) and np.array_equal(gs, ps)
        for i, row in enumerate(rows):
            want = E.compose([E.ErrorRV(m, s) for m, s in row])
            assert gm[i] == want.mean and gs[i] == want.std

    @given(st.floats(0.2, 2.5),
           st.one_of(st.just(0.0), st.just(1e-13), st.floats(1e-6, 0.6)),
           st.floats(0.01, 2.0))
    @settings(max_examples=80, deadline=None)
    def test_property_prob_within_batch_bit_identical(self, mean, std, e):
        """prob_within_batch == scalar prob_within, bit-for-bit, through
        both the deterministic (std ~ 0) and the normal-CDF branch."""
        want = E.prob_within(E.ErrorRV(mean, std), e)
        got = E.prob_within_batch(np.array([mean]), np.array([std]), e)
        assert float(got[0]) == want

    @given(st.lists(rv_strategy, min_size=2, max_size=6))
    @settings(max_examples=40, deadline=None)
    def test_property_goodman_fold_continuation(self, pairs):
        """Continuing the raw fold with one more factor equals composing
        the full list — the planner engine appends the deduction term
        this way."""
        *head, (lm, ls) = pairs
        means = np.array([m for m, _ in head])
        stds = np.array([s for _, s in head])
        ep, v, e2 = E.goodman_fold(means, stds)
        mm = lm * lm
        ep = ep * lm
        v = v * (ls * ls + mm)
        e2 = e2 * mm
        want = E.compose([E.ErrorRV(m, s) for m, s in pairs])
        assert float(ep) == want.mean
        assert float(np.sqrt(np.maximum(v - e2, 0.0))) == want.std


class TestPlanner:
    # (table, cols) pool for randomized target sets: permutations share a
    # column set (ColSet deductions), wider keys extend narrower (ColExt)
    PLAN_POOL = (
        ("lineitem", ("l_shipdate",)),
        ("lineitem", ("l_quantity",)),
        ("lineitem", ("l_extendedprice",)),
        ("lineitem", ("l_shipdate", "l_quantity")),
        ("lineitem", ("l_quantity", "l_shipdate")),
        ("lineitem", ("l_shipdate", "l_extendedprice")),
        ("lineitem", ("l_shipdate", "l_extendedprice", "l_quantity")),
        ("lineitem", ("l_extendedprice", "l_shipdate", "l_quantity")),
        ("orders", ("o_orderdate",)),
        ("orders", ("o_orderdate", "o_totalprice")),
        ("orders", ("o_totalprice", "o_orderdate")),
    )

    @given(st.sampled_from(["NS", "LDICT"]),
           st.lists(st.integers(0, 10), min_size=1, max_size=6,
                    unique=True),
           st.sampled_from(F_GRID),
           st.floats(0.05, 1.5),
           st.sampled_from([0.5, 0.8, 0.9, 0.99, FORCE_ALL_Q]),
           st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_property_batched_planner_plan_identical(
            self, method, picks, f, e, q, with_existing):
        """Batched engine == greedy_scalar, plan-identically, over
        randomized target sets, fractions, (e, q) — including the
        FORCE_ALL_Q all-sampled forcing and EXACT existing-index nodes."""
        schema = make_tpch_like(scale=0.2, z=0, seed=0)
        targets = [NodeKey(t, c, method)
                   for t, c in (self.PLAN_POOL[i] for i in picks)]
        existing = {NodeKey("lineitem", ("l_shipdate",), method): 4321.0} \
            if with_existing else None
        planner = EstimationPlanner(schema.tables, existing=existing)
        ref = planner.greedy_scalar(targets, f, e, q)
        got = planner.engine.greedy_batch(targets, e, q, (f,))[0]
        assert_plan_identical(ref, got)

    @given(st.sampled_from(["NS", "LDICT"]), st.floats(0.1, 1.2),
           st.floats(0.5, 0.99))
    @settings(max_examples=10, deadline=None)
    def test_property_plan_engine_equals_scalar_grid(self, method, e, q):
        """`plan` (engine) == `plan_scalar` (reference grid loop)."""
        schema = make_tpch_like(scale=0.2, z=0, seed=0)
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets(method)
        assert_plan_identical(planner.plan_scalar(targets, e, q),
                              planner.plan(targets, e, q))

    def make_targets(self, method="NS"):
        return [
            NodeKey("lineitem", ("l_shipdate",), method),
            NodeKey("lineitem", ("l_extendedprice",), method),
            NodeKey("lineitem", ("l_shipdate", "l_extendedprice"), method),
            NodeKey("lineitem", ("l_shipdate", "l_extendedprice",
                                 "l_quantity"), method),
        ]

    def test_greedy_uses_deduction_when_loose(self, schema):
        planner = EstimationPlanner(schema.tables)
        plan = planner.plan(self.make_targets(), e=1.0, q=0.8)
        assert plan.feasible
        assert plan.n_deduced() >= 1  # wide indexes deduced from narrow ones

    def test_tight_constraint_samples_more(self, schema):
        planner = EstimationPlanner(schema.tables)
        loose = planner.plan(self.make_targets(), e=1.0, q=0.8)
        tight = planner.plan(self.make_targets(), e=0.05, q=0.99)
        assert tight.n_sampled() >= loose.n_sampled()

    def test_greedy_cost_leq_all_sampled(self, schema):
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets()
        plan = planner.plan(targets, e=0.8, q=0.85)
        f = plan.f
        from repro.core.estimation_graph import sampling_cost
        all_cost = sum(sampling_cost(schema.tables[t.table], t, f)
                       for t in targets)
        assert plan.total_cost <= all_cost

    def test_existing_index_is_free(self, schema):
        t = NodeKey("lineitem", ("l_shipdate",), "NS")
        planner = EstimationPlanner(schema.tables, existing={t: 12345.0})
        plan = planner.greedy([t], f=0.05, e=0.5, q=0.9)
        assert plan.nodes[t].state is State.EXACT
        assert plan.total_cost == 0.0
        mgr = SampleManager(schema.tables)
        est = planner.execute(plan, mgr)[t]
        assert est.est_bytes == 12345.0 and est.cost_pages == 0.0

    def test_optimal_not_worse_than_greedy(self, schema):
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets()[:3]
        g = planner.greedy(targets, f=0.05, e=0.8, q=0.85)
        o = planner.optimal(targets, f=0.05, e=0.8, q=0.85)
        assert o.feasible
        assert o.total_cost <= g.total_cost + 1e-9

    def test_execute_estimates_close_to_truth(self, schema):
        li = schema.tables["lineitem"]
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets()
        plan = planner.plan(targets, e=0.5, q=0.9)
        mgr = SampleManager(schema.tables, seed=1)
        ests = planner.execute(plan, mgr)
        for t in targets:
            idx = IndexDef(t.table, t.cols, t.method)
            _, true = full_index_sizes(li, idx)
            assert abs(ests[t].est_bytes / true - 1) < 0.5  # e=0.5 bound

    @given(st.sampled_from(["NS", "LDICT"]), st.floats(0.2, 1.5),
           st.floats(0.5, 0.95))
    @settings(max_examples=10, deadline=None)
    def test_property_plan_always_covers_targets(self, schema_method, e, q):
        schema = make_tpch_like(scale=0.2, z=0, seed=0)
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets(schema_method)
        plan = planner.plan(targets, e=e, q=q)
        for t in targets:
            assert plan.nodes[t].state in (State.SAMPLED, State.DEDUCED)

    @given(st.sampled_from(["NS", "LDICT"]), st.floats(0.1, 1.2),
           st.floats(0.5, 0.99), st.sampled_from([0.025, 0.05, 0.10]))
    @settings(max_examples=15, deadline=None)
    def test_property_greedy_vs_optimal(self, method, e, q, f):
        """Small graphs (paper App. D yardstick): optimal <= greedy <=
        all-sampled, and any feasible plan satisfies (e, q) per target."""
        schema = make_tpch_like(scale=0.2, z=0, seed=0)
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets(method)[:3]
        g = planner.greedy(targets, f, e, q)
        o = planner.optimal(targets, f, e, q)
        from repro.core.estimation_graph import sampling_cost
        all_cost = sum(sampling_cost(schema.tables[t.table], t, f)
                       for t in targets)
        assert o.total_cost <= g.total_cost + 1e-9
        assert g.total_cost <= all_cost + 1e-9
        for plan in (g, o):
            if plan.feasible:
                for t in targets:
                    assert E.satisfies(plan.nodes[t].rv, e, q)
            else:
                assert any(not E.satisfies(plan.nodes[t].rv, e, q)
                           for t in targets)

    @given(st.sampled_from(["NS", "LDICT"]), st.floats(0.1, 0.8),
           st.floats(0.6, 0.99))
    @settings(max_examples=15, deadline=None)
    def test_property_all_sampled_baseline(self, method, e, q):
        """The "All" baseline samples everything and picks the first grid
        fraction satisfying the caller's (e, q), falling back to the
        cheapest (= smallest f) when none does."""
        from repro.core.estimation_graph import F_GRID
        schema = make_tpch_like(scale=0.2, z=0, seed=0)
        planner = EstimationPlanner(schema.tables)
        targets = self.make_targets(method)
        plan = planner.plan_all_sampled(targets, e, q)
        assert plan.n_deduced() == 0
        assert plan.n_sampled() == len(targets)
        feasible_f = [f for f in F_GRID
                      if E.satisfies(E.samplecf_error(method, f), e, q)]
        if feasible_f:
            assert plan.feasible and plan.f == feasible_f[0]
        else:
            assert not plan.feasible and plan.f == F_GRID[0]


class TestAdaptiveEstimator:
    def test_table1_ordering(self, schema):
        """AE error << multiply error on an aggregation MV (Table 1)."""
        from repro.core import SampleManager
        samples = SampleManager(schema.tables, seed=0)
        syn = SynopsisManager(schema, samples)
        mv = MVDef("mv_ship", "lineitem", group_by=("l_shipdate",))
        _, n_ae = syn.mv_sample(mv, 0.05)
        li = schema.tables["lineitem"]
        true = li.ndv(["l_shipdate"])
        sample = samples.get_sample("lineitem", 0.05)
        d_sample = int(np.unique(sample.values["l_shipdate"]).size)
        n_mult = DV.estimate_multiply(d_sample, 0.05)
        err_ae = abs(n_ae / true - 1)
        err_mult = abs(n_mult / true - 1)
        assert err_ae < err_mult
        assert err_ae < 0.5

    def test_ae_exact_when_full_sample(self):
        keys = np.array([1, 1, 2, 3, 3, 3])
        est = DV.adaptive_estimator(DV.frequency_stats(keys), 3, 6, 6)
        assert est == 3.0

    @given(st.integers(10, 500), st.integers(2, 50), st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_property_ae_bounded_by_n(self, n, ndv, seed):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, ndv, n)
        est = DV.adaptive_estimator(
            DV.frequency_stats(keys), int(np.unique(keys).size), n, n * 10)
        assert 0 <= est <= n * 10


class TestSynopses:
    def test_join_synopsis_fk_match(self, schema):
        from repro.core import SampleManager
        samples = SampleManager(schema.tables, seed=0)
        syn = SynopsisManager(schema, samples)
        js = syn.join_synopsis("lineitem", 0.05)
        base = samples.get_sample("lineitem", 0.05)
        assert js.nrows == base.nrows  # FKs always match (B.2)
        assert "o_orderdate" in js.values  # dimension columns joined in

    def test_filtered_sample(self, schema):
        from repro.core import Predicate, SampleManager
        samples = SampleManager(schema.tables, seed=0)
        syn = SynopsisManager(schema, samples)
        li = schema.tables["lineitem"]
        lo, hi = li.minmax("l_shipdate")
        mid = (lo + hi) // 2
        fs = syn.filtered_sample("lineitem", Predicate("l_shipdate", lo, mid),
                                 0.05)
        assert fs.nrows > 0
        assert fs.values["l_shipdate"].max() <= mid
